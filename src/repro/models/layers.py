"""Shared model primitives: norms, rotary embeddings, gated MLP.

Everything is functional: ``init_*`` returns a param PyTree; ``apply``-style
functions take (params, x).  Initializers take an explicit PRNG key and
return arrays in the config dtype (parameters are kept in float32 master
copies by the optimizer; forward casts per config.dtype).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------

def init_norm(kind: str, d: int):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    if kind == "nonparam_ln":        # olmo: no learnable affine
        return {}
    raise ValueError(kind)


def apply_norm(kind: str, params, x: jax.Array, eps: float = 1e-6
               ) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        out = xf / rms * params["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            out = out * params["scale"] + params["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE and Qwen2-VL's M-RoPE).
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float = 10_000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0
               ) -> jax.Array:
    """x f32[..., T, D]; positions int32[..., T] (broadcastable)."""
    d = x.shape[-1]
    while positions.ndim < x.ndim - 1:    # insert head axes before T
        positions = positions[..., None, :]
    freqs = rope_freqs(d, theta)                            # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)             # [..., T, D/2]
    x1, x2 = x[..., ::2], x[..., 1::2]
    rx1 = x1 * cos - x2 * sin
    rx2 = x1 * sin + x2 * cos
    return jnp.stack([rx1, rx2], axis=-1).reshape(x.shape).astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array,
                sections=(0.25, 0.375, 0.375), theta: float = 10_000.0
                ) -> jax.Array:
    """Qwen2-VL M-RoPE: rotary dims split into (temporal, height, width)
    sections, each driven by its own position row.

    x f32[..., T, D]; positions3 int32[3, ..., T].  For pure-text inputs all
    three rows are equal and M-RoPE degenerates to RoPE exactly.
    """
    d = x.shape[-1]
    half = d // 2
    bounds = [0]
    for s in sections[:-1]:
        bounds.append(bounds[-1] + int(half * s))
    bounds.append(half)
    freqs = rope_freqs(d, theta)                            # [D/2]
    # Build a [.., T, D/2] angle table section-by-section.
    angle_parts = []
    for i in range(3):
        lo, hi = bounds[i], bounds[i + 1]
        pos = positions3[i]
        while pos.ndim < x.ndim - 1:      # insert head axes before T
            pos = pos[..., None, :]
        ang = pos[..., None].astype(jnp.float32) * freqs[lo:hi]
        angle_parts.append(ang)
    angles = jnp.concatenate(angle_parts, axis=-1)          # [..., T, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., ::2], x[..., 1::2]
    rx1 = x1 * cos - x2 * sin
    rx2 = x1 * sin + x2 * cos
    return jnp.stack([rx1, rx2], axis=-1).reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU) and plain MLP.
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in
                   ).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in
                 ).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * s_out
                   ).astype(dtype),
    }


def apply_mlp(params, x: jax.Array) -> jax.Array:
    gate = jax.nn.silu(x @ params["w_gate"])
    return (gate * (x @ params["w_up"])) @ params["w_down"]


def init_linear(key, d_in: int, d_out: int, dtype, bias: bool = False
                ) -> dict:
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * d_in ** -0.5
               ).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def apply_linear(params, x: jax.Array) -> jax.Array:
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y
