"""Model zoo: the 10 assigned architectures assembled from block kinds."""
from repro.models.transformer import (decode_step, encode, forward,
                                      init_cache, init_params, param_count)

__all__ = ["decode_step", "encode", "forward", "init_cache", "init_params",
           "param_count"]
