"""RG-LRU recurrent block (RecurrentGemma / Griffin).

The Real-Gated Linear Recurrent Unit:

    r_t = σ(W_a x_t)             (recurrence gate)
    i_t = σ(W_x x_t)             (input gate)
    a_t = exp(−c·softplus(Λ)·r_t)
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

The recurrence is *linear in h*, so the whole sequence computes with a
``jax.lax.associative_scan`` over (a, b) pairs — O(log T) depth on TPU
instead of a T-step serial scan.  This is the sub-quadratic path that makes
the recurrentgemma long_500k cell runnable: decode state is O(rnn_dim).

Block structure (Griffin): x → {gelu(W_gate·x)} ⊙ {RG-LRU(conv1d(W_in·x))}
→ W_out, with a causal depthwise conv of width ``cfg.conv_width``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dtype_of

_C = 8.0  # Griffin's fixed gate sharpness


def init_rglru(key, cfg) -> dict:
    dt = dtype_of(cfg.dtype)
    d, r = cfg.d_model, cfg.rnn_dim
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "w_in": (jax.random.normal(ks[0], (d, r)) * s).astype(dt),
        "w_gate": (jax.random.normal(ks[1], (d, r)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, r))
                   * cfg.conv_width ** -0.5).astype(jnp.float32),
        "w_a": (jax.random.normal(ks[3], (r, r)) * r ** -0.5
                ).astype(jnp.float32),
        "w_x": (jax.random.normal(ks[4], (r, r)) * r ** -0.5
                ).astype(jnp.float32),
        # Λ init so that a ≈ 0.9..0.999 at r=1 (Griffin's init range).
        "lam": jnp.linspace(0.9, 4.0, r).astype(jnp.float32),
        "w_out": (jax.random.normal(ks[5], (r, d)) * r ** -0.5).astype(dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv: x [B, T, R], w [W, R]."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + pad[:, i:i + x.shape[1]] * w[i]
    return out


def _gates(params, u: jax.Array):
    """u [..., R] -> (a, b) of the linear recurrence h = a·h_prev + b."""
    r_gate = jax.nn.sigmoid(u @ params["w_a"])
    i_gate = jax.nn.sigmoid(u @ params["w_x"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r_gate
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_gate * u)
    return a, b


def rglru_forward(cfg, params, x: jax.Array, return_state: bool = False):
    """x [B, T, D] -> [B, T, D]."""
    gate = jax.nn.gelu((x @ params["w_gate"]).astype(jnp.float32))
    u_raw = (x @ params["w_in"]).astype(jnp.float32)
    u = _causal_conv(u_raw, params["conv_w"])
    a, b = _gates(params, u)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return (a2 * a1, a2 * b1 + b2)

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h * gate) @ params["w_out"].astype(jnp.float32)
    if return_state:
        w = params["conv_w"].shape[0]
        t = x.shape[1]
        if t >= w - 1:
            conv_state = u_raw[:, t - (w - 1):]
        else:
            conv_state = jnp.pad(u_raw, ((0, 0), (w - 1 - t, 0), (0, 0)))
        return y.astype(x.dtype), {"h": h[:, -1], "conv": conv_state}
    return y.astype(x.dtype)


def init_rglru_state(cfg, batch: int) -> dict:
    r = cfg.rnn_dim
    return {"h": jnp.zeros((batch, r), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, r), jnp.float32)}


def rglru_decode(cfg, params, x: jax.Array, state: dict
                 ) -> tuple[jax.Array, dict]:
    """x [B, 1, D] — one linear-recurrence step."""
    gate = jax.nn.gelu((x[:, 0] @ params["w_gate"]).astype(jnp.float32))
    u = (x[:, 0] @ params["w_in"]).astype(jnp.float32)     # [B, R]
    hist = jnp.concatenate([state["conv"], u[:, None]], axis=1)
    w = params["conv_w"]
    u_conv = jnp.einsum("bwr,wr->br", hist, w)
    a, b = _gates(params, u_conv)
    h = a * state["h"] + b
    y = ((h * gate) @ params["w_out"].astype(jnp.float32)
         ).astype(x.dtype)[:, None]
    return y, {"h": h, "conv": hist[:, 1:]}
