"""Mixture-of-Experts FFN with REX-style delta dispatch.

Expert dispatch IS the paper's rehash: each token's routed copy is a
*delta* ``(key=expert, payload=activation)``; dispatch groups deltas by
owner into fixed-capacity per-expert buffers (cf. ``route_by_owner`` in
core/delta.py — same sort-rank-scatter construction), the experts apply
them, and the combine scatters results back weighted by router probability.
Capacity overflow drops the lowest-priority copies (standard MoE token
dropping — the delta-buffer overflow policy, with the router prob as the
priority), exactly the bounded-sparsity adaptation DESIGN.md §2 describes.

Three dispatch strategies, selected by ``strategy``:
  * "sort"  (baseline) — rank-in-group by sorted expert id, scatter into
    [E·C, D] buffers, batched expert matmuls, gather-combine.  Under GSPMD
    the buffers shard over the model axis (EP) and the scatter lowers to
    collectives chosen by XLA.
  * "onehot" — dispatch/combine as one-hot einsums (dense [T, E, C]
    masks); more FLOPs, sometimes better collective schedules for small E.
  * "a2a"   — the REX rehash made explicit (perf iteration 3): a
    ``shard_map`` over the 'model' (EP) axis routes token copies into
    fixed-capacity per-owner segments (``route_by_owner``'s construction,
    keyed by expert owner) and swaps them with ONE ``all_to_all`` each
    way.  Wire bytes drop from GSPMD's gather-everything resolution to
    exactly 2·k·tokens·d_model — the delta-buffer bound.
All are numerically equivalent up to capacity-drop policy (tested).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dtype_of, init_mlp


def init_moe(key, cfg) -> dict:
    dt = dtype_of(cfg.dtype)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {
        "router": (jax.random.normal(k1, (d, e)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (e, d, f)) * s_in).astype(dt),
        "w_up": (jax.random.normal(k3, (e, d, f)) * s_in).astype(dt),
        "w_down": (jax.random.normal(k4, (e, f, d)) * s_out).astype(dt),
    }
    if cfg.moe_dense_residual:          # arctic: parallel dense FFN
        p["dense"] = init_mlp(jax.random.fold_in(key, 7), d, cfg.d_ff, dt)
    return p


def _capacity(cfg, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(8, -(-c // 8) * 8)


def _route(cfg, params, xf):
    """Router: top-k expert choices + normalized probs per token."""
    logits = xf @ params["router"]                        # [T, E]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)        # [T, K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    aux = _load_balance_loss(probs, top_e, cfg.n_experts)
    return top_e.astype(jnp.int32), top_p, aux


def _load_balance_loss(probs, top_e, n_experts):
    """Switch-style auxiliary loss (fraction routed × mean prob)."""
    t = probs.shape[0]
    counts = jnp.zeros((n_experts,), jnp.float32).at[
        top_e.reshape(-1)].add(1.0)
    frac = counts / (t * top_e.shape[-1])
    mean_p = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(frac * mean_p)


def _expert_ffn(params, buf):
    """buf f32[E, C, D] -> f32[E, C, D] (batched SwiGLU over experts)."""
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    return jnp.einsum("ecf,efd->ecd", gate * up, params["w_down"])


def moe_ffn(cfg, params, x: jax.Array, strategy: str = "sort"
            ) -> tuple[jax.Array, jax.Array]:
    """x [B, T, D] -> (y [B, T, D], aux_loss scalar)."""
    b, t, d = x.shape
    xf = x.reshape(b * t, d)
    n = b * t
    cap = _capacity(cfg, n)
    top_e, top_p, aux = _route(cfg, params, xf)

    if strategy == "sort":
        y = _dispatch_sort(cfg, params, xf, top_e, top_p, cap)
    elif strategy == "onehot":
        y = _dispatch_onehot(cfg, params, xf, top_e, top_p, cap)
    elif strategy == "a2a":
        y = _dispatch_a2a(cfg, params, xf, top_e, top_p)
    else:
        raise ValueError(strategy)

    if cfg.moe_dense_residual:
        from repro.models.layers import apply_mlp
        y = y + apply_mlp(params["dense"], xf)
    return y.reshape(b, t, d).astype(x.dtype), aux


def _dispatch_sort(cfg, params, xf, top_e, top_p, cap):
    """Sort-based delta dispatch (route_by_owner over expert keys)."""
    n, d = xf.shape
    e = cfg.n_experts
    k = cfg.top_k
    flat_e = top_e.reshape(-1)                            # [N*K]
    flat_p = top_p.reshape(-1)
    token_of = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    # Rank of each routed copy within its expert group (stable by priority:
    # sort by (expert, -prob) so low-prob copies overflow first).  Routing
    # order is discrete control flow — stop_gradient keeps AD out of the
    # sort (whose transpose triggers batched-gather paths; grads reach the
    # router through the combine-side probability product instead).
    order = jnp.lexsort((jax.lax.stop_gradient(-flat_p), flat_e))
    sorted_e = flat_e[order]
    is_start = jnp.concatenate([jnp.array([True]),
                                sorted_e[1:] != sorted_e[:-1]])
    pos = jnp.arange(n * k, dtype=jnp.int32)
    group_start = jnp.full((n * k,), n * k, jnp.int32).at[
        jnp.cumsum(is_start.astype(jnp.int32)) - 1].min(pos)
    rank_sorted = pos - group_start[jnp.cumsum(
        is_start.astype(jnp.int32)) - 1]
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    keep = rank < cap
    slot = jnp.where(keep, flat_e * cap + rank, e * cap)  # drop -> sentinel
    buf = jnp.zeros((e * cap + 1, d), jnp.float32).at[slot].add(
        jnp.where(keep[:, None], xf[token_of], 0.0), mode="drop")[:-1]
    out_buf = _expert_ffn(params, buf.reshape(e, cap, d)).reshape(
        e * cap, d)
    gathered = out_buf[jnp.where(keep, slot, 0)]
    contrib = jnp.where(keep[:, None], gathered * flat_p[:, None], 0.0)
    return jnp.zeros((n, d), jnp.float32).at[token_of].add(contrib)


def _rank_in_group(owner: jax.Array, n_groups: int) -> jax.Array:
    """Stable rank of each element within its owner group (the
    route_by_owner construction from core/delta.py)."""
    c = owner.shape[0]
    order = jnp.argsort(owner, stable=True)
    sorted_owner = owner[order]
    is_start = jnp.concatenate([jnp.array([True]),
                                sorted_owner[1:] != sorted_owner[:-1]])
    gid = jnp.cumsum(is_start.astype(jnp.int32)) - 1
    pos = jnp.arange(c, dtype=jnp.int32)
    gstart = jnp.full((c,), c, jnp.int32).at[gid].min(pos)
    rank_sorted = pos - gstart[gid]
    return jnp.zeros_like(owner).at[order].set(rank_sorted)


def _dispatch_a2a(cfg, params, xf, top_e, top_p):
    """REX rehash dispatch under shard_map (see module docstring).

    Requires expert weights already gathered to TP-only sharding (the
    opt-level-2 gather hook).  Two sub-modes:
      * EP  (E % model_size == 0): token copies are deltas keyed by
        expert; route_by_owner → ONE all_to_all each way over 'model'.
      * TP  (E < model_size): experts are feature-sharded like a dense
        FFN; dispatch is local, one output psum over 'model'.
    Falls back to the sort dispatch when no mesh/model axis is ambient.
    """
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or "model" not in tuple(mesh.axis_names or ()):
        raise ValueError(
            "a2a MoE dispatch needs an ambient mesh with a 'model' axis "
            "(jax.sharding.set_mesh) — use strategy='sort' otherwise")
    msize = mesh.shape["model"]
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    e, k = cfg.n_experts, cfg.top_k
    n, d = xf.shape
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    n_loc = n // dp_size
    ep_mode = (e % msize == 0) and (n_loc % msize == 0)

    w_specs = (P("model", None, None),) * 3 if ep_mode else (
        P(None, None, "model"), P(None, None, "model"),
        P(None, "model", None))

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(P(dp, None), P(dp, None), P(dp, None)) + w_specs,
             out_specs=P(dp, None), check_vma=False)
    def body(xf_l, e_l, p_l, wg, wu, wd):
        if not ep_mode:
            # TP experts: local dispatch, feature-sharded FFN, one psum.
            cap = _capacity(cfg, xf_l.shape[0])
            y = _dispatch_sort(cfg, {"w_gate": wg, "w_up": wu,
                                     "w_down": wd}, xf_l, e_l, p_l, cap)
            return jax.lax.psum(y, "model")

        m = jax.lax.axis_index("model")
        e_per = e // msize
        n_sub = n_loc // msize
        # Each model rank dispatches its slice of the data-row tokens.
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, m * n_sub, n_sub, 0)
        xs, es, ps = sl(xf_l), sl(e_l), sl(p_l)
        copies = n_sub * k
        flat_e = es.reshape(copies)
        flat_p = ps.reshape(copies)
        token_of = jnp.repeat(jnp.arange(n_sub, dtype=jnp.int32), k)
        owner = flat_e // e_per
        cap_seg = max(8, -(-int(cfg.capacity_factor * copies / msize)
                           // 8) * 8)
        rank = _rank_in_group(owner, msize)
        keep = rank < cap_seg
        slot = jnp.where(keep, owner * cap_seg + rank, msize * cap_seg)
        # Payloads travel bf16 (halves the a2a wire); experts compute f32.
        wire_dt = xs.dtype
        send_tok = jnp.zeros((msize * cap_seg + 1, d), wire_dt).at[
            slot].set(jnp.where(keep[:, None], xs[token_of],
                                jnp.zeros((), wire_dt)),
                      mode="drop")[:-1]
        send_e = jnp.full((msize * cap_seg + 1,), -1, jnp.int32).at[
            slot].set(jnp.where(keep, flat_e, -1), mode="drop")[:-1]
        # THE rehash: one all_to_all each way (paper §4.1 wire pattern).
        recv_tok = jax.lax.all_to_all(
            send_tok.reshape(msize, cap_seg, d), "model", 0, 0,
            tiled=False).reshape(msize * cap_seg, d)
        recv_e = jax.lax.all_to_all(
            send_e.reshape(msize, cap_seg), "model", 0, 0,
            tiled=False).reshape(msize * cap_seg)
        # Group received rows by LOCAL expert; batched FFN; route back.
        le = jnp.where(recv_e >= 0, recv_e - m * e_per, e_per)
        cap_loc = max(8, (msize * cap_seg // e_per) * 2)
        rank2 = _rank_in_group(le, e_per + 1)
        keep2 = (le < e_per) & (rank2 < cap_loc)
        slot2 = jnp.where(keep2, le * cap_loc + rank2, e_per * cap_loc)
        buf = jnp.zeros((e_per * cap_loc + 1, d), jnp.float32).at[
            slot2].set(jnp.where(keep2[:, None],
                                 recv_tok.astype(jnp.float32), 0.0),
                       mode="drop")[:-1]
        out_buf = _expert_ffn({"w_gate": wg, "w_up": wu, "w_down": wd},
                              buf.reshape(e_per, cap_loc, d)
                              ).reshape(e_per * cap_loc, d)
        out_rows = jnp.where(keep2[:, None],
                             out_buf[jnp.where(keep2, slot2, 0)],
                             0.0).astype(wire_dt)
        back = jax.lax.all_to_all(
            out_rows.reshape(msize, cap_seg, d), "model", 0, 0,
            tiled=False).reshape(msize * cap_seg, d)
        got = jnp.where(keep[:, None], back[jnp.where(keep, slot, 0)],
                        jnp.zeros((), wire_dt))
        y_sub = jnp.zeros((n_sub, d), jnp.float32).at[token_of].add(
            got.astype(jnp.float32) * flat_p[:, None])
        # Reassemble the data row in WIRE dtype (bf16): the fwd gather and
        # its transpose (reduce-scatter) both move half the f32 bytes.
        return jax.lax.all_gather(y_sub.astype(wire_dt), "model",
                                  axis=0, tiled=True)

    return body(xf, top_e, top_p, params["w_gate"], params["w_up"],
                params["w_down"]).astype(jnp.float32)


def _dispatch_onehot(cfg, params, xf, top_e, top_p, cap):
    """One-hot einsum dispatch (dense masks; Switch/GShard style)."""
    n, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k
    # Position of each (token, k) copy within its expert, by cumsum.
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.float32)   # [N, K, E]
    pos_in_e = (jnp.cumsum(onehot.reshape(n * k, e), axis=0) - 1
                ).reshape(n, k, e)
    pos = jnp.sum(pos_in_e * onehot, axis=-1).astype(jnp.int32)  # [N, K]
    keep = pos < cap
    disp = ((onehot * keep[..., None])[..., None]
            * jax.nn.one_hot(jnp.where(keep, pos, 0), cap,
                             dtype=jnp.float32)[..., None, :]
            )                                               # [N, K, E, C]
    disp = jnp.sum(disp, axis=1)                            # [N, E, C]
    buf = jnp.einsum("nec,nd->ecd", disp, xf)
    out_buf = _expert_ffn(params, buf)
    comb = disp * jnp.sum(
        jax.nn.one_hot(top_e, e, dtype=jnp.float32)
        * top_p[..., None], axis=1)[:, :, None]             # [N, E, C]
    return jnp.einsum("nec,ecd->nd", comb, out_buf)
