"""Attention variants: GQA (full / sliding-window / bidirectional), MLA,
cross-attention — with training and single-token decode paths.

Decode caches:
  * full attention — k/v [B, H_kv, S, Dh] plus a write position; masked
    prefix attention (the decode_32k cell: one token vs a seq_len cache).
  * sliding window — RING buffer of ``window`` slots with per-slot global
    positions (−1 = empty): O(window) memory regardless of context, which
    is what makes mixtral/recurrentgemma long_500k cells runnable.
  * MLA — stores the rank-r latent + shared rope-key per token (288 floats
    for minicpm3 vs 5120 for dense GQA): the up-projections are *absorbed*
    into the query/output at decode time.

The training path calls kernels/flash_attention (Pallas) when
``use_kernel``; otherwise the jnp oracle (XLA fuses it fine on CPU, and the
dry-run cost model sees identical FLOPs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_attention.ops import attention as flash_attn_op
from repro.models.layers import apply_mrope, apply_rope


# ---------------------------------------------------------------------------
# GQA.
# ---------------------------------------------------------------------------

def init_gqa(key, cfg) -> dict:
    d, hd = cfg.d_model, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    import repro.models.layers as L
    dt = L.dtype_of(cfg.dtype)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(k1, (d, cfg.n_heads * hd)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (d, cfg.n_kv_heads * hd)) * s
               ).astype(dt),
        "wv": (jax.random.normal(k3, (d, cfg.n_kv_heads * hd)) * s
               ).astype(dt),
        "wo": (jax.random.normal(k4, (cfg.n_heads * hd, d))
               * (cfg.n_heads * hd) ** -0.5).astype(dt),
    }


def _split_heads(x, n_heads, hd):
    b, t, _ = x.shape
    return x.reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, t, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * hd)


def _positions_rope(cfg, q, k, positions):
    if cfg.rope_kind == "rope":
        q = apply_rope(q, positions)
        k = apply_rope(k, positions)
    elif cfg.rope_kind == "mrope":
        # positions may be [B, T] (text-only: 3 equal rows) or [3, B, T].
        pos3 = (positions if positions.ndim == 3
                else jnp.broadcast_to(positions[None],
                                      (3,) + positions.shape))
        pos3 = pos3[:, :, None]                      # [3, B, 1, T] per head
        q = apply_mrope(q, pos3)
        k = apply_mrope(k, pos3)
    return q, k


def gqa_train(cfg, params, x: jax.Array, positions: jax.Array,
              causal: bool = True, use_kernel: bool = False) -> jax.Array:
    """x [B, T, D]; positions [B, T] (or [3, B, T] for mrope)."""
    hd = cfg.hd
    q = _split_heads(x @ params["wq"], cfg.n_heads, hd)
    k = _split_heads(x @ params["wk"], cfg.n_kv_heads, hd)
    v = _split_heads(x @ params["wv"], cfg.n_kv_heads, hd)
    q, k = _positions_rope(cfg, q, k, positions)
    if cfg.window and causal:
        out = _windowed_attention(q, k, v, cfg.window)
    elif use_kernel:
        out = flash_attn_op(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), causal=causal,
                            use_kernel=True).astype(x.dtype)
    else:
        out = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), causal=causal
                            ).astype(x.dtype)
    return _merge_heads(out) @ params["wo"]


def _windowed_attention(q, k, v, window: int) -> jax.Array:
    """Causal sliding-window attention (materialized mask; the Pallas
    flash kernel's block-skip generalizes this on TPU)."""
    b, h, t, hd = q.shape
    _, h_kv, s, _ = k.shape
    group = h // h_kv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (hd ** 0.5)
    rows = jnp.arange(t)[:, None]
    cols = jnp.arange(s)[None, :]
    mask = (rows >= cols) & (rows - cols < window)
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


# ---- decode -----------------------------------------------------------

def init_gqa_cache(cfg, batch: int, max_len: int, dtype) -> dict:
    """Full cache, or ring buffer when cfg.window > 0."""
    slots = min(cfg.window, max_len) if cfg.window else max_len
    return {
        "k": jnp.zeros((batch, cfg.n_kv_heads, slots, cfg.hd), dtype),
        "v": jnp.zeros((batch, cfg.n_kv_heads, slots, cfg.hd), dtype),
        "pos": jnp.full((batch, slots), -1, jnp.int32),
    }


def gqa_decode(cfg, params, x: jax.Array, cache: dict, pos: jax.Array,
               flash: bool = False) -> tuple[jax.Array, dict]:
    """x [B, 1, D]; pos scalar int32 — global index of the new token.

    ``flash=True``: flash-decoding under shard_map — the KV cache stays
    SEQUENCE-SHARDED over the 'model' axis; each shard computes a partial
    (m, l, acc) and one tiny psum combines them.  Without it GSPMD
    all-gathers the whole cache per step (the decode_32k baseline's
    dominant collective).  Requires an ambient mesh with a 'model' axis.
    """
    hd = cfg.hd
    b = x.shape[0]
    q = _split_heads(x @ params["wq"], cfg.n_heads, hd)       # [B,H,1,hd]
    k_new = _split_heads(x @ params["wk"], cfg.n_kv_heads, hd)
    v_new = _split_heads(x @ params["wv"], cfg.n_kv_heads, hd)
    posb = jnp.broadcast_to(pos[None, None], (b, 1))
    q, k_new = _positions_rope(cfg, q, k_new, posb)

    slots = cache["k"].shape[2]
    slot = (pos % slots).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=2)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=2)
    slot_pos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], posb, slot, axis=1)
    new_cache = {"k": k, "v": v, "pos": slot_pos}

    if flash:
        out = _flash_decode_attention(cfg, q, k, v, slot_pos, pos)
    else:
        out = _full_decode_attention(cfg, q, k, v, slot_pos, pos)
    return _merge_heads(out) @ params["wo"], new_cache


def _full_decode_attention(cfg, q, k, v, slot_pos, pos):
    hd = cfg.hd
    group = cfg.n_heads // cfg.n_kv_heads
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhsd->bhqs", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) / (hd ** 0.5)
    valid = (slot_pos >= 0)
    if cfg.window:
        valid = valid & (slot_pos > pos - cfg.window)
    valid = valid & (slot_pos <= pos)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqs,bhsd->bhqd", probs, vx.astype(jnp.float32)
                      ).astype(q.dtype)


def _flash_decode_attention(cfg, q, k, v, slot_pos, pos):
    from functools import partial as _partial
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or "model" not in tuple(mesh.axis_names or ()):
        return _full_decode_attention(cfg, q, k, v, slot_pos, pos)
    P = jax.sharding.PartitionSpec
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]
    bspec = dp if (q.shape[0] % max(dp_total, 1) == 0
                   and q.shape[0] >= dp_total) else None
    hd = cfg.hd
    group = cfg.n_heads // cfg.n_kv_heads

    @_partial(jax.shard_map, mesh=mesh,
              in_specs=(P(bspec, None, None, None),
                        P(bspec, None, "model", None),
                        P(bspec, None, "model", None),
                        P(bspec, "model"), P()),
              out_specs=P(bspec, None, None, None), check_vma=False)
    def body(q_l, k_l, v_l, sp_l, pos_s):
        kx = jnp.repeat(k_l, group, axis=1)
        vx = jnp.repeat(v_l, group, axis=1)
        s = jnp.einsum("bhqd,bhsd->bhqs", q_l.astype(jnp.float32),
                       kx.astype(jnp.float32)) / (hd ** 0.5)
        valid = (sp_l >= 0) & (sp_l <= pos_s)
        if cfg.window:
            valid = valid & (sp_l > pos_s - cfg.window)
        s = jnp.where(valid[:, None, None, :], s, -1e30)
        m = jnp.max(s, axis=-1)                       # [B,H,1] local
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bhqs,bhsd->bhqd", p, vx.astype(jnp.float32))
        m_g = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, "model")
        acc_g = jax.lax.psum(acc * corr[..., None], "model")
        return (acc_g / jnp.maximum(l_g, 1e-30)[..., None]
                ).astype(q_l.dtype)

    return body(q, k, v, slot_pos, pos)


BLOCKED_THRESHOLD = 4096 * 8192   # T·S above this ⇒ blocked attention


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True, window: int = 0,
                      block_k: int = 2048, unroll: bool = False
                      ) -> jax.Array:
    """Flash-style attention at the XLA level: lax.scan over KV blocks
    carrying (m, l, acc) — the [T, S] score matrix never materializes.
    This is what makes the prefill_32k cells *fit* (the Pallas kernel is
    the TPU codegen of the same schedule; this is its GSPMD-shardable
    form).  ``unroll`` unrolls the KV loop for exact cost analysis."""
    b, h, t, d = q.shape
    _, h_kv, s, _ = k.shape
    dv = v.shape[-1]                 # MLA: value dim ≠ qk dim
    group = h // h_kv
    nb = -(-s // block_k)
    pad = nb * block_k - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(b, h_kv, nb, block_k, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, h_kv, nb, block_k, dv).transpose(2, 0, 1, 3, 4)
    qg = q.reshape(b, h_kv, group, t, d).astype(jnp.float32)
    rows = jnp.arange(t)[:, None]                    # query positions
    scale = 1.0 / (d ** 0.5)

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, j = blk
        sc = jnp.einsum("bhgtd,bhsd->bhgts", qg,
                        kblk.astype(jnp.float32)) * scale
        cols = j * block_k + jnp.arange(block_k)[None, :]
        mask = cols < s
        if causal:
            mask = mask & (rows >= cols)
        if window:
            mask = mask & (rows - cols < window)
        sc = jnp.where(mask, sc, -1e30)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = (acc * corr[..., None]
                   + jnp.einsum("bhgts,bhsd->bhgtd", p,
                                vblk.astype(jnp.float32)))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h_kv, group, t), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h_kv, group, t), jnp.float32)
    acc0 = jnp.zeros((b, h_kv, group, t, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (kb, vb, jnp.arange(nb)), unroll=nb if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, t, dv).astype(q.dtype)


def gqa_prefill(cfg, params, x: jax.Array, positions: jax.Array,
                max_len: int, unroll: bool = False
                ) -> tuple[jax.Array, dict]:
    """Full-sequence forward that also materializes the decode cache
    (k/v for the whole prompt — or its last ``window`` slots for SWA)."""
    hd = cfg.hd
    b, t, _ = x.shape
    q = _split_heads(x @ params["wq"], cfg.n_heads, hd)
    k = _split_heads(x @ params["wk"], cfg.n_kv_heads, hd)
    v = _split_heads(x @ params["wv"], cfg.n_kv_heads, hd)
    q, k = _positions_rope(cfg, q, k, positions)
    if t * t > BLOCKED_THRESHOLD:
        out = blocked_attention(q, k, v, causal=True, window=cfg.window,
                                unroll=unroll)
    elif cfg.window:
        out = _windowed_attention(q, k, v, cfg.window)
    else:
        out = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), causal=True
                            ).astype(x.dtype)
    y = _merge_heads(out) @ params["wo"]

    slots = min(cfg.window, max_len) if cfg.window else max_len
    pos2 = positions if positions.ndim == 2 else positions[0]
    if t >= slots:          # keep the last ``slots`` positions (ring order)
        k_keep, v_keep = k[:, :, t - slots:], v[:, :, t - slots:]
        pos_keep = pos2[:, t - slots:]
    else:
        pad = slots - t
        k_keep = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_keep = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        pos_keep = jnp.pad(pos2, ((0, 0), (0, pad)), constant_values=-1)
    # Ring slot per kept position; padding slots (-1) fall back to their own
    # index (no collision: live slots occupy pos % slots, and when padding
    # exists t < slots so live ring values are the identity on [0, t)).
    ring_safe = jnp.where(pos_keep >= 0, pos_keep % slots,
                          jnp.arange(slots, dtype=jnp.int32)[None, :]
                          ).astype(jnp.int32)
    # Scatter each kept position into its ring slot.
    bidx = jnp.arange(b)[:, None]
    cache_k = jnp.zeros((b, cfg.n_kv_heads, slots, hd), x.dtype
                        ).at[bidx, :, ring_safe].set(
        jnp.swapaxes(k_keep, 1, 2).astype(x.dtype))
    cache_v = jnp.zeros((b, cfg.n_kv_heads, slots, hd), x.dtype
                        ).at[bidx, :, ring_safe].set(
        jnp.swapaxes(v_keep, 1, 2).astype(x.dtype))
    cache_pos = jnp.full((b, slots), -1, jnp.int32).at[
        bidx, ring_safe].set(jnp.where(pos_keep >= 0, pos_keep, -1))
    return y, {"k": cache_k, "v": cache_v, "pos": cache_pos}


def mla_prefill(cfg, params, x: jax.Array, positions: jax.Array,
                max_len: int, unroll: bool = False
                ) -> tuple[jax.Array, dict]:
    """MLA forward + latent cache (c, rope-k) for the prompt."""
    b, t, _ = x.shape
    y = mla_train(cfg, params, x, positions, causal=True, unroll=unroll)
    c = _rms(x @ params["w_dkv"], params["kv_norm"])
    kr = apply_rope((x @ params["w_kr"])[:, None],
                    positions[:, None])[:, 0]
    pad = max_len - t
    cache_c = jnp.pad(c, ((0, 0), (0, pad), (0, 0))).astype(x.dtype)
    cache_kr = jnp.pad(kr, ((0, 0), (0, pad), (0, 0))).astype(x.dtype)
    return y, {"c": cache_c, "kr": cache_kr}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, minicpm3).
# ---------------------------------------------------------------------------

def init_mla(key, cfg) -> dict:
    import repro.models.layers as L
    dt = L.dtype_of(cfg.dtype)
    d, h = cfg.d_model, cfg.n_heads
    nope = cfg.hd
    rope = cfg.mla_rope_dim
    qr, kvr = cfg.mla_q_rank, cfg.mla_kv_rank
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    return {
        "w_dq": (jax.random.normal(ks[0], (d, qr)) * s).astype(dt),
        "w_uq": (jax.random.normal(ks[1], (qr, h * (nope + rope)))
                 * qr ** -0.5).astype(dt),
        "w_dkv": (jax.random.normal(ks[2], (d, kvr)) * s).astype(dt),
        "w_uk": (jax.random.normal(ks[3], (kvr, h * nope))
                 * kvr ** -0.5).astype(dt),
        "w_uv": (jax.random.normal(ks[4], (kvr, h * nope))
                 * kvr ** -0.5).astype(dt),
        "w_kr": (jax.random.normal(ks[5], (d, rope)) * s).astype(dt),
        "wo": (jax.random.normal(ks[6], (h * nope, d))
               * (h * nope) ** -0.5).astype(dt),
        "q_norm": jnp.ones((qr,), jnp.float32),
        "kv_norm": jnp.ones((kvr,), jnp.float32),
    }


def _rms(x, scale):
    xf = x.astype(jnp.float32)
    r = jnp.sqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
    return (xf / r * scale).astype(x.dtype)


def mla_train(cfg, params, x: jax.Array, positions: jax.Array,
              causal: bool = True, use_kernel: bool = False,
              unroll: bool = False) -> jax.Array:
    b, t, d = x.shape
    h, nope, rope = cfg.n_heads, cfg.hd, cfg.mla_rope_dim
    cq = _rms(x @ params["w_dq"], params["q_norm"])
    q = (cq @ params["w_uq"]).reshape(b, t, h, nope + rope)
    q = q.transpose(0, 2, 1, 3)                               # [B,H,T,·]
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    c = _rms(x @ params["w_dkv"], params["kv_norm"])          # [B,T,kvr]
    k_nope = (c @ params["w_uk"]).reshape(b, t, h, nope).transpose(0, 2, 1, 3)
    v = (c @ params["w_uv"]).reshape(b, t, h, nope).transpose(0, 2, 1, 3)
    k_rope = (x @ params["w_kr"])[:, None]                    # [B,1,T,rope]
    q_rope = apply_rope(q_rope, positions[:, None])
    k_rope = apply_rope(k_rope, positions[:, None])
    # Assemble full-dim q/k; shared rope key broadcast across heads.
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (b, h, t, rope))], axis=-1)
    if t * t > BLOCKED_THRESHOLD:
        out = blocked_attention(qf, kf, v, causal=causal, unroll=unroll)
    else:
        out = attention_ref(qf.astype(jnp.float32), kf.astype(jnp.float32),
                            v.astype(jnp.float32), causal=causal
                            ).astype(x.dtype)
    return _merge_heads(out) @ params["wo"]


def init_mla_cache(cfg, batch: int, max_len: int, dtype) -> dict:
    return {
        "c": jnp.zeros((batch, max_len, cfg.mla_kv_rank), dtype),
        "kr": jnp.zeros((batch, max_len, cfg.mla_rope_dim), dtype),
    }


def mla_decode(cfg, params, x: jax.Array, cache: dict, pos: jax.Array
               ) -> tuple[jax.Array, dict]:
    """Absorbed-matmul MLA decode: attention runs in latent space; the
    cache stores (kv_rank + rope_dim) floats per token."""
    b = x.shape[0]
    h, nope, rope = cfg.n_heads, cfg.hd, cfg.mla_rope_dim
    kvr = cfg.mla_kv_rank
    cq = _rms(x @ params["w_dq"], params["q_norm"])
    q = (cq @ params["w_uq"]).reshape(b, 1, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]             # [B,1,H,·]
    posb = jnp.broadcast_to(pos[None, None], (b, 1))
    q_rope = apply_rope(q_rope.transpose(0, 2, 1, 3),
                        posb[:, None]).transpose(0, 2, 1, 3)
    c_new = _rms(x @ params["w_dkv"], params["kv_norm"])      # [B,1,kvr]
    kr_new = apply_rope((x @ params["w_kr"])[:, None],
                        posb[:, None])[:, 0]                  # [B,1,rope]

    cache_c = jax.lax.dynamic_update_slice_in_dim(
        cache["c"], c_new.astype(cache["c"].dtype), pos, axis=1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(
        cache["kr"], kr_new.astype(cache["kr"].dtype), pos, axis=1)

    # Absorb w_uk into the query: q_c[b,h,r] = Σ_n q_nope·w_uk[r,(h,n)].
    w_uk = params["w_uk"].reshape(kvr, h, nope)
    q_c = jnp.einsum("bqhn,rhn->bhqr", q_nope.astype(jnp.float32),
                     w_uk.astype(jnp.float32))                # [B,H,1,kvr]
    scores = (jnp.einsum("bhqr,bsr->bhqs", q_c,
                         cache_c.astype(jnp.float32))
              + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                           cache_kr.astype(jnp.float32))
              ) / ((nope + rope) ** 0.5)
    valid = jnp.arange(cache_c.shape[1]) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bhqr", probs,
                     cache_c.astype(jnp.float32))             # [B,H,1,kvr]
    w_uv = params["w_uv"].reshape(kvr, h, nope)
    out = jnp.einsum("bhqr,rhn->bhqn", ctx,
                     w_uv.astype(jnp.float32)).astype(x.dtype)
    return (_merge_heads(out) @ params["wo"],
            {"c": cache_c, "kr": cache_kr})


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder).
# ---------------------------------------------------------------------------

def init_cross(key, cfg) -> dict:
    return init_gqa(key, cfg)


def cross_attend(cfg, params, x: jax.Array, enc_kv: tuple) -> jax.Array:
    """x [B, T, D]; enc_kv = (k, v) each [B, H_kv, S_enc, hd] precomputed
    from the encoder output (cached for the whole decode)."""
    q = _split_heads(x @ params["wq"], cfg.n_heads, cfg.hd)
    k, v = enc_kv
    out = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=False).astype(x.dtype)
    return _merge_heads(out) @ params["wo"]


def encode_cross_kv(cfg, params, enc_out: jax.Array) -> tuple:
    k = _split_heads(enc_out @ params["wk"], cfg.n_kv_heads, cfg.hd)
    v = _split_heads(enc_out @ params["wv"], cfg.n_kv_heads, cfg.hd)
    return (k, v)
