"""xLSTM blocks: chunked-parallel mLSTM and sequential sLSTM.

mLSTM (matrix memory): per head, ``C_t = f_t·C_{t−1} + i_t·k_t v_tᵀ`` with
stabilized exponential gating; ``h_t = C_t q_t / max(|n_t·q_t|, e^{−m_t})``.
A naive time scan materializes a [hd, hd] state per step; the TPU
adaptation uses the **chunked-parallel form** (as in GLA / mamba-2): within
a chunk of ``cfg.mlstm_chunk`` tokens the contribution is a masked
attention-like matmul (MXU-dense); across chunks only the boundary state
(C, n, m) recurs.  Sequential depth drops from T to T/chunk.

Derivation used below (per head; g_s = ĩ_s − F_s, F = cumsum log f):
    M_c   = max(m₀, cummax_{s≤c} g_s)            (stabilizer, query c)
    w_cs  = exp(g_s − M_c)·[s ≤ c]               (intra-chunk weights)
    num_c = e^{m₀−M_c}·C₀ᵀq_c + Σ_s w_cs (k_s·q_c) v_s
    den_c = e^{m₀−M_c}·n₀·q_c + Σ_s w_cs (k_s·q_c)
    h_c   = num_c / max(|den_c|, e^{−(M_c+F_c)})
with the carry advanced to the chunk end the same way.

sLSTM (scalar memory, recurrent connection R·h_{t−1} inside the gates) is
inherently sequential — a lax.scan over time with block-diagonal per-head
recurrent weights.  Per the xLSTM paper this irreducible sequentiality is
why the architecture mixes the two kinds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dtype_of


# ---------------------------------------------------------------------------
# mLSTM.
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg) -> dict:
    dt = dtype_of(cfg.dtype)
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], (d, h * hd)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, h * hd)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, h * hd)) * s).astype(dt),
        "w_if": (jax.random.normal(ks[3], (d, 2 * h)) * s).astype(jnp.float32),
        "out_gate": (jax.random.normal(ks[4], (d, h * hd)) * s).astype(dt),
        "wo": (jax.random.normal(ks[5], (h * hd, d))
               * (h * hd) ** -0.5).astype(dt),
    }


def _mlstm_chunk_body(carry, inp):
    """One chunk: carry (C [B,H,hd,hd], n [B,H,hd], m [B,H])."""
    C0, n0, m0 = carry
    qc, kc, vc, log_i, log_f = inp        # [B,CH,H,hd] ×3, [B,CH,H] ×2
    F = jnp.cumsum(log_f, axis=1)                         # [B,CH,H]
    g = log_i - F                                         # [B,CH,H]
    M = jnp.maximum(m0[:, None], jax.lax.cummax(g, axis=1))   # [B,CH,H]

    scores_qk = jnp.einsum("bchd,bshd->bcsh", qc, kc)     # [B,CQ,CS,H]
    mask = jnp.tril(jnp.ones((qc.shape[1], qc.shape[1]), bool))
    w = jnp.where(mask[None, :, :, None],
                  jnp.exp(g[:, None] - M[:, :, None]), 0.0)
    scores = scores_qk * w                                # [B,CQ,CS,H]
    inter_decay = jnp.exp(m0[:, None] - M)                # [B,CH,H]
    num = (jnp.einsum("bchd,bhde->bche", qc, C0) * inter_decay[..., None]
           + jnp.einsum("bcsh,bshd->bchd", scores, vc))
    den = (jnp.einsum("bchd,bhd->bch", qc, n0) * inter_decay
           + jnp.sum(scores, axis=2))
    floor = jnp.exp(-(M + F))
    out = num / jnp.maximum(jnp.abs(den), floor)[..., None]

    # Advance carry to chunk end.
    F_L = F[:, -1]                                        # [B,H]
    M_L = jnp.maximum(m0, jnp.max(g, axis=1))
    k_decay = jnp.exp(g - M_L[:, None])                   # [B,CH,H]
    C_new = (jnp.exp(m0 - M_L)[..., None, None] * C0
             + jnp.einsum("bshd,bshe,bsh->bhde", kc, vc, k_decay))
    n_new = (jnp.exp(m0 - M_L)[..., None] * n0
             + jnp.einsum("bshd,bsh->bhd", kc, k_decay))
    return (C_new, n_new, M_L + F_L), out


def mlstm_forward(cfg, params, x: jax.Array, return_state: bool = False):
    """x [B, T, D] -> [B, T, D] (T padded up to a chunk multiple; causal,
    so trailing padding never affects real positions — zero-input pads
    contribute nothing to (C, n), so the returned state is exact too)."""
    b, t_orig, d = x.shape
    h, hd, ch = cfg.n_heads, cfg.hd, cfg.mlstm_chunk
    pad = (-t_orig) % ch
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((b, pad, d), x.dtype)], axis=1)
    t = x.shape[1]
    nc = t // ch
    q = (x @ params["wq"]).reshape(b, nc, ch, h, hd).astype(jnp.float32)
    k = ((x @ params["wk"]).reshape(b, nc, ch, h, hd).astype(jnp.float32)
         / hd ** 0.5)
    v = (x @ params["wv"]).reshape(b, nc, ch, h, hd).astype(jnp.float32)
    gates = (x.astype(jnp.float32) @ params["w_if"]).reshape(
        b, nc, ch, 2, h)
    log_i = gates[..., 0, :]
    log_f = jax.nn.log_sigmoid(gates[..., 1, :])
    if pad:
        # Padding steps must be identity on the carried state: f=1 (no
        # decay), i=0 (no injection) — otherwise the returned prefill
        # state would have been forgotten ``pad`` extra times.
        is_pad = (jnp.arange(t) >= t_orig).reshape(1, nc, ch, 1)
        log_f = jnp.where(is_pad, 0.0, log_f)
        log_i = jnp.where(is_pad, -1e30, log_i)

    C0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, h, hd), jnp.float32)
    m0 = jnp.zeros((b, h), jnp.float32)
    inputs = tuple(a.swapaxes(0, 1) for a in (q, k, v, log_i, log_f))
    carry, outs = jax.lax.scan(_mlstm_chunk_body, (C0, n0, m0), inputs)
    outs = outs.swapaxes(0, 1).reshape(b, t, h * hd)
    gate = jax.nn.sigmoid((x @ params["out_gate"]).astype(jnp.float32))
    y = ((outs * gate) @ params["wo"].astype(jnp.float32)).astype(x.dtype)
    y = y[:, :t_orig]
    if return_state:
        C, n, m = carry
        return y, {"C": C, "n": n, "m": m}
    return y


def init_mlstm_state(cfg, batch: int) -> dict:
    h, hd = cfg.n_heads, cfg.hd
    return {"C": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32),
            "m": jnp.zeros((batch, h), jnp.float32)}


def mlstm_decode(cfg, params, x: jax.Array, state: dict
                 ) -> tuple[jax.Array, dict]:
    """x [B, 1, D] — one recurrent step (a one-delta stratum over the
    mutable state, cf. DESIGN.md §5 decode-as-delta)."""
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.hd
    q = (x @ params["wq"]).reshape(b, h, hd).astype(jnp.float32)
    k = ((x @ params["wk"]).reshape(b, h, hd).astype(jnp.float32)
         / hd ** 0.5)
    v = (x @ params["wv"]).reshape(b, h, hd).astype(jnp.float32)
    gates = (x.astype(jnp.float32) @ params["w_if"]).reshape(b, 2, h)
    log_i, log_f = gates[:, 0], jax.nn.log_sigmoid(gates[:, 1])
    m_new = jnp.maximum(log_f + state["m"], log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    C = f_s[..., None, None] * state["C"] + i_s[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = f_s[..., None] * state["n"] + i_s[..., None] * k
    num = jnp.einsum("bhde,bhd->bhe", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)),
                      jnp.exp(-m_new))
    out = (num / den[..., None]).reshape(b, 1, h * hd)
    gate = jax.nn.sigmoid((x @ params["out_gate"]).astype(jnp.float32))
    y = ((out * gate) @ params["wo"].astype(jnp.float32)).astype(x.dtype)
    return y, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM.
# ---------------------------------------------------------------------------

def init_slstm(key, cfg) -> dict:
    dt = dtype_of(cfg.dtype)
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 3)
    s = d ** -0.5
    return {
        # 4 gates (i, f, z, o), input part: [D, 4·H·hd]
        "w_gates": (jax.random.normal(ks[0], (d, 4 * h * hd)) * s
                    ).astype(dt),
        # recurrent part, block-diagonal per head: [4, H, hd, hd]
        "r_gates": (jax.random.normal(ks[1], (4, h, hd, hd)) * hd ** -0.5
                    ).astype(jnp.float32),
        "wo": (jax.random.normal(ks[2], (h * hd, d))
               * (h * hd) ** -0.5).astype(dt),
    }


def _slstm_step(params, carry, wx_t):
    """carry: (c, n, h, m) each [B, H, hd]; wx_t [B, 4, H, hd]."""
    c, n, hprev, m = carry
    rec = jnp.einsum("ghde,bhd->bghe", params["r_gates"], hprev)
    pre = wx_t + rec                                      # [B,4,H,hd]
    i_t, f_t, z_t, o_t = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    m_new = jnp.maximum(jax.nn.log_sigmoid(f_t) + m, i_t)
    i_s = jnp.exp(i_t - m_new)
    f_s = jnp.exp(jax.nn.log_sigmoid(f_t) + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(z_t)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def slstm_forward(cfg, params, x: jax.Array, return_state: bool = False):
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.hd
    wx = (x.astype(jnp.float32) @ params["w_gates"].astype(jnp.float32)
          ).reshape(b, t, 4, h, hd)
    carry0 = tuple(jnp.zeros((b, h, hd), jnp.float32) for _ in range(4))

    def step(carry, wx_t):
        new = _slstm_step(params, carry, wx_t)
        return new, new[2]

    carry, hs = jax.lax.scan(step, carry0, wx.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1).reshape(b, t, h * hd)
    y = (hs @ params["wo"].astype(jnp.float32)).astype(x.dtype)
    if return_state:
        c, n, hh, m = carry
        return y, {"c": c, "n": n, "h": hh, "m": m}
    return y


def init_slstm_state(cfg, batch: int) -> dict:
    h, hd = cfg.n_heads, cfg.hd
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def slstm_decode(cfg, params, x: jax.Array, state: dict
                 ) -> tuple[jax.Array, dict]:
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.hd
    wx = (x.astype(jnp.float32) @ params["w_gates"].astype(jnp.float32)
          ).reshape(b, 4, h, hd)
    carry = (state["c"], state["n"], state["h"], state["m"])
    c, n, hh, m = _slstm_step(params, carry, wx)
    y = (hh.reshape(b, 1, h * hd) @ params["wo"].astype(jnp.float32)
         ).astype(x.dtype)
    return y, {"c": c, "n": n, "h": hh, "m": m}
