"""Model assembly: block kinds, scan-over-units, train/decode paths.

An architecture is a repeating **unit** of block kinds (configs/base.py):
homogeneous archs have ``unit=("dense",)``; xlstm alternates
``("mlstm","slstm")``; recurrentgemma is ``("rec","rec","attn_local")``
with an exact ``("rec","rec")`` tail (26 = 8·3 + 2).  Parameters for the
repeated units are **stacked** (leading U axis) and the forward pass is a
``jax.lax.scan`` over units — keeping the lowered HLO one-unit sized, which
matters for 512-device dry-run compiles and is how production JAX LM
frameworks (MaxText et al.) scale layer count.

Block kinds:
  dense       pre-norm GQA attention + SwiGLU MLP
  mla         multi-head latent attention + MLP        (minicpm3)
  moe         GQA attention + top-k expert MLP          (arctic, mixtral)
  mlstm/slstm xLSTM cells (no MLP; d_ff = 0)
  rec         RG-LRU recurrent block + MLP              (recurrentgemma)
  attn_local  sliding-window GQA + MLP                  (recurrentgemma)
  enc         bidirectional attention + MLP             (whisper encoder)
  dec_cross   causal self-attn + cross-attn + MLP       (whisper decoder)

Decode carries a per-unit cache PyTree (leading U axis) through the same
scan.  Recurrent kinds store O(1)-per-token state — the decode-as-delta
framing of DESIGN.md §5: each step is a one-delta stratum applied to the
mutable state under immutable weights.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import rglru, ssm
from repro.models.layers import (apply_mlp, apply_norm, dtype_of, init_mlp,
                                 init_norm)
from repro.models.moe import init_moe, moe_ffn


# ---------------------------------------------------------------------------
# Block init / apply / cache, by kind.
# ---------------------------------------------------------------------------

def init_block(kind: str, cfg, key) -> dict:
    dt = dtype_of(cfg.dtype)
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"ln1": init_norm(cfg.norm_kind, d)}
    if kind in ("dense", "moe", "attn_local", "enc", "dec_cross"):
        p["attn"] = attn.init_gqa(k1, cfg)
    elif kind == "mla":
        p["attn"] = attn.init_mla(k1, cfg)
    elif kind == "mlstm":
        p["cell"] = ssm.init_mlstm(k1, cfg)
    elif kind == "slstm":
        p["cell"] = ssm.init_slstm(k1, cfg)
    elif kind == "rec":
        p["cell"] = rglru.init_rglru(k1, cfg)
    else:
        raise ValueError(kind)
    if kind == "dec_cross":
        p["ln_cross"] = init_norm(cfg.norm_kind, d)
        p["cross"] = attn.init_cross(k2, cfg)
    if kind == "moe":
        p["ln2"] = init_norm(cfg.norm_kind, d)
        p["ffn"] = init_moe(k3, cfg)
    elif kind in ("dense", "mla", "rec", "attn_local", "enc", "dec_cross"):
        if cfg.d_ff:
            p["ln2"] = init_norm(cfg.norm_kind, d)
            p["mlp"] = init_mlp(k3, d, cfg.d_ff, dt)
    return p


def apply_block(kind: str, cfg, p: dict, x: jax.Array,
                positions: jax.Array, enc_out: Optional[jax.Array] = None,
                moe_strategy: str = "sort", use_kernel: bool = False
                ) -> tuple[jax.Array, jax.Array]:
    """Returns (x', aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg.norm_kind, p["ln1"], x)
    if kind in ("dense", "moe", "attn_local"):
        # attn_local relies on cfg.window; dense archs have window == 0.
        x = x + attn.gqa_train(cfg, p["attn"], h, positions, causal=True,
                               use_kernel=use_kernel)
    elif kind == "enc":
        x = x + attn.gqa_train(cfg, p["attn"], h, positions, causal=False,
                               use_kernel=use_kernel)
    elif kind == "dec_cross":
        x = x + attn.gqa_train(cfg, p["attn"], h, positions, causal=True,
                               use_kernel=use_kernel)
        hc = apply_norm(cfg.norm_kind, p["ln_cross"], x)
        enc_kv = attn.encode_cross_kv(cfg, p["cross"], enc_out)
        x = x + attn.cross_attend(cfg, p["cross"], hc, enc_kv)
    elif kind == "mla":
        x = x + attn.mla_train(cfg, p["attn"], h, positions, causal=True)
    elif kind == "mlstm":
        x = x + ssm.mlstm_forward(cfg, p["cell"], h)
    elif kind == "slstm":
        x = x + ssm.slstm_forward(cfg, p["cell"], h)
    elif kind == "rec":
        x = x + rglru.rglru_forward(cfg, p["cell"], h)
    else:
        raise ValueError(kind)
    if kind == "moe":
        h2 = apply_norm(cfg.norm_kind, p["ln2"], x)
        y, aux = moe_ffn(cfg, p["ffn"], h2, strategy=moe_strategy)
        x = x + y
    elif "mlp" in p:
        h2 = apply_norm(cfg.norm_kind, p["ln2"], x)
        x = x + apply_mlp(p["mlp"], h2)
    return x, aux


def init_block_cache(kind: str, cfg, batch: int, max_len: int, dtype):
    if kind in ("dense", "moe", "attn_local", "dec_cross"):
        c = {"attn": attn.init_gqa_cache(cfg, batch, max_len, dtype)}
        if kind == "dec_cross":
            hd = cfg.hd
            c["cross_kv"] = (
                jnp.zeros((batch, cfg.n_kv_heads, cfg.encoder_seq, hd),
                          dtype),
                jnp.zeros((batch, cfg.n_kv_heads, cfg.encoder_seq, hd),
                          dtype))
        return c
    if kind == "mla":
        return {"attn": attn.init_mla_cache(cfg, batch, max_len, dtype)}
    if kind == "mlstm":
        return {"cell": ssm.init_mlstm_state(cfg, batch)}
    if kind == "slstm":
        return {"cell": ssm.init_slstm_state(cfg, batch)}
    if kind == "rec":
        return {"cell": rglru.init_rglru_state(cfg, batch)}
    raise ValueError(kind)


def decode_block(kind: str, cfg, p: dict, x: jax.Array, cache: dict,
                 pos: jax.Array, flash: bool = False
                 ) -> tuple[jax.Array, dict]:
    h = apply_norm(cfg.norm_kind, p["ln1"], x)
    new_cache = dict(cache)
    if kind in ("dense", "moe", "attn_local", "dec_cross"):
        y, new_cache["attn"] = attn.gqa_decode(cfg, p["attn"], h,
                                               cache["attn"], pos,
                                               flash=flash)
        x = x + y
        if kind == "dec_cross":
            hc = apply_norm(cfg.norm_kind, p["ln_cross"], x)
            x = x + attn.cross_attend(cfg, p["cross"], hc,
                                      cache["cross_kv"])
    elif kind == "mla":
        y, new_cache["attn"] = attn.mla_decode(cfg, p["attn"], h,
                                               cache["attn"], pos)
        x = x + y
    elif kind == "mlstm":
        y, new_cache["cell"] = ssm.mlstm_decode(cfg, p["cell"], h,
                                                cache["cell"])
        x = x + y
    elif kind == "slstm":
        y, new_cache["cell"] = ssm.slstm_decode(cfg, p["cell"], h,
                                                cache["cell"])
        x = x + y
    elif kind == "rec":
        y, new_cache["cell"] = rglru.rglru_decode(cfg, p["cell"], h,
                                                  cache["cell"])
        x = x + y
    else:
        raise ValueError(kind)
    if kind == "moe":
        h2 = apply_norm(cfg.norm_kind, p["ln2"], x)
        y, _ = moe_ffn(cfg, p["ffn"], h2)
        x = x + y
    elif "mlp" in p:
        h2 = apply_norm(cfg.norm_kind, p["ln2"], x)
        x = x + apply_mlp(p["mlp"], h2)
    return x, new_cache


# ---------------------------------------------------------------------------
# Whole-model init.
# ---------------------------------------------------------------------------

def init_params(cfg, key) -> dict:
    dt = dtype_of(cfg.dtype)
    keys = jax.random.split(key, 8)
    params = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dt),
        "final_norm": init_norm(cfg.norm_kind, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab)) * cfg.d_model ** -0.5
            ).astype(dt)

    def unit_params(key):
        ks = jax.random.split(key, len(cfg.unit))
        return {f"b{i}_{kind}": init_block(kind, cfg, ks[i])
                for i, kind in enumerate(cfg.unit)}

    unit_keys = jax.random.split(keys[2], cfg.n_units)
    params["units"] = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[unit_params(k) for k in unit_keys]) if cfg.n_units > 1 else \
        jax.tree.map(lambda x: x[None], unit_params(unit_keys[0]))

    if cfg.tail:
        tks = jax.random.split(keys[3], len(cfg.tail))
        params["tail"] = {f"t{i}_{kind}": init_block(kind, cfg, tks[i])
                          for i, kind in enumerate(cfg.tail)}

    if cfg.encoder_layers:
        eks = jax.random.split(keys[4], cfg.encoder_layers)
        params["enc_units"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[{"b0_enc": init_block("enc", cfg, k)} for k in eks]) \
            if cfg.encoder_layers > 1 else jax.tree.map(
            lambda x: x[None], {"b0_enc": init_block("enc", cfg, eks[0])})
        params["enc_norm"] = init_norm(cfg.norm_kind, cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill).
# ---------------------------------------------------------------------------

def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    """Fixed sin/cos position encoding (whisper-style, table-free)."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10_000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _default_positions(cfg, b, t):
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    return pos


def encode(cfg, params, frames: jax.Array, unroll: bool = False
           ) -> jax.Array:
    """Whisper encoder over precomputed frame embeddings [B, S, D]."""
    b, s, d = frames.shape
    pos = _default_positions(cfg, b, s)
    x = frames + _sinusoid(pos, d).astype(frames.dtype)

    def body(x, unit_p):
        y, _ = apply_block("enc", cfg, unit_p["b0_enc"], x, pos)
        return y, None

    if unroll:
        for u in range(cfg.encoder_layers):
            x, _ = body(x, jax.tree.map(lambda p: p[u],
                                        params["enc_units"]))
    else:
        x, _ = jax.lax.scan(body, x, params["enc_units"])
    return apply_norm(cfg.norm_kind, params["enc_norm"], x)


def forward(cfg, params, tokens: jax.Array,
            positions: Optional[jax.Array] = None,
            enc_out: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            moe_strategy: str = "sort", use_kernel: bool = False,
            unroll: bool = False, gather_fn=None
            ) -> tuple[jax.Array, jax.Array]:
    """tokens int32[B, T] (or ``embeds`` [B, T, D] for stub frontends).

    ``gather_fn(subtree, hint)`` is the ZeRO-3 hook: params are *stored*
    2D-sharded (FSDP×TP) and re-constrained to TP-only at point of use —
    per unit, inside the scan body, so only one layer's weights are ever
    resident gathered.  GSPMD then emits per-layer weight all-gathers and
    reduce-scatters gradients back to the storage sharding, instead of
    partial-matmul + activation-sized all-reduces (perf log iteration 2).

    Returns (logits f32[B, T, V], aux_loss scalar)."""
    gf = gather_fn or (lambda sub, hint: sub)
    embed_w = gf(params["embed"], "embed")
    if embeds is None:
        x = embed_w[tokens]
    else:
        x = embeds.astype(embed_w.dtype)
    b, t, d = x.shape
    if positions is None:
        positions = _default_positions(cfg, b, t)
    if cfg.rope_kind == "none":
        x = x + _sinusoid(
            positions if positions.ndim == 2 else positions[0], d
            ).astype(x.dtype)

    block = functools.partial(apply_block, cfg=cfg, positions=positions,
                              enc_out=enc_out, moe_strategy=moe_strategy,
                              use_kernel=use_kernel)

    def unit_body(carry, unit_p):
        x, aux = carry
        unit_p = gf(unit_p, "unit")
        for i, kind in enumerate(cfg.unit):
            x, a = block(kind, p=unit_p[f"b{i}_{kind}"], x=x)
            aux = aux + a
        return (x, aux), None

    if cfg.remat:
        unit_body = jax.checkpoint(unit_body)
    carry = (x, jnp.zeros((), jnp.float32))
    if unroll:
        # Unrolled layer loop: XLA's cost analysis counts while-loop bodies
        # ONCE (trip count is dynamic), so roofline lowering unrolls to get
        # exact whole-program FLOPs/bytes/collectives.  Training still uses
        # the scan (small HLO, fast compiles).
        for u in range(cfg.n_units):
            unit_p = jax.tree.map(lambda p: p[u], params["units"])
            carry, _ = unit_body(carry, unit_p)
    else:
        carry, _ = jax.lax.scan(unit_body, carry, params["units"])
    (x, aux) = carry
    if cfg.tail:
        tail_p = gf(params["tail"], "unit")
        for i, kind in enumerate(cfg.tail):
            x, a = block(kind, p=tail_p[f"t{i}_{kind}"], x=x)
            aux = aux + a

    x = apply_norm(cfg.norm_kind, params["final_norm"], x)
    head = (embed_w.T if cfg.tie_embeddings
            else gf(params["lm_head"], "lm_head"))
    logits = (x @ head).astype(jnp.float32)
    return logits, aux


# ---------------------------------------------------------------------------
# Prefill: full-sequence forward that also materializes the decode cache.
# ---------------------------------------------------------------------------

def prefill_block(kind: str, cfg, p: dict, x: jax.Array,
                  positions: jax.Array, max_len: int,
                  enc_out: Optional[jax.Array] = None,
                  unroll: bool = False, moe_strategy: str = "sort"
                  ) -> tuple[jax.Array, dict]:
    h = apply_norm(cfg.norm_kind, p["ln1"], x)
    cache = {}
    if kind in ("dense", "moe", "attn_local", "dec_cross"):
        y, cache["attn"] = attn.gqa_prefill(cfg, p["attn"], h, positions,
                                            max_len, unroll=unroll)
        x = x + y
        if kind == "dec_cross":
            hc = apply_norm(cfg.norm_kind, p["ln_cross"], x)
            enc_kv = attn.encode_cross_kv(cfg, p["cross"], enc_out)
            cache["cross_kv"] = enc_kv
            x = x + attn.cross_attend(cfg, p["cross"], hc, enc_kv)
    elif kind == "mla":
        y, cache["attn"] = attn.mla_prefill(cfg, p["attn"], h, positions,
                                            max_len, unroll=unroll)
        x = x + y
    elif kind == "mlstm":
        y, cache["cell"] = ssm.mlstm_forward(cfg, p["cell"], h,
                                             return_state=True)
        x = x + y
    elif kind == "slstm":
        y, cache["cell"] = ssm.slstm_forward(cfg, p["cell"], h,
                                             return_state=True)
        x = x + y
    elif kind == "rec":
        y, cache["cell"] = rglru.rglru_forward(cfg, p["cell"], h,
                                               return_state=True)
        x = x + y
    else:
        raise ValueError(kind)
    if kind == "moe":
        h2 = apply_norm(cfg.norm_kind, p["ln2"], x)
        y, _ = moe_ffn(cfg, p["ffn"], h2, strategy=moe_strategy)
        x = x + y
    elif "mlp" in p:
        h2 = apply_norm(cfg.norm_kind, p["ln2"], x)
        x = x + apply_mlp(p["mlp"], h2)
    return x, cache


def prefill_forward(cfg, params, tokens: jax.Array, max_len: int,
                    enc_out: Optional[jax.Array] = None,
                    embeds: Optional[jax.Array] = None,
                    unroll: bool = False, gather_fn=None,
                    moe_strategy: str = "sort") -> tuple[jax.Array, dict]:
    """Returns (last-position logits [B, 1, V], cache) — the prefill_32k
    cell lowers this: full-sequence compute, cache materialization, and
    only the next-token logits leave the device."""
    gf = gather_fn or (lambda sub, hint: sub)
    embed_w = gf(params["embed"], "embed")
    if embeds is None:
        x = embed_w[tokens]
    else:
        x = embeds.astype(embed_w.dtype)
    b, t, d = x.shape
    positions = _default_positions(cfg, b, t)
    if cfg.rope_kind == "none":
        x = x + _sinusoid(positions, d).astype(x.dtype)

    def unit_body(x, unit_p):
        unit_p = gf(unit_p, "unit")
        cache = {}
        for i, kind in enumerate(cfg.unit):
            name = f"b{i}_{kind}"
            x, cache[name] = prefill_block(kind, cfg, unit_p[name], x,
                                           positions, max_len, enc_out,
                                           unroll=unroll,
                                           moe_strategy=moe_strategy)
        return x, cache

    if unroll:
        caches = []
        for u in range(cfg.n_units):
            x, c = unit_body(x, jax.tree.map(lambda p: p[u],
                                             params["units"]))
            caches.append(c)
        unit_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    else:
        x, unit_caches = jax.lax.scan(unit_body, x, params["units"])
    cache = {"units": unit_caches}
    if cfg.tail:
        cache["tail"] = {}
        for i, kind in enumerate(cfg.tail):
            name = f"t{i}_{kind}"
            x, cache["tail"][name] = prefill_block(
                kind, cfg, params["tail"][name], x, positions, max_len,
                enc_out, unroll=unroll, moe_strategy=moe_strategy)
    x = apply_norm(cfg.norm_kind, params["final_norm"], x[:, -1:])
    head = (embed_w.T if cfg.tie_embeddings
            else gf(params["lm_head"], "lm_head"))
    return (x @ head).astype(jnp.float32), cache


# ---------------------------------------------------------------------------
# Decode (one token against a cache).
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int) -> dict:
    dt = dtype_of(cfg.dtype)

    def unit_cache():
        return {f"b{i}_{kind}": init_block_cache(kind, cfg, batch, max_len,
                                                 dt)
                for i, kind in enumerate(cfg.unit)}

    cache = {"units": jax.tree.map(
        lambda *xs: jnp.stack(xs), *[unit_cache()
                                     for _ in range(cfg.n_units)])
        if cfg.n_units > 1 else jax.tree.map(lambda x: x[None],
                                             unit_cache())}
    if cfg.tail:
        cache["tail"] = {f"t{i}_{kind}": init_block_cache(
            kind, cfg, batch, max_len, dt)
            for i, kind in enumerate(cfg.tail)}
    return cache


def decode_step(cfg, params, token: jax.Array, cache: dict, pos: jax.Array,
                unroll: bool = False, flash_decode: bool = False
                ) -> tuple[jax.Array, dict]:
    """token int32[B, 1]; pos scalar int32.  Returns (logits [B,1,V], cache')."""
    x = params["embed"][token]
    if cfg.rope_kind == "none":
        posb = jnp.broadcast_to(pos[None, None], token.shape)
        x = x + _sinusoid(posb, cfg.d_model).astype(x.dtype)

    def unit_body(x, scanned):
        unit_p, unit_c = scanned
        new_c = {}
        for i, kind in enumerate(cfg.unit):
            name = f"b{i}_{kind}"
            x, new_c[name] = decode_block(kind, cfg, unit_p[name], x,
                                          unit_c[name], pos, flash_decode)
        return x, new_c

    if unroll:
        new_cs = []
        for u in range(cfg.n_units):
            take = lambda p: jax.tree.map(lambda a: a[u], p)
            x, c = unit_body(x, (take(params["units"]),
                                 take(cache["units"])))
            new_cs.append(c)
        new_unit_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_cs)
    else:
        x, new_unit_caches = jax.lax.scan(
            unit_body, x, (params["units"], cache["units"]))
    new_cache = {"units": new_unit_caches}
    if cfg.tail:
        new_cache["tail"] = {}
        for i, kind in enumerate(cfg.tail):
            name = f"t{i}_{kind}"
            x, new_cache["tail"][name] = decode_block(
                kind, cfg, params["tail"][name], x, cache["tail"][name],
                pos, flash_decode)
    x = apply_norm(cfg.norm_kind, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return (x @ head).astype(jnp.float32), new_cache


def param_count(params) -> int:
    return sum(int(jnp.size(x)) for x in jax.tree.leaves(params))
