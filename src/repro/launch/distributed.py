"""Multi-process launch + distributed resilient driver (real failures).

Everything before this module ran in ONE process: the chaos layer
(PR 7) injects failures by fiat, and recovery is validated against
simulated fault events.  Pregelix's lesson (PAPERS.md) is that runtime
behavior cannot be extrapolated from one box — real process loss, real
timeouts and real latency variance must drive the machinery.  This
module supplies that, in three layers:

**Launch.**  ``spawn_worker``/``Cluster`` bring up N worker processes on
one host (the same subprocess pattern as ``tests/subproc.py``), each
optionally running its own jax runtime:

  * ``jax_mode="off"``   — health/lease/ack protocol only (fast spawn);
  * ``jax_mode="local"`` — a per-worker single-process jax with its own
    virtual CPU devices; stratum acks carry a real device computation;
  * ``jax_mode="distributed"`` — workers call
    ``jax.distributed.initialize`` and form a REAL multi-process jax
    cluster (worker 0 hosts the coordination service): each process
    sees the GLOBAL device list, builds the process-aware
    ``launch.mesh.flat_mesh(devices=...)``, verifies a cross-process
    collective, and reports its local-vs-global shard ownership.  The
    ``--selftest`` CLI drives exactly this bring-up and is the CI
    ``distributed-smoke`` entry point.  (Long-lived distributed-mode
    workers are for failure-free validation: today's jax has no elastic
    collectives — killing one member poisons the whole communicator,
    which is precisely why the chaos path keeps the data plane on the
    coordinator and gives workers isolated runtimes.)

**Failure detection.**  Workers lease their shards and renew by
heartbeating over the ``runtime/health.py`` file channel; the
coordinator's :class:`~repro.runtime.health.HealthMonitor` turns a
missed lease deadline into ``FaultEvent(kind="fail")`` and a
late-but-alive worker into a straggle signal.

**Recovery.**  :class:`DistributedResilientDriver` subclasses the
chaos-hardened :class:`~repro.runtime.recovery.ResilientDriver` and
reuses its queue-driven re-entrant recovery verbatim: a real SIGKILL
lands in ``_recovery_queue`` as the same event an injected failure
produces, worker replacement re-runs ``ReplicaChain.reseed()``, and a
worker that never comes back triggers the elastic rescale path.  Real
per-stratum ack arrival times feed ``MeasuredLatencies`` (and therefore
``SpeculationPolicy``) in place of simulated timings.

Real multi-host entry point::

    REPRO_COORDINATOR=host0:1234 REPRO_NUM_PROCESSES=4 \\
        REPRO_PROCESS_ID=k python your_driver.py
    # then: mesh, my_shards = initialize_from_env()
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import socket
import subprocess
import sys
import tempfile
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.launch.mesh import flat_mesh, local_shards
from repro.runtime.health import (HealthConfig, HealthMonitor, ack_path,
                                  heartbeat_path, read_json, stratum_path,
                                  worker_dir, write_json)
from repro.runtime.recovery import (FaultEvent, FaultSchedule,
                                    ResilientDriver, pack_state,
                                    unpack_state)
from repro.runtime.retry import IO_RETRYABLE, Retrier
from repro.runtime.straggler import StragglerMitigator

_JAX_MODES = ("off", "local", "distributed")


_WORKER_MODULE = "repro.launch._worker"


def _src_root() -> str:
    """Directory that makes ``import repro`` work in a child process
    (``repro`` is a namespace package: no ``__file__``, use the path)."""
    import repro
    return os.path.dirname(os.path.abspath(list(repro.__path__)[0]))


def free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _enable_cpu_gloo() -> None:
    """Cross-process CPU collectives need the gloo backend where the
    config knob exists; older jaxlibs that lack it either default
    correctly or fail loudly at the first collective."""
    import jax
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # noqa: BLE001 — knob absent on this jax
        pass


def initialize_from_env(env=None):
    """Real multi-host bring-up: ``jax.distributed.initialize`` from
    ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID``
    env vars (single-process when unset), then the process-aware global
    flat mesh.  Returns ``(mesh, my_shard_ids)``."""
    import jax
    env = os.environ if env is None else env
    coord = env.get("REPRO_COORDINATOR")
    n = int(env.get("REPRO_NUM_PROCESSES", "1") or 1)
    if coord and n > 1:
        _enable_cpu_gloo()
        jax.distributed.initialize(
            coordinator_address=coord, num_processes=n,
            process_id=int(env.get("REPRO_PROCESS_ID", "0") or 0))
    mesh = flat_mesh(devices=jax.devices())
    return mesh, local_shards(mesh)


# ---------------------------------------------------------------------------
# Coordinator-side cluster handle.  The worker process entry lives in
# the import-light ``launch/_worker.py`` (see its import-discipline
# note); this module is coordinator-only and free to import the full
# runtime stack.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WorkerProc:
    worker_id: int
    popen: subprocess.Popen
    log_path: str
    spawned_t: float

    def alive(self) -> bool:
        return self.popen.poll() is None


class Cluster:
    """Spawn/replace/signal a set of worker subprocesses on one host.

    ``ownership`` maps worker id → leased shard ids (round-robin over
    ``num_shards`` by default).  ``detect`` picks the death-detection
    path the monitor may use: ``"lease"`` (missed heartbeat deadline
    only — the path a real multi-box deployment has) or ``"poll"``
    (also consult ``Popen.poll`` — the fast local path).
    """

    def __init__(self, root: str, num_workers: int, *,
                 num_shards: Optional[int] = None,
                 config: Optional[HealthConfig] = None,
                 jax_mode: str = "off", devices_per_worker: int = 1,
                 detect: str = "lease", env: Optional[dict] = None,
                 retrier: Optional[Retrier] = None, tracer=None,
                 metrics=None):
        if jax_mode not in _JAX_MODES:
            raise ValueError(f"jax_mode must be one of {_JAX_MODES}, "
                             f"got {jax_mode!r}")
        if detect not in ("lease", "poll"):
            raise ValueError(f"detect must be 'lease' or 'poll', "
                             f"got {detect!r}")
        self.root = root
        self.num_workers = int(num_workers)
        self.num_shards = int(num_shards or num_workers)
        self.config = config or HealthConfig()
        self.jax_mode = jax_mode
        self.devices_per_worker = int(devices_per_worker)
        self.detect = detect
        self.extra_env = dict(env or {})
        self.retrier = retrier or Retrier()
        self.tracer = tracer
        self.metrics = metrics
        self.procs: Dict[int, WorkerProc] = {}
        self.ownership: Dict[int, List[int]] = {
            w: [s for s in range(self.num_shards)
                if s % self.num_workers == w]
            for w in range(self.num_workers)}
        self.retired: Dict[int, Optional[int]] = {}
        self.kill_times: Dict[int, float] = {}
        self._cmd_seq = 0
        self._bseq = 0
        self._timers: List[threading.Timer] = []
        os.makedirs(root, exist_ok=True)

    # ---- spawn / lifecycle ----------------------------------------------
    def _spawn(self, wid: int) -> WorkerProc:
        wdir = worker_dir(self.root, wid)
        os.makedirs(wdir, exist_ok=True)
        log_path = os.path.join(wdir, "log.txt")
        env = dict(os.environ)
        env["PYTHONPATH"] = _src_root() + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH")
            else "")
        if self.jax_mode != "off":
            env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count"
                                f"={self.devices_per_worker}")
        env.update(self.extra_env)
        cmd = [sys.executable, "-m", _WORKER_MODULE,
               "--id", str(wid), "--root", self.root,
               "--hb-interval", str(self.config.heartbeat_interval),
               "--jax", self.jax_mode]
        log = open(log_path, "ab")
        try:
            popen = subprocess.Popen(cmd, env=env, stdout=log,
                                     stderr=subprocess.STDOUT)
        finally:
            log.close()
        proc = WorkerProc(wid, popen, log_path, time.monotonic())
        self.procs[wid] = proc
        if self.tracer is not None:
            self.tracer.instant("worker_spawned", tid=f"worker{wid}",
                                worker=wid, pid=popen.pid)
        if self.metrics is not None:
            self.metrics.counter("health.workers_spawned").inc()
        return proc

    def start(self) -> None:
        for w in range(self.num_workers):
            self._spawn(w)
        self.wait_ready(list(range(self.num_workers)))
        self._push_assignments()

    def wait_ready(self, worker_ids: List[int],
                   timeout: Optional[float] = None) -> None:
        deadline = time.monotonic() + (
            timeout if timeout is not None else self.config.ready_timeout)
        pending = set(worker_ids)
        while pending:
            for w in sorted(pending):
                hb = self.retrier.call(
                    read_json, heartbeat_path(self.root, w),
                    op=f"ready:{w}", retryable=IO_RETRYABLE)
                if hb is not None:
                    pending.discard(w)
                    continue
                proc = self.procs.get(w)
                if proc is not None and not proc.alive():
                    raise RuntimeError(
                        f"worker {w} exited rc={proc.popen.returncode} "
                        f"before its first heartbeat — log tail:\n"
                        f"{self.log_tail(w)}")
            if not pending:
                return
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"workers {sorted(pending)} not ready; log tails:\n"
                    + "\n".join(self.log_tail(w) for w in sorted(pending)))
            time.sleep(self.config.poll_interval)

    def log_tail(self, wid: int, n: int = 1500) -> str:
        proc = self.procs.get(wid)
        if proc is None or not os.path.exists(proc.log_path):
            return f"[worker {wid}: no log]"
        with open(proc.log_path, "rb") as f:
            data = f.read()[-n:]
        return f"[worker {wid}] " + data.decode(errors="replace")

    def shutdown(self) -> None:
        for t in self._timers:
            t.cancel()
        for wid, proc in self.procs.items():
            if not proc.alive():
                continue
            try:                       # a paused worker can't read cmds
                os.kill(proc.popen.pid, signal.SIGCONT)
            except OSError:
                pass
            self._cmd(wid, {"kind": "shutdown"})
        deadline = time.monotonic() + 2.0
        for proc in self.procs.values():
            try:
                proc.popen.wait(timeout=max(deadline - time.monotonic(),
                                            0.05))
            except subprocess.TimeoutExpired:
                proc.popen.kill()
                proc.popen.wait(timeout=10)

    # ---- channel writes --------------------------------------------------
    def _cmd(self, wid: int, payload: dict) -> None:
        self._cmd_seq += 1
        write_json(os.path.join(worker_dir(self.root, wid), "cmd.json"),
                   {**payload, "seq": self._cmd_seq})

    def _push_assignments(self) -> None:
        for wid, shards in self.ownership.items():
            self._cmd(wid, {"kind": "assign", "shards": list(shards)})

    def broadcast_stratum(self, stratum: int) -> tuple[int, float]:
        """Publish the stratum task; returns ``(broadcast_seq, t0)`` —
        ack walls are measured against ``t0``."""
        self._bseq += 1
        t0 = time.monotonic()
        write_json(stratum_path(self.root),
                   {"seq": self._bseq, "stratum": int(stratum), "t": t0})
        return self._bseq, t0

    def collect_acks(self, bseq: int, t0: float,
                     timeout: Optional[float] = None
                     ) -> Dict[int, Optional[float]]:
        """Wait (bounded) for each live worker's ack to broadcast
        ``bseq``; returns worker → measured ack wall seconds (``None``
        = missed the deadline — dead, paused, or straggling past it)."""
        # Deadline counts from the BROADCAST; the stratum compute between
        # broadcast and collection may exceed it (first-stratum compile),
        # so always run at least one read pass — acks already on disk
        # must never be misread as timeouts.
        deadline = t0 + (timeout if timeout is not None
                         else self.config.ack_timeout)
        waiting = {w for w in self.ownership
                   if w not in self.retired and self.ownership.get(w)}
        walls: Dict[int, Optional[float]] = {}
        while True:
            for w in sorted(waiting):
                ack = self.retrier.call(
                    read_json, ack_path(self.root, w, bseq),
                    op=f"ack:{w}", shard=(self.ownership[w] or [0])[0],
                    retryable=IO_RETRYABLE)
                if ack is not None:
                    walls[w] = max(ack["t"] - t0, 0.0)
                elif self.detect == "poll" and w in self.procs \
                        and not self.procs[w].alive():
                    walls[w] = None       # observably dead: stop waiting
            waiting -= set(walls)
            if not waiting or time.monotonic() >= deadline:
                break
            time.sleep(self.config.poll_interval)
        for w in waiting:
            walls[w] = None
        return walls

    # ---- ownership / signals --------------------------------------------
    def worker_of(self, shard: int) -> int:
        for w, shards in self.ownership.items():
            if shard in shards:
                return w
        raise KeyError(f"shard {shard} is leased by no worker "
                       f"(ownership: {self.ownership})")

    def proc_alive(self, wid: int) -> Optional[bool]:
        """Fast-path liveness for the HealthMonitor; ``None`` in lease
        mode (deadline-only detection, the multi-box-faithful path)."""
        if self.detect != "poll":
            return None
        proc = self.procs.get(wid)
        return proc.alive() if proc is not None else False

    def kill(self, wid: int) -> None:
        """REAL failure: SIGKILL the worker and wait for the process to
        be gone (the kill is then strictly before the next barrier)."""
        proc = self.procs[wid]
        self.kill_times[wid] = time.monotonic()
        if proc.alive():
            proc.popen.kill()
        try:
            proc.popen.wait(timeout=10)
        except subprocess.TimeoutExpired:
            pass
        if self.tracer is not None:
            self.tracer.instant("worker_killed", tid=f"worker{wid}",
                                worker=wid)

    def pause(self, wid: int, duration: float) -> None:
        """REAL straggler: SIGSTOP now, SIGCONT after ``duration`` —
        the worker misses heartbeats/acks but its lease survives."""
        proc = self.procs[wid]
        if not proc.alive():
            return
        os.kill(proc.popen.pid, signal.SIGSTOP)
        if self.tracer is not None:
            self.tracer.instant("worker_paused", tid=f"worker{wid}",
                                worker=wid, duration_s=duration)

        def _resume(pid=proc.popen.pid):
            try:
                os.kill(pid, signal.SIGCONT)
            except OSError:
                pass
        t = threading.Timer(duration, _resume)
        t.daemon = True
        t.start()
        self._timers.append(t)

    def retire(self, wid: int,
               new_num_shards: Optional[int] = None) -> None:
        """REAL permanent loss: kill with replacement disabled — the
        driver's elastic rescale absorbs the missing worker."""
        self.retired[wid] = new_num_shards
        self.kill(wid)

    def replace(self, wid: int) -> None:
        """Replacement node: fresh process under the same worker id,
        taking over the dead worker's lease (its channel dir is wiped —
        a stale heartbeat must not revive the old lease)."""
        old = self.procs.get(wid)
        if old is not None and old.alive():
            old.popen.kill()
            old.popen.wait(timeout=10)
        wdir = worker_dir(self.root, wid)
        for name in ("heartbeat.json", "ready.json", "cmd.json"):
            try:
                os.unlink(os.path.join(wdir, name))
            except OSError:
                pass
        self._spawn(wid)
        self.wait_ready([wid])
        self._cmd(wid, {"kind": "assign",
                        "shards": list(self.ownership.get(wid, []))})
        self.kill_times.pop(wid, None)

    def reassign(self, num_shards: int) -> Dict[int, List[int]]:
        """Round-robin ``num_shards`` shards over the surviving workers
        (elastic rescale): retired/dead workers lease nothing."""
        alive = [w for w in sorted(self.ownership)
                 if w not in self.retired
                 and (w in self.procs and self.procs[w].alive())]
        if not alive:
            raise RuntimeError("no live workers left to lease shards")
        self.num_shards = int(num_shards)
        new = {w: [] for w in self.ownership}
        for s in range(num_shards):
            new[alive[s % len(alive)]].append(s)
        self.ownership = new
        self._push_assignments()
        return new


# ---------------------------------------------------------------------------
# The distributed resilient driver.
# ---------------------------------------------------------------------------

class DistributedResilientDriver(ResilientDriver):
    """ResilientDriver whose failure signals are REAL.

    The data plane (stratum compute, replica chain, recovery) is the
    parent class verbatim; this subclass adds the control plane:

      * every punctuation barrier broadcasts a stratum task to the
        workers and measures real per-worker ack arrival walls, which
        REPLACE the simulated per-shard latencies in
        ``MeasuredLatencies`` (the SpeculationPolicy feed);
      * the :class:`HealthMonitor` is polled at every barrier; a missed
        lease deadline wipes the dead node's replica-chain disk and
        pushes its shards through ``_recover`` — the SAME queue-driven
        path an injected ``FaultSchedule`` failure takes — then a
        replacement worker is spawned and ``ReplicaChain.reseed`` heals
        the ring;
      * a worker marked ``retired`` (it never comes back) triggers the
        elastic rescale path instead, with leases re-granted round-robin
        over the survivors;
      * ``chaos_hook(driver)`` (optional) runs first at each barrier —
        the real chaos executor uses it to deliver SIGKILL/SIGSTOP on
        schedule.
    """

    def __init__(self, executor, algo, state0, live0, immutable,
                 max_iters: int, mode: str = "delta",
                 explicit_cond: Optional[Callable] = None, *,
                 ckpt_root: str, cluster: Cluster,
                 strategy: str = "incremental", respawn: bool = True,
                 chaos_hook: Optional[Callable] = None,
                 policy=None, latency_model=None, remake=None,
                 pack: Callable = pack_state,
                 unpack: Callable = unpack_state,
                 retry=None, budget=None, tracer=None, metrics=None):
        super().__init__(
            executor, algo, state0, live0, immutable, max_iters,
            mode=mode, explicit_cond=explicit_cond, ckpt_root=ckpt_root,
            fault_plan=FaultSchedule(strategy=strategy), policy=policy,
            latency_model=latency_model, remake=remake, pack=pack,
            unpack=unpack, retry=retry, budget=budget, tracer=tracer,
            metrics=metrics)
        self.cluster = cluster
        self.respawn = respawn
        self.chaos_hook = chaos_hook
        # Real runs always carry a mitigator: stragglers are not
        # scheduled, they happen.
        if self.mitigator is None:
            self.mitigator = StragglerMitigator(
                self.snapshot.num_shards, self.policy,
                replicas_of=self.snapshot.replicas_of)
        self.monitor = HealthMonitor(
            cluster.root, cluster.ownership, cluster.config,
            retrier=self.retrier, proc_alive=cluster.proc_alive,
            tracer=self.tracer, metrics=self.metrics)
        self.detections: List[dict] = []
        self.ack_timeouts = 0
        self.acks_collected = 0

    # ---- real failure signals -------------------------------------------
    def _external_events(self) -> bool:
        if self.chaos_hook is not None:
            self.chaos_hook(self)
        report = self.monitor.observe(stratum=self.stratum)
        for shard, age in report.straggles:
            self.mitigator.note_timeout(shard)
            self._event({"event": "worker_straggle",
                         "stratum": self.stratum, "shard": shard,
                         "age_s": age})
        if not report.dead_workers:
            return False
        now = time.monotonic()
        for w in report.dead_workers:
            kt = self.cluster.kill_times.get(w)
            det = (now - kt) if kt is not None else None
            self.detections.append({"worker": w, "stratum": self.stratum,
                                    "detection_s": det})
            self._event({"event": "worker_dead", "worker": w,
                         "stratum": self.stratum, "detection_s": det,
                         "shards": list(
                             self.cluster.ownership.get(w, []))})
        replaceable = [w for w in report.dead_workers
                       if self.respawn and w not in self.cluster.retired]
        gone = [w for w in report.dead_workers if w not in replaceable]
        restarted = False
        if replaceable:
            restarted = self._handle_replaceable(replaceable)
        if gone:
            self._handle_gone(gone)
        return restarted

    def _handle_replaceable(self, workers: List[int]) -> bool:
        """Real process loss → the injected-failure path verbatim: wipe
        the dead nodes' disks, respawn replacements, drain the recovery
        queue (which reseeds the replica ring), or restart under the
        restart strategy."""
        dead_shards = sorted({s for w in workers
                              for s in self.cluster.ownership.get(w, [])})
        for s in dead_shards:
            self.chain.wipe(s)
        self._event({"event": "failure", "stratum": self.stratum,
                     "shard": dead_shards[0] if dead_shards else -1,
                     "correlated": len(workers) > 1, "during": "real",
                     "strategy": self.schedule.strategy,
                     "shards": dead_shards, "workers": list(workers)})
        for w in workers:
            self.cluster.replace(w)
            self.monitor.reinstate(w)
            self._event({"event": "worker_replaced", "worker": w,
                         "stratum": self.stratum})
        if not dead_shards:
            return False
        if self.schedule.strategy == "restart":
            self._restart()
            return True
        return self._recover(dead_shards)

    def _handle_gone(self, workers: List[int]) -> None:
        """A worker that never comes back → elastic rescale: its disk is
        gone, its lease is not re-granted, and the key space is
        re-partitioned over the survivors."""
        if self.remake is None:
            raise ValueError(
                "a permanently-lost worker needs remake(new_snapshot) "
                "-> (executor, algo, immutable) to rescale around it")
        lost = sorted({s for w in workers
                       for s in self.cluster.ownership.get(w, [])})
        for s in lost:
            self.chain.wipe(s)
        targets = [self.cluster.retired.get(w) for w in workers
                   if self.cluster.retired.get(w)]
        new_k = targets[0] if targets else max(
            self.snapshot.num_shards - len(lost), 1)
        self._event({"event": "worker_gone", "stratum": self.stratum,
                     "workers": list(workers), "shards": lost,
                     "to_shards": new_k})
        for w in workers:
            self.cluster.ownership[w] = []
            self.cluster.retired.setdefault(w, None)
        self._do_rescale(FaultEvent(kind="rescale", at=self.stratum,
                                    new_num_shards=new_k))

    def _do_rescale(self, ev) -> None:
        super()._do_rescale(ev)
        ownership = self.cluster.reassign(self.snapshot.num_shards)
        self.monitor.set_ownership(ownership)

    # ---- real measured latencies ----------------------------------------
    def step(self):
        stratum = self.stratum
        bseq, t0 = self.cluster.broadcast_stratum(stratum)
        outcome = super().step()
        walls = self.cluster.collect_acks(bseq, t0)
        per_shard = list(self.measured.latencies[-1])
        for w, wall in sorted(walls.items()):
            shards = self.cluster.ownership.get(w, [])
            if wall is None:
                self.ack_timeouts += 1
                for s in shards:
                    self.mitigator.note_timeout(s)
                self._event({"event": "ack_timeout", "stratum": stratum,
                             "worker": w})
                continue
            self.acks_collected += 1
            for s in shards:
                if s < len(per_shard):
                    per_shard[s] = wall
            if self.tracer is not None:
                self.tracer.instant("worker_ack", tid=f"worker{w}",
                                    worker=w, stratum=stratum,
                                    wall_s=wall)
            if self.metrics is not None:
                self.metrics.histogram(
                    "health.ack_wall_seconds").observe(wall)
        # Real arrival walls replace the coordinator-side estimate as
        # the stratum's measured per-shard latency (speculation feed).
        self.measured.latencies[-1] = per_shard
        return outcome

    def run(self):
        out = super().run()
        out.metrics["mode"] = "distributed"
        out.metrics["workers"] = self.cluster.num_workers
        out.metrics["worker_detections"] = self.detections
        out.metrics["acks_collected"] = self.acks_collected
        out.metrics["ack_timeouts"] = self.ack_timeouts
        return out


# ---------------------------------------------------------------------------
# Bring-up selftest (the CI distributed-smoke entry point).
# ---------------------------------------------------------------------------

def selftest(num_workers: int = 4, devices_per_worker: int = 2,
             timeout: Optional[float] = None) -> dict:
    """Spawn ``num_workers`` REAL ``jax.distributed`` processes (worker 0
    hosts the coordination service), collect each process's bring-up
    report, and verify the global/local device split, the process-aware
    flat-mesh shard ownership (disjoint, exhaustive), and one
    cross-process collective."""
    root = tempfile.mkdtemp(prefix="repro_dist_selftest_")
    port = free_port()
    env = dict(os.environ)
    env["PYTHONPATH"] = _src_root() + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count"
                        f"={devices_per_worker}")
    procs = []
    for w in range(num_workers):
        wdir = worker_dir(root, w)
        os.makedirs(wdir, exist_ok=True)
        log = open(os.path.join(wdir, "log.txt"), "ab")
        try:
            procs.append(subprocess.Popen(
                [sys.executable, "-m", _WORKER_MODULE,
                 "--oneshot", "--id", str(w), "--root", root,
                 "--jax", "distributed",
                 "--coordinator", f"127.0.0.1:{port}",
                 "--num-processes", str(num_workers),
                 "--process-id", str(w)],
                env=env, stdout=log, stderr=subprocess.STDOUT))
        finally:
            log.close()
    deadline = (timeout if timeout is not None
                else float(os.environ.get("REPRO_SUBPROC_TIMEOUT", "900")))
    failures = []
    for w, p in enumerate(procs):
        try:
            rc = p.wait(timeout=deadline)
        except subprocess.TimeoutExpired:
            p.kill()
            rc = -9
        if rc != 0:
            with open(os.path.join(worker_dir(root, w), "log.txt"),
                      "rb") as f:
                failures.append(f"worker {w} rc={rc}: "
                                + f.read()[-1500:].decode(errors="replace"))
    if failures:
        raise RuntimeError("distributed bring-up failed:\n"
                           + "\n".join(failures))
    reports = []
    for w in range(num_workers):
        rep = read_json(os.path.join(worker_dir(root, w), "ready.json"))
        if rep is None:
            raise RuntimeError(f"worker {w} exited 0 but wrote no "
                               "ready report")
        reports.append(rep)
    total = num_workers * devices_per_worker
    owned: List[int] = []
    for w, rep in enumerate(reports):
        assert rep["process_index"] == w, reports
        assert rep["num_processes"] == num_workers, reports
        assert rep["global_devices"] == total, reports
        assert rep["local_devices"] == devices_per_worker, reports
        assert rep["num_shards"] == total, reports
        assert rep["allgather"] == list(range(num_workers)), reports
        owned.extend(rep["local_shards"])
    assert sorted(owned) == list(range(total)), (
        f"shard ownership must partition the flat mesh, got {owned}")
    return {
        "num_workers": num_workers,
        "devices_per_worker": devices_per_worker,
        "global_devices": total,
        "ownership": {str(w): rep["local_shards"]
                      for w, rep in enumerate(reports)},
        "collective_ok": True,
    }


# ---------------------------------------------------------------------------
# CLI.
# ---------------------------------------------------------------------------

def main(argv: Optional[list] = None) -> int:
    import argparse
    parser = argparse.ArgumentParser(
        description="Multi-process jax.distributed bring-up selftest "
                    "(the CI distributed-smoke entry point).")
    parser.add_argument("--selftest", action="store_true")
    parser.add_argument("--workers", type=int, default=4)
    parser.add_argument("--devices-per-worker", type=int, default=2)
    args = parser.parse_args(argv)
    if args.selftest:
        report = selftest(args.workers, args.devices_per_worker)
        print(json.dumps(report, indent=2))
        return 0
    parser.error("pass --selftest (workers run via "
                 f"python -m {_WORKER_MODULE})")
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
