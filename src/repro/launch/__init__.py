from repro.launch.mesh import (dp_axes, dp_size, flat_mesh, make_mesh,
                               make_production_mesh, model_axis_size)
from repro.launch.sharding import (batch_spec, cache_spec,
                                   cache_tree_specs, param_spec,
                                   to_shardings, tree_specs)

__all__ = ["dp_axes", "dp_size", "flat_mesh", "make_mesh",
           "make_production_mesh", "model_axis_size", "batch_spec",
           "cache_spec", "cache_tree_specs", "param_spec", "to_shardings",
           "tree_specs"]
