"""Production meshes (multi-pod dry-run spec).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod = (16, 16) over ("data", "model") = 256 chips;
multi-pod = (2, 16, 16) over ("pod", "data", "model") = 512 chips.  The
"pod" axis is pure data parallelism across ICI-disjoint pods (gradient
all-reduce crosses DCN); "data" is in-pod DP/FSDP; "model" is TP/EP.

REX analytics shards its key space over the FLATTENED device list (a
partition snapshot has no TP notion) — ``flat_mesh`` provides that view.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, small-scale drivers)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def flat_mesh(num_devices: int | None = None, axis: str = "shards"):
    """1-D mesh over all (or the first N) devices — the REX partition-
    snapshot view for the analytics engine."""
    n = num_devices or len(jax.devices())
    return jax.make_mesh((n,), (axis,),
                         axis_types=(jax.sharding.AxisType.Auto,))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a production mesh (batch sharding)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
