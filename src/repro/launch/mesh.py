"""Production meshes (multi-pod dry-run spec).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod = (16, 16) over ("data", "model") = 256 chips;
multi-pod = (2, 16, 16) over ("pod", "data", "model") = 512 chips.  The
"pod" axis is pure data parallelism across ICI-disjoint pods (gradient
all-reduce crosses DCN); "data" is in-pod DP/FSDP; "model" is TP/EP.

REX analytics shards its key space over the FLATTENED device list (a
partition snapshot has no TP notion) — ``flat_mesh`` provides that view.

Compatibility floor: ``jax.sharding.AxisType`` only exists from jax 0.5.x;
on older jax (0.4.37 ships ``jax.make_mesh`` but no axis types) every mesh
here is built without the ``axis_types`` keyword — the default is Auto
everywhere, which is exactly what these helpers request when the enum
exists, so behaviour is identical on both sides of the floor.
"""
from __future__ import annotations

import jax


def _axis_types_kw(num_axes: int) -> dict:
    """``axis_types=(Auto, ...)`` when this jax has the enum, else {}."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * num_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, small-scale drivers)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_types_kw(len(axes)))


def flat_mesh(num_devices: int | None = None, axis: str = "shards"):
    """1-D mesh over all (or the first N) devices — the REX partition-
    snapshot view for the analytics engine."""
    n = num_devices or len(jax.devices())
    return jax.make_mesh((n,), (axis,), **_axis_types_kw(1))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a production mesh (batch sharding)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
