"""Production meshes (multi-pod dry-run spec).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod = (16, 16) over ("data", "model") = 256 chips;
multi-pod = (2, 16, 16) over ("pod", "data", "model") = 512 chips.  The
"pod" axis is pure data parallelism across ICI-disjoint pods (gradient
all-reduce crosses DCN); "data" is in-pod DP/FSDP; "model" is TP/EP.

REX analytics shards its key space over the FLATTENED device list (a
partition snapshot has no TP notion) — ``flat_mesh`` provides that view.

Compatibility floor: ``jax.sharding.AxisType`` only exists from jax 0.5.x;
on older jax (0.4.37 ships ``jax.make_mesh`` but no axis types) every mesh
here is built without the ``axis_types`` keyword — the default is Auto
everywhere, which is exactly what these helpers request when the enum
exists, so behaviour is identical on both sides of the floor.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

import jax


def _axis_types_kw(num_axes: int) -> dict:
    """``axis_types=(Auto, ...)`` when this jax has the enum, else {}."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * num_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, small-scale drivers)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_types_kw(len(axes)))


def flat_mesh(num_devices: int | None = None, axis: str = "shards", *,
              devices: Optional[Sequence] = None):
    """1-D mesh over an explicit device list, or over all (or the first
    N) devices — the REX partition-snapshot view for the analytics
    engine.

    Under a multi-process (``jax.distributed``) launch, ``jax.devices()``
    is the GLOBAL device list while a worker only owns
    ``jax.local_devices()`` — the legacy ``num_devices``-prefix form
    would silently build a mesh over the first N global devices (all of
    process 0's, typically).  Pass ``devices=`` explicitly in that
    regime; :func:`local_shards` / :func:`shard_process_indices` then
    answer which shards of the flat mesh each process owns.
    """
    if devices is not None:
        devices = list(devices)
        if num_devices is not None and num_devices != len(devices):
            raise ValueError(
                f"flat_mesh: num_devices={num_devices} contradicts the "
                f"explicit device list of length {len(devices)} — pass "
                "one or the other")
        if not devices:
            raise ValueError("flat_mesh: empty device list")
        arr = np.empty(len(devices), dtype=object)
        arr[:] = devices
        try:
            return jax.sharding.Mesh(arr, (axis,), **_axis_types_kw(1))
        except TypeError:      # Mesh() predating the axis_types keyword
            return jax.sharding.Mesh(arr, (axis,))
    n = num_devices or len(jax.devices())
    return jax.make_mesh((n,), (axis,), **_axis_types_kw(1))


# ---------------------------------------------------------------------------
# Process-aware ownership of a flat mesh (multi-process launches).
# ---------------------------------------------------------------------------

def mesh_devices(mesh) -> list:
    """The mesh's devices flattened in mesh order (shard i of a flat
    mesh lives on ``mesh_devices(mesh)[i]``)."""
    return list(np.asarray(mesh.devices, dtype=object).flat)


def shard_process_indices(mesh) -> list[int]:
    """Owning process index per flat-mesh position — the global shard →
    process map a coordinator uses to translate one process's death
    into the shards whose leases just died with it."""
    return [int(d.process_index) for d in mesh_devices(mesh)]


def local_shards(mesh, process_index: int | None = None) -> list[int]:
    """Flat-mesh positions owned by ``process_index`` (default: the
    calling process) — the worker-side view of shard ownership."""
    if process_index is None:
        process_index = jax.process_index()
    return [i for i, p in enumerate(shard_process_indices(mesh))
            if p == process_index]


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a production mesh (batch sharding)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
