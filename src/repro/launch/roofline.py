"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

Per (arch × shape) cell, from the single-pod compiled program:

    compute term    = HLO_FLOPs_global  / (chips × 197e12 FLOP/s)
    memory term     = HLO_bytes_global  / (chips × 819e9  B/s)
    collective term = collective_bytes  / (chips × 50e9   B/s/link)

``cost_analysis`` on an SPMD program reports PER-DEVICE numbers
(calibrated in EXPERIMENTS.md §Method), so global = per-device × chips and
the per-chip terms divide back out: term = per_device / peak.

MODEL_FLOPS (the useful-work yardstick):
    train   : 6·N·D       (dense)  or 6·N_active·D  (MoE)   [+attention]
    prefill : 2·N·D + attention
    decode  : 2·N·B (one token per sequence) + attention-over-cache

The xlstm cells carry an analytic correction for the inner time scans
(XLA counts while bodies once; the sLSTM/mLSTM chunk loops have known
static trip counts — formula in ``xlstm_correction``).
"""
from __future__ import annotations

import argparse
import json
from typing import Optional

from repro.configs import SHAPES, get_arch

PEAK_FLOPS = 197e12        # bf16 FLOP/s per v5e chip
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per link


def model_params(cfg) -> tuple[float, float]:
    """(total_params, active_params) — active counts top-k experts only."""
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    attn = d * h * hd + 2 * d * kv * hd + h * hd * d
    mlp = 3 * d * ff
    per_kind = {
        "dense": attn + mlp, "enc": attn + mlp,
        "attn_local": attn + mlp,
        "dec_cross": 2 * attn + mlp,
        "mla": (d * cfg.mla_q_rank + cfg.mla_q_rank * h * (hd + cfg.mla_rope_dim)
                + d * cfg.mla_kv_rank + 2 * cfg.mla_kv_rank * h * hd
                + d * cfg.mla_rope_dim + h * hd * d + mlp),
        "moe": (attn + cfg.n_experts * mlp
                + (mlp if cfg.moe_dense_residual else 0) + d * cfg.n_experts),
        "mlstm": 3 * d * h * hd + d * 2 * h + d * h * hd + h * hd * d,
        "slstm": d * 4 * h * hd + 4 * h * hd * hd + h * hd * d,
        "rec": (2 * d * cfg.rnn_dim + 2 * cfg.rnn_dim ** 2
                + cfg.rnn_dim * d + mlp),
    }
    total = active = 0.0
    seq = list(cfg.unit) * cfg.n_units + list(cfg.tail)
    for kind in seq:
        total += per_kind[kind]
        if kind == "moe":
            active += (attn + cfg.top_k * mlp
                       + (mlp if cfg.moe_dense_residual else 0)
                       + d * cfg.n_experts)
        else:
            active += per_kind[kind]
    enc = cfg.encoder_layers * per_kind["enc"] if cfg.encoder_layers else 0
    total += enc
    active += enc
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    return total + emb, active + emb


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs (global) for the cell."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    b, t = shape.global_batch, shape.seq_len
    total, active = model_params(cfg)
    d = cfg.d_model
    n_attn = sum(k in ("dense", "moe", "attn_local", "mla", "enc",
                       "dec_cross")
                 for k in list(cfg.unit) * cfg.n_units + list(cfg.tail))
    if shape.kind == "train":
        toks = b * t
        eff_t = min(t, cfg.window) if cfg.window else t
        attn_fl = 3 * 2 * 2 * b * t * eff_t * d * n_attn / 2  # fwd+bwd, causal/2
        return 6.0 * active * toks + attn_fl
    if shape.kind == "prefill":
        toks = b * t
        eff_t = min(t, cfg.window) if cfg.window else t
        attn_fl = 2 * 2 * b * t * eff_t * d * n_attn / 2
        return 2.0 * active * toks + attn_fl
    # decode: one token/sequence; attention reads the whole cache
    eff_s = min(t, cfg.window) if cfg.window else t
    attn_fl = 2 * 2 * b * 1 * eff_s * d * n_attn
    return 2.0 * active * b + attn_fl


def xlstm_correction(arch: str, shape_name: str) -> float:
    """Extra HLO FLOPs hidden in the xLSTM inner time scans (bodies
    counted once; static trip counts known).  Global FLOPs."""
    if arch != "xlstm-350m":
        return 0.0
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        return 0.0                  # decode has no inner scan
    b, t = shape.global_batch, shape.seq_len
    h, hd, ch = cfg.n_heads, cfg.hd, cfg.mlstm_chunk
    n_units = cfg.n_units
    # mLSTM chunk body: intra scores 2·b·ch²·h·hd ×2 (qk, pv) + carry
    # einsums ≈ 2·b·ch·h·hd² ×3; trips = t/ch (body counted once).
    trips_m = t // ch
    body_m = b * (4 * ch * ch * h * hd + 6 * ch * h * hd * hd)
    # sLSTM step: recurrent gates 2·4·h·hd² per token; trips = t.
    body_s = b * 8 * h * hd * hd
    mult = 3.0 if shape.kind == "train" else 1.0   # fwd+bwd(2×) vs fwd
    return mult * n_units * ((trips_m - 1) * body_m + (t - 1) * body_s)


def analyse(cell: dict) -> Optional[dict]:
    if "error" in cell:
        return None
    chips = cell["devices"]
    flops_dev = cell["flops"] + xlstm_correction(
        cell["arch"], cell["shape"]) / chips
    bytes_dev = cell["bytes_accessed"]
    coll_dev = cell["collective_bytes"]["total"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cell["arch"], cell["shape"])
    useful = mf / (flops_dev * chips) if flops_dev > 0 else 0.0
    bound = max(t_compute, t_memory, t_coll)
    # Roofline fraction: useful work over what the dominant term allows.
    step_time = bound
    mfu = mf / (chips * PEAK_FLOPS * step_time) if step_time > 0 else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"], "chips": chips,
        "compute_s": t_compute, "memory_s": t_memory,
        "collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": flops_dev * chips,
        "useful_ratio": useful, "roofline_mfu": mfu,
    }


def what_would_help(row: dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("shrink/overlap collectives: pre-aggregate before "
                "all-reduce, avoid KV re-gather, 2D-shard so gathers move "
                "shards not replicas")
    if d == "memory":
        return ("raise arithmetic intensity: fuse attention (flash), "
                "larger tiles, bf16 residuals, avoid materializing "
                "logits/scores")
    return ("compute-bound (good): push MFU via MXU-aligned tiles, "
            "remat policy tuning, overlap the residual collectives")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("results", help="dryrun_16x16.json")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    cells = json.load(open(args.results))
    rows = [r for r in (analyse(c) for c in cells) if r]
    if args.markdown:
        print("| arch | shape | compute s | memory s | collective s | "
              "dominant | MODEL/HLO | roofline MFU |")
        print("|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} "
                  f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
                  f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
                  f"| {r['roofline_mfu']:.3f} |")
    else:
        for r in rows:
            r["hint"] = what_would_help(r)
            print(json.dumps(r))


if __name__ == "__main__":
    main()
