"""Sharding rules: param/optimizer/cache/batch PartitionSpecs.

Scheme (1000+-node posture, DESIGN.md §6):
  * 2-D weight sharding: the "parallel" dim (heads / d_ff / experts /
    vocab) shards over **model** (TP/EP); the other large dim shards over
    **data** (FSDP / ZeRO-3 analogue — GSPMD inserts the per-layer
    all-gathers).  Optimizer moments inherit the param spec (ZeRO-1+).
  * The **pod** axis is pure DP: params replicated across pods, gradients
    all-reduced over it.
  * Activations/batch shard over (pod, data); model-dim activations stay
    unsharded (GSPMD chooses internal shardings).
  * Decode caches: batch over DP axes; the sequence dim over **model**
    when divisible (context-parallel KV for the 32k/500k cells) — KV heads
    are usually < 16 so head-sharding is not available at kv≤8.

Every assignment is divisibility-checked with graceful fallback (e.g.
minicpm3's vocab 73448 is not 16-divisible ⇒ its embedding shards over
d_model instead).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import dp_axes, dp_size, model_axis_size


def _fits(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0 and dim >= size


def _axis(mesh, name: str) -> Optional[str]:
    return name if name in mesh.axis_names else None


def param_spec(path: str, shape: tuple, mesh) -> P:
    """PartitionSpec for a parameter leaf addressed by its tree path."""
    msize = model_axis_size(mesh)
    dsize = mesh.shape["data"] if "data" in mesh.axis_names else 1
    model = _axis(mesh, "model")
    data = _axis(mesh, "data")

    # Strip the stacked-units leading axis (units/enc_units subtrees).
    lead: tuple = ()
    if ("units" in path or "enc_units" in path) and len(shape) > 1:
        lead, shape = (None,), shape[1:]

    def dim(i, axis, size):
        return axis if axis and _fits(shape[i], size) else None

    n = len(shape)
    if n <= 1:
        # vectors (norm scales, lam): shard over model when large.
        spec = (dim(0, model, msize) if n == 1 and shape[0] >= 1024
                else (None,) * n)
        return P(*lead, *(spec if isinstance(spec, tuple) else (spec,)))

    name = path.split("/")[-1]
    if name == "embed":
        s = (dim(0, model, msize), dim(1, data, dsize))
        if s[0] is None:        # vocab not divisible: shard d_model on model
            s = (None, dim(1, model, msize))
        return P(*s)
    if name == "lm_head":
        s = (dim(0, data, dsize), dim(1, model, msize))
        if s[1] is None:
            s = (dim(0, model, msize), None)
        return P(*s)
    if name == "router":
        return P(*lead, None, None)
    if name in ("w_gate", "w_up", "w_down") and n == 3:   # experts [E,·,·]
        e_ax = dim(0, model, msize)
        if name == "w_down":    # [E, F, D]
            return P(*lead, e_ax, dim(1, data, dsize) if e_ax else
                     dim(1, model, msize), None)
        return P(*lead, e_ax, dim(1, data, dsize) if e_ax else None,
                 dim(2, model, msize) if not e_ax else None)
    if name in ("wo", "w_down", "w_out"):                 # [big, D]
        return P(*lead, dim(0, model, msize), dim(1, data, dsize))
    if name == "r_gates":                                 # [4, H, hd, hd]
        return P(*lead, None, dim(1, model, msize), None, None)
    if name == "conv_w":                                  # [W, R]
        return P(*lead, None, dim(1, model, msize))
    if n == 2:
        # Default projection [D_in, D_out]: FSDP on in, TP on out.
        return P(*lead, dim(0, data, dsize), dim(1, model, msize))
    return P(*lead, *(None,) * n)


def tree_specs(tree, mesh, prefix: str = ""):
    """Map param_spec over a PyTree, building path strings."""
    def walk(subtree, path):
        if isinstance(subtree, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in subtree.items()}
        if isinstance(subtree, (tuple, list)) and not hasattr(
                subtree, "shape"):
            t = type(subtree)
            vals = [walk(v, f"{path}/{i}") for i, v in enumerate(subtree)]
            return t(*vals) if hasattr(subtree, "_fields") else t(vals)
        return param_spec(path, subtree.shape, mesh)
    return walk(tree, prefix)


def batch_spec(shape: tuple, mesh) -> P:
    """Tokens/labels/frames/embeds: batch over DP axes when divisible."""
    dps = dp_axes(mesh)
    total = dp_size(mesh)
    if shape and _fits(shape[0], total):
        return P(dps, *(None,) * (len(shape) - 1))
    return P(*(None,) * len(shape))


def cache_spec(path: str, shape: tuple, mesh) -> P:
    """Decode-cache leaves: batch→DP; longest remaining divisible dim →
    model (context-parallel KV)."""
    msize = model_axis_size(mesh)
    model = _axis(mesh, "model")
    dps = dp_axes(mesh)
    total = dp_size(mesh)
    lead: tuple = ()
    if "units" in path and len(shape) > 1:
        lead, shape = (None,), shape[1:]
    spec = [None] * len(shape)
    if shape and _fits(shape[0], total):
        spec[0] = dps
    if model and len(shape) > 1:
        # Largest non-batch dim divisible by the model axis.
        cands = sorted(range(1, len(shape)), key=lambda i: -shape[i])
        for i in cands:
            if _fits(shape[i], msize):
                spec[i] = model
                break
    return P(*lead, *spec)


def cache_tree_specs(tree, mesh, prefix: str = ""):
    def walk(subtree, path):
        if isinstance(subtree, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in subtree.items()}
        if isinstance(subtree, (tuple, list)) and not hasattr(
                subtree, "shape"):
            t = type(subtree)
            vals = [walk(v, f"{path}/{i}") for i, v in enumerate(subtree)]
            return t(*vals) if hasattr(subtree, "_fields") else t(vals)
        return cache_spec(path, subtree.shape, mesh)
    return walk(tree, prefix)


def to_shardings(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def drop_data(spec: P) -> P:
    """TP-only view of a param spec (the ZeRO-3 gathered layout)."""
    def keep(ax):
        if ax is None:
            return None
        if isinstance(ax, (tuple, list)):
            kept = tuple(a for a in ax if a not in ("data", "pod"))
            return kept if kept else None
        return None if ax in ("data", "pod") else ax
    return P(*(keep(a) for a in spec))


def make_gather_fn(mesh):
    """ZeRO-3 hook for transformer.forward: constrain a param subtree to
    its TP-only sharding at point of use (storage stays FSDP×TP).  GSPMD
    emits the per-layer all-gather here and the matching reduce-scatter in
    the backward."""
    def gather(subtree, hint):
        specs = tree_specs({hint: subtree}, mesh, "gather")[hint]
        specs = jax.tree.map(drop_data, specs,
                             is_leaf=lambda x: isinstance(x, P))
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, s)), subtree, specs)
    return gather
