import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds abstract program state (``jax.eval_shape`` — no
allocation), derives NamedShardings (launch/sharding.py), lowers the step
under the production mesh, compiles, and extracts ``memory_analysis`` /
``cost_analysis`` / collective bytes (parsed from post-SPMD HLO).

**Layer-scan accounting.** The step keeps its production form (scan over
stacked units — small HLO, tractable 512-way compiles even for the
128-expert arctic cells), but XLA's cost analysis counts a while-loop body
ONCE.  So each cell additionally compiles a **one-unit probe** (the unit
body alone — fwd+bwd for train cells — under the same shardings) and the
reported totals are compositional:

    total = scan_program + (U − 1) × unit_probe     [U = n_units]

(The scan program itself contains exactly one body execution, the probe
measures one body; extras — embeddings, logits, loss, optimizer, rehash of
inputs — live outside the scan and are counted exactly.)  Whisper's
encoder scan gets a second probe.  The xLSTM *inner* time scans carry a
documented analytic correction in launch/roofline.py instead.

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import re
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cells, get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_spec, cache_tree_specs,
                                   make_gather_fn, to_shardings,
                                   tree_specs)
from repro.models import transformer
from repro.models.layers import dtype_of
from repro.train.optimizer import AdamWState, adamw_init
from repro.train.train_step import TrainConfig, TrainState, make_train_step

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
           "int32": jnp.int32}
P = jax.sharding.PartitionSpec


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    b, t = shape.global_batch, shape.seq_len
    dt = _DTYPES[cfg.dtype]
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((b, t), jnp.int32)}
        if shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((b, t), jnp.int32)
        if cfg.frontend == "audio_stub":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), dt)
        elif cfg.frontend == "vision_stub":
            batch["embeds"] = jax.ShapeDtypeStruct((b, t, cfg.d_model), dt)
        return batch
    return {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


# ---------------------------------------------------------------------------
# HLO collective parsing.
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64)"
                       r"\[([\d,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8}
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def collective_bytes(hlo_text: str, body_mult: int = 1) -> dict:
    """Σ result-shape bytes per collective kind (×2 for all-reduce: ring
    send+recv of reduced data).  '-start' async forms counted; '-done'
    skipped (same payload).

    Collectives whose op_name metadata places them inside the layer scan
    (``/while/body/``) are multiplied by ``body_mult`` (the scan's static
    trip count = n_units) — XLA's text lists a while body once but it
    executes U times.  Inner time scans (mLSTM/sLSTM) contain no
    collectives, so the single multiplier is exact."""
    out = {k: 0.0 for k in _COLL_KINDS}
    op_re = re.compile(
        r"^%?\S+\s*=\s*(.*?)\s(?<!%)"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(-start|-done)?\(")
    for line in hlo_text.splitlines():
        m = op_re.match(line.strip())
        if not m or m.group(3) == "-done":
            continue
        kind = m.group(2)
        size = 0
        for dtype, dims in _SHAPE_RE.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            size += n * _BYTES[dtype]
        mult = body_mult if "/while/body/" in line else 1
        out[kind] += (float(size) * mult
                      * (2.0 if kind == "all-reduce" else 1.0))
    out["total"] = sum(out.values())
    return out


# ---------------------------------------------------------------------------
# Cell programs.
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh, opt_level: int = 1):
    """(fn, args, in_shardings, out_shardings, donate) for the cell.

    opt_level 0 = naive baseline (unconstrained outputs);
    opt_level 1 = +constrained out_shardings & donated state (perf iter 1);
    opt_level 2 = +ZeRO-3 per-layer weight gathering (perf iter 2 — the
    gather_fn hook re-constrains weights to TP-only inside the scan);
    opt_level 3 = +REX-rehash a2a MoE dispatch (perf iter 3)."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    batch = input_specs(arch, shape_name)
    tcfg = TrainConfig(
        gather_fn=make_gather_fn(mesh) if opt_level >= 2 else None,
        moe_strategy="a2a" if (opt_level >= 3 and cfg.n_experts)
        else "sort")

    params_a = jax.eval_shape(partial(transformer.init_params, cfg),
                              jax.random.PRNGKey(0))
    p_specs = tree_specs(params_a, mesh, "params")
    b_specs = jax.tree.map(lambda x: batch_spec(x.shape, mesh), batch)

    if shape.kind == "train":
        opt_a = jax.eval_shape(adamw_init, params_a)
        state_a = TrainState(params=params_a, opt=opt_a, residuals=None)
        s_specs = TrainState(
            params=p_specs,
            opt=AdamWState(step=P(), mu=p_specs, nu=p_specs),
            residuals=None)
        out_specs = (s_specs, None) if opt_level >= 1 else None
        donate = (0,) if opt_level >= 1 else ()
        return make_train_step(cfg, tcfg), (state_a, batch), \
            (s_specs, b_specs), out_specs, donate

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            kw = {}
            if "frames" in batch:
                kw["enc_out"] = transformer.encode(cfg, params,
                                                   batch["frames"])
            if "embeds" in batch:
                kw["embeds"] = batch["embeds"]
            return transformer.prefill_forward(
                cfg, params, batch["tokens"], shape.seq_len,
                gather_fn=tcfg.gather_fn,
                moe_strategy=tcfg.moe_strategy, **kw)
        return prefill_fn, (params_a, batch), (p_specs, b_specs), \
            None, ()

    cache_a = jax.eval_shape(
        partial(transformer.init_cache, cfg, shape.global_batch,
                shape.seq_len))
    c_specs = cache_tree_specs(cache_a, mesh, "cache")

    def decode_fn(params, cache, token, pos):
        return transformer.decode_step(
            cfg, params, token, cache, pos,
            flash_decode=opt_level >= 2)

    out_specs = (None, c_specs) if opt_level >= 1 else None
    donate = (1,) if opt_level >= 1 else ()
    return (decode_fn, (params_a, cache_a, batch["token"], batch["pos"]),
            (p_specs, c_specs, batch_spec(batch["token"].shape, mesh),
             P()), out_specs, donate)


def build_probes(arch: str, shape_name: str, mesh, opt_level: int = 1):
    """[(multiplier, fn, args, in_shardings)] one-unit probes."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    dt = dtype_of(cfg.dtype)
    b, t = shape.global_batch, shape.seq_len
    probes = []
    gf = make_gather_fn(mesh) if opt_level >= 2 else (lambda s, h: s)

    def unit_params_a():
        def mk(key):
            ks = jax.random.split(key, len(cfg.unit))
            return {f"b{i}_{k}": transformer.init_block(k, cfg, ks[i])
                    for i, k in enumerate(cfg.unit)}
        return jax.eval_shape(mk, jax.random.PRNGKey(0))

    up_a = unit_params_a()
    up_specs = tree_specs(up_a, mesh, "probe")
    enc_out_a = (jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model),
                                      dt) if cfg.encoder_layers else None)
    enc_spec = (batch_spec(enc_out_a.shape, mesh)
                if enc_out_a is not None else None)

    if shape.kind == "train":
        x_a = jax.ShapeDtypeStruct((b, t, cfg.d_model), dt)

        def unit_fwd(up, x, enc_out=None):
            up = gf(up, "unit")
            pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
            aux = jnp.zeros((), jnp.float32)
            for i, kind in enumerate(cfg.unit):
                x, a = transformer.apply_block(
                    kind, cfg, up[f"b{i}_{kind}"], x, pos, enc_out)
                aux = aux + a
            return jnp.sum(x.astype(jnp.float32)) + aux

        body = jax.checkpoint(unit_fwd) if cfg.remat else unit_fwd

        if cfg.encoder_layers:
            def probe(up, x, enc_out):
                return jax.grad(body, argnums=(0, 1))(up, x, enc_out)
            args = (up_a, x_a, enc_out_a)
            specs = (up_specs, batch_spec(x_a.shape, mesh), enc_spec)
        else:
            def probe(up, x):
                return jax.grad(body, argnums=(0, 1))(up, x)
            args = (up_a, x_a)
            specs = (up_specs, batch_spec(x_a.shape, mesh))
        probes.append((cfg.n_units - 1, probe, args, specs))

        if cfg.encoder_layers:  # whisper encoder scan probe
            def enc_params_a():
                return jax.eval_shape(
                    lambda k: {"b0_enc": transformer.init_block(
                        "enc", cfg, k)}, jax.random.PRNGKey(0))
            ep_a = enc_params_a()
            ep_specs = tree_specs(ep_a, mesh, "probe")
            xe_a = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model),
                                        dt)

            def enc_fwd(ep, x):
                pos = jnp.broadcast_to(
                    jnp.arange(cfg.encoder_seq, dtype=jnp.int32),
                    (b, cfg.encoder_seq))
                y, _ = transformer.apply_block("enc", cfg, ep["b0_enc"],
                                               x, pos)
                return jnp.sum(y.astype(jnp.float32))

            def enc_probe(ep, x):
                return jax.grad(enc_fwd, argnums=(0, 1))(ep, x)
            probes.append((cfg.encoder_layers - 1, enc_probe,
                           (ep_a, xe_a),
                           (ep_specs, batch_spec(xe_a.shape, mesh))))
        return probes

    if shape.kind == "prefill":
        x_a = jax.ShapeDtypeStruct((b, t, cfg.d_model), dt)

        def probe(up, x, enc_out=None):
            up = gf(up, "unit")
            pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
            cache = {}
            for i, kind in enumerate(cfg.unit):
                # unroll=True: the blocked-attention KV scan must unroll
                # here or cost analysis counts a single KV block.
                x, cache[f"b{i}_{kind}"] = transformer.prefill_block(
                    kind, cfg, up[f"b{i}_{kind}"], x, pos, t, enc_out,
                    unroll=True,
                    moe_strategy="a2a" if (opt_level >= 3
                                           and cfg.n_experts) else "sort")
            return x, cache

        if cfg.encoder_layers:
            args = (up_a, x_a, enc_out_a)
            specs = (up_specs, batch_spec(x_a.shape, mesh), enc_spec)

            def probe_enc(up, x, enc_out):
                return probe(up, x, enc_out)
            probes.append((cfg.n_units - 1, probe_enc, args, specs))

            def enc_probe(ep, x):
                pos = jnp.broadcast_to(
                    jnp.arange(cfg.encoder_seq, dtype=jnp.int32),
                    (b, cfg.encoder_seq))
                y, _ = transformer.apply_block("enc", cfg, ep["b0_enc"],
                                               x, pos)
                return y
            ep_a = jax.eval_shape(
                lambda k: {"b0_enc": transformer.init_block("enc", cfg, k)},
                jax.random.PRNGKey(0))
            xe_a = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model),
                                        dt)
            probes.append((cfg.encoder_layers - 1, enc_probe,
                           (ep_a, xe_a),
                           (tree_specs(ep_a, mesh, "probe"),
                            batch_spec(xe_a.shape, mesh))))
        else:
            probes.append((cfg.n_units - 1, probe, (up_a, x_a),
                           (up_specs, batch_spec(x_a.shape, mesh))))
        return probes

    # decode
    x_a = jax.ShapeDtypeStruct((b, 1, cfg.d_model), dt)

    def unit_cache_a():
        return jax.eval_shape(
            lambda: {f"b{i}_{k}": transformer.init_block_cache(
                k, cfg, b, t, dt) for i, k in enumerate(cfg.unit)})
    uc_a = unit_cache_a()
    uc_specs = cache_tree_specs(uc_a, mesh, "probe")

    def probe(up, uc, x, pos):
        new_c = {}
        for i, kind in enumerate(cfg.unit):
            name = f"b{i}_{kind}"
            x, new_c[name] = transformer.decode_block(
                kind, cfg, up[name], x, uc[name], pos)
        return x, new_c

    probes.append((cfg.n_units - 1, probe,
                   (up_a, uc_a, x_a, jax.ShapeDtypeStruct((), jnp.int32)),
                   (up_specs, uc_specs, batch_spec(x_a.shape, mesh), P())))
    return probes


def _compile(fn, args, in_specs, mesh, out_specs=None, donate=()):
    kw = {}
    if out_specs is not None:
        kw["out_shardings"] = to_shardings(out_specs, mesh)
    if donate:
        kw["donate_argnums"] = donate
    # set_mesh (not just `with mesh:`) so the ambient ABSTRACT mesh is
    # visible at trace time — the a2a MoE dispatch reads it.
    with jax.sharding.set_mesh(mesh), mesh:
        jitted = jax.jit(fn, in_shardings=to_shardings(in_specs, mesh),
                         **kw)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return compiled


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             verbose: bool = True, with_probes: bool = True,
             opt_level: int = 1) -> dict:
    cfg = get_arch(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, args, in_specs, out_specs, donate = build_cell(
        arch, shape_name, mesh, opt_level=opt_level)
    compiled = _compile(fn, args, in_specs, mesh, out_specs, donate)
    t_main = time.time() - t0

    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    # Collectives: exact from the MAIN program (while-body ops × trip
    # count).  FLOPs/bytes: composed from one-unit probes (XLA cost
    # analysis cannot be scoped per-computation from Python).
    coll = collective_bytes(compiled.as_text(), body_mult=cfg.n_units)
    mem = compiled.memory_analysis()

    probe_detail = []
    if with_probes:
        for mult, pfn, pargs, pspecs in build_probes(arch, shape_name,
                                                     mesh, opt_level):
            if mult <= 0:
                continue
            pc = _compile(pfn, pargs, pspecs, mesh)
            pcost = pc.cost_analysis() or {}
            flops += mult * float(pcost.get("flops", 0.0))
            bytes_acc += mult * float(pcost.get("bytes accessed", 0.0))
            probe_detail.append({
                "mult": mult,
                "flops": float(pcost.get("flops", 0.0)),
                "bytes": float(pcost.get("bytes accessed", 0.0))})

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "opt_level": opt_level,
        "devices": int(mesh.size),
        "compile_s": round(time.time() - t0, 2),
        "main_compile_s": round(t_main, 2),
        "flops": flops,                       # per-device (SPMD), composed
        "bytes_accessed": bytes_acc,
        "collective_bytes": coll,
        "probes": probe_detail,
        "memory": {
            k: int(getattr(mem, k, 0)) for k in
            ("temp_size_in_bytes", "argument_size_in_bytes",
             "output_size_in_bytes")
        } if mem is not None else {},
    }
    if verbose:
        print(json.dumps(result), flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--opt-level", type=int, default=0,
                    help="0 = naive baseline; 1 = constrained "
                         "out_shardings + donation (perf iteration 1)")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    if args.all:
        todo = [(a, s) for a, s, skip in cells() if not skip]
        # Cheap archs first so partial results are useful early.
        order = {"olmo-1b": 0, "xlstm-350m": 1, "starcoder2-3b": 2,
                 "qwen2-vl-2b": 3, "recurrentgemma-2b": 4, "llama3-8b": 5,
                 "whisper-large-v3": 6, "minicpm3-4b": 7,
                 "mixtral-8x22b": 8, "arctic-480b": 9}
        todo.sort(key=lambda c: (order.get(c[0], 99), c[1]))
    else:
        todo = [(args.arch, args.shape)]
    results = []
    for arch, shape in todo:
        try:
            results.append(run_cell(arch, shape, args.multi_pod,
                                    with_probes=not args.no_probes,
                                    opt_level=args.opt_level))
        except Exception as e:  # noqa: BLE001 — report, continue sweep
            print(json.dumps({"arch": arch, "shape": shape,
                              "error": repr(e)[:500]}), flush=True)
            results.append({"arch": arch, "shape": shape,
                            "error": repr(e)[:500]})
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    errs = [r for r in results if "error" in r]
    print(f"# {len(results) - len(errs)}/{len(results)} cells compiled",
          file=sys.stderr)
    sys.exit(1 if errs else 0)


if __name__ == "__main__":
    main()
