"""Training driver: sharded train loop with checkpointing + recovery.

Runs a REAL (small-scale) training run on the local devices — the same
code path the production mesh would run via GSPMD; scale is a config knob.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 50 \
      --reduced --mesh 1x1 [--compression delta] [--resume]

On a cluster each host runs this with its own ``--host-id``; the data
pipeline shards by host, GSPMD shards the step, and the CheckpointManager
writes per-node shards with a replication chain.  Fault tolerance: the
loop checkpoints every ``--ckpt-every`` steps and ``--resume`` restores
the latest (replica-searched) snapshot — kill the process mid-run and
relaunch to exercise it.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.tokens import TokenPipeline
from repro.launch.mesh import make_mesh
from repro.launch.sharding import batch_spec, to_shardings, tree_specs
from repro.train.optimizer import AdamWConfig, AdamWState
from repro.train.train_step import (TrainConfig, TrainState,
                                    init_train_state, make_train_step)
from repro.runtime.checkpoint import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 4x2")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "delta"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--num-hosts", type=int, default=1)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))
    tcfg = TrainConfig(
        adamw=AdamWConfig(lr=args.lr, warmup_steps=10,
                          total_steps=args.steps),
        microbatches=args.microbatches, compression=args.compression)

    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    ckpt = CheckpointManager(args.ckpt_dir, num_nodes=args.num_hosts,
                             replication=min(3, args.num_hosts))
    start_step = 0
    if args.resume:
        try:
            state, start_step = ckpt.load_full(args.host_id, state)
            print(f"resumed from step {start_step}")
        except FileNotFoundError:
            print("no checkpoint found; starting fresh")

    p_specs = tree_specs(state.params, mesh, "params")
    s_specs = TrainState(
        params=p_specs,
        opt=AdamWState(step=jax.sharding.PartitionSpec(), mu=p_specs,
                       nu=p_specs),
        residuals=p_specs if state.residuals is not None else None)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq_len,
                         global_batch=args.global_batch,
                         host_id=args.host_id, num_hosts=args.num_hosts)
    sample = pipe.batch_at(0)
    b_specs = jax.tree.map(lambda x: batch_spec(x.shape, mesh), sample)
    with mesh:
        state = jax.device_put(state, to_shardings(s_specs, mesh))
        step_fn = jax.jit(make_train_step(cfg, tcfg),
                          in_shardings=to_shardings((s_specs, b_specs),
                                                    mesh),
                          donate_argnums=(0,))
        t0 = time.time()
        for step in range(start_step, args.steps):
            batch = pipe.batch_at(step)
            state, metrics = step_fn(state, batch)
            if step % 10 == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"wire {float(metrics['wire_bytes']):.2e}B "
                      f"({(time.time() - t0):.1f}s)", flush=True)
            if (step + 1) % args.ckpt_every == 0:
                ckpt.save_full(args.host_id, step + 1,
                               jax.device_get(state))
                print(f"checkpointed @ {step + 1}")
    print("done.")


if __name__ == "__main__":
    main()
