"""Import-light file channel shared by coordinator and workers.

The multi-process launch path talks over one directory per worker:
atomic JSON heartbeats, leases, stratum tasks and work acks.  This
module holds the channel LAYOUT and the atomic read/write primitives —
and deliberately imports nothing from ``repro.runtime`` (or jax): a
worker in ``jax_mode="distributed"`` must call
``jax.distributed.initialize`` before ANY jax computation, and the
``repro.runtime`` package import chain materializes device constants
(``core.delta.PAD_KEY``) at import time.  Keeping the worker's entire
import surface to this module + stdlib is what makes the distributed
bring-up possible at all; ``runtime/health.py`` re-exports these
helpers for the coordinator side.

Writes follow the same tmp + fsync + replace + dir-fsync discipline as
checkpoint manifests (``runtime/checkpoint.atomic_write_json``) — a
reader never sees a torn heartbeat.  Timestamps are
``time.monotonic()``: comparable across processes on one host
(CLOCK_MONOTONIC is system-wide), which is all the single-box
multi-process regime needs.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Callable, Optional, Tuple


# ---------------------------------------------------------------------------
# Channel layout (one directory per worker under the channel root).
# ---------------------------------------------------------------------------

def worker_dir(root: str, worker_id: int) -> str:
    return os.path.join(root, f"worker{worker_id}")


def heartbeat_path(root: str, worker_id: int) -> str:
    return os.path.join(worker_dir(root, worker_id), "heartbeat.json")


def lease_path(root: str, worker_id: int) -> str:
    return os.path.join(worker_dir(root, worker_id), "lease.json")


def stratum_path(root: str) -> str:
    return os.path.join(root, "stratum.json")


def ack_path(root: str, worker_id: int, stratum: int) -> str:
    return os.path.join(worker_dir(root, worker_id), f"ack{stratum}.json")


# ---------------------------------------------------------------------------
# Atomic channel I/O.
# ---------------------------------------------------------------------------

def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_json(path: str, payload: dict) -> None:
    """Atomic channel write — a heartbeat/ack is never readable torn."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".json")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(os.path.dirname(path))
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def read_json(path: str) -> Optional[dict]:
    """One channel read attempt; ``None`` when not written yet."""
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# Worker-side lease renewal (called from the worker loop).
# ---------------------------------------------------------------------------

def write_heartbeat(root: str, worker_id: int, seq: int,
                    shards: Tuple[int, ...] = (),
                    clock: Callable[[], float] = time.monotonic,
                    **extra) -> None:
    write_json(heartbeat_path(root, worker_id), {
        "worker_id": worker_id, "seq": seq, "t": clock(),
        "pid": os.getpid(), "shards": list(shards), **extra})
