"""Worker process entry point for the multi-process launch path.

Run as ``python -m repro.launch._worker --id K --root DIR ...`` by
``launch/distributed.py``'s :class:`Cluster` (long-lived protocol
workers) and :func:`selftest` (oneshot ``jax.distributed`` bring-up).

IMPORT DISCIPLINE: this module imports ONLY stdlib +
``launch/channel.py``.  In ``--jax distributed`` mode the worker must
call ``jax.distributed.initialize`` before any jax computation, and the
``repro.runtime`` import chain materializes device constants at import
time — so jax (and ``launch/mesh.py``) are imported lazily, AFTER
initialize.  Keep it that way.

The worker's life:

  * write a ``ready.json`` report + first heartbeat (the lease uptake);
  * loop: renew the lease every ``--hb-interval``; follow ``cmd.json``
    (shard assignment, shutdown); ack each broadcast stratum task —
    with a real on-device computation when a jax mode is on;
  * exit when orphaned (the coordinator died) or told to shut down.

A SIGKILL simply stops the loop — heartbeats cease and the coordinator's
lease table notices; a SIGSTOP freezes it — heartbeats arrive late, the
straggle signal.  Nothing here cooperates with its own failure.
"""
from __future__ import annotations

import os
import time
from typing import List

from repro.launch.channel import (ack_path, read_json, stratum_path,
                                  worker_dir, write_heartbeat, write_json)

JAX_MODES = ("off", "local", "distributed")


def _enable_cpu_gloo() -> None:
    """Cross-process CPU collectives need the gloo backend where the
    config knob exists; older jaxlibs that lack it either default
    correctly or fail loudly at the first collective."""
    import jax
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # noqa: BLE001 — knob absent on this jax
        pass


def _report_distributed(args) -> dict:
    """Distributed-mode bring-up: join the jax cluster, build the global
    flat mesh, run one cross-process collective, report ownership."""
    _enable_cpu_gloo()
    import jax
    jax.distributed.initialize(coordinator_address=args.coordinator,
                               num_processes=args.num_processes,
                               process_id=args.process_id)
    import jax.numpy as jnp
    from jax.experimental import multihost_utils

    from repro.launch.mesh import flat_mesh, local_shards
    mesh = flat_mesh(devices=jax.devices())
    gathered = multihost_utils.process_allgather(
        jnp.asarray([args.process_id], jnp.int32))
    return {
        "process_index": int(jax.process_index()),
        "num_processes": int(jax.process_count()),
        "global_devices": len(jax.devices()),
        "local_devices": len(jax.local_devices()),
        "num_shards": int(mesh.devices.size),
        "local_shards": local_shards(mesh),
        "allgather": [int(x) for x in gathered.reshape(-1)],
    }


def _device_work(seq: int):
    """A real on-device computation per stratum ack (local/distributed
    jax modes): proves the worker's runtime is alive, not just its
    event loop."""
    import jax.numpy as jnp
    return float(jnp.sum(jnp.arange(256, dtype=jnp.float32) + seq))


def worker_main(args) -> int:
    root = args.root
    wid = args.id
    os.makedirs(worker_dir(root, wid), exist_ok=True)
    report = {"worker_id": wid, "jax": args.jax, "pid": os.getpid()}
    if args.jax == "distributed":
        report.update(_report_distributed(args))
    elif args.jax == "local":
        import jax
        report["local_devices"] = len(jax.devices())
    write_json(os.path.join(worker_dir(root, wid), "ready.json"), report)
    write_heartbeat(root, wid, 0, jax=args.jax)
    if args.oneshot:
        return 0

    ppid = os.getppid()
    shards: List[int] = []
    hb_seq, last_hb = 1, time.monotonic()
    last_ack_seq = -1
    cmd_seq = -1
    poll_s = max(min(args.hb_interval / 4.0, 0.02), 0.001)
    while True:
        now = time.monotonic()
        if os.getppid() != ppid:          # coordinator gone: orphan exit
            return 1
        try:
            cmd = read_json(os.path.join(worker_dir(root, wid),
                                         "cmd.json"))
        except (OSError, ValueError):
            cmd = None
        if cmd and cmd.get("seq", -1) > cmd_seq:
            cmd_seq = cmd["seq"]
            if cmd.get("kind") == "shutdown":
                return 0
            if cmd.get("kind") == "assign":
                shards = list(cmd.get("shards", []))
        if now - last_hb >= args.hb_interval:
            write_heartbeat(root, wid, hb_seq, tuple(shards),
                            jax=args.jax)
            hb_seq += 1
            last_hb = now
        try:
            task = read_json(stratum_path(root))
        except (OSError, ValueError):
            task = None
        if task and task.get("seq", -1) > last_ack_seq:
            last_ack_seq = task["seq"]
            ack = {"worker_id": wid, "seq": last_ack_seq,
                   "stratum": task.get("stratum", -1),
                   "t": time.monotonic()}
            if args.jax in ("local", "distributed"):
                ack["device_work"] = _device_work(last_ack_seq)
            write_json(ack_path(root, wid, last_ack_seq), ack)
        time.sleep(poll_s)


def main(argv=None) -> int:
    import argparse
    parser = argparse.ArgumentParser(
        description="Launch-path worker process (heartbeat/lease/ack "
                    "loop, optional per-worker jax runtime).")
    parser.add_argument("--id", type=int, required=True)
    parser.add_argument("--root", required=True)
    parser.add_argument("--hb-interval", type=float, default=0.1)
    parser.add_argument("--jax", default="off", choices=JAX_MODES)
    parser.add_argument("--oneshot", action="store_true")
    parser.add_argument("--coordinator", default="")
    parser.add_argument("--num-processes", type=int, default=1)
    parser.add_argument("--process-id", type=int, default=0)
    return worker_main(parser.parse_args(argv))


if __name__ == "__main__":
    raise SystemExit(main())
