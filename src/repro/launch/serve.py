"""Serving driver: prefill + batched greedy decode on local devices.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
      --batch 4 --prompt-len 16 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import transformer
from repro.train.serve_step import ServeState, serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new_tokens
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab)
    enc_out = None
    if cfg.encoder_layers:
        frames = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
        enc_out = transformer.encode(cfg, params, frames)

    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, t: transformer.prefill_forward(cfg, p, t, max_len,
                                                 enc_out=enc_out)
    )(params, prompt)
    if cfg.encoder_layers:
        from repro.train.serve_step import fill_cross_kv
        cache = fill_cross_kv(cfg, params, cache, enc_out)
    nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)[:, None]
    state = ServeState(cache=cache,
                       pos=jnp.asarray(args.prompt_len, jnp.int32),
                       last_token=nxt)
    print(f"prefill [{args.batch}x{args.prompt_len}] "
          f"{time.time() - t0:.2f}s")

    step = jax.jit(lambda s: serve_step(cfg, params, s))
    toks = [nxt]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        tok, state = step(state)
        toks.append(tok)
    out = jnp.concatenate(toks, axis=1)
    dt = time.time() - t0
    print(f"decoded {args.new_tokens - 1} steps in {dt:.2f}s "
          f"({args.batch * (args.new_tokens - 1) / max(dt, 1e-9):.1f} "
          f"tok/s)")
    print("sample tokens:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
