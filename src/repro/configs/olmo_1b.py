"""olmo-1b [dense]: 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304 —
non-parametric LayerNorm [arXiv:2402.00838; hf]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=50_304, head_dim=128,
    unit=("dense",), rope_kind="rope", norm_kind="nonparam_ln",
    tie_embeddings=True,
    long_context_ok=False, decode_ok=True,
))
