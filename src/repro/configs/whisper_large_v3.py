"""whisper-large-v3 [audio]: 32L d_model=1280 20H (kv=20) d_ff=5120
vocab=51866 — enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified].

Encoder-decoder: 32 bidirectional encoder layers over 1500 precomputed
frame embeddings (the conv frontend is a STUB — ``input_specs`` supplies
the frames), 32 decoder layers with causal self-attn + cross-attn.
Decode shapes run (the decoder IS autoregressive); long_500k is skipped
(full attention decoder).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab=51_866, head_dim=64,
    unit=("dec_cross",), encoder_layers=32, encoder_seq=1500,
    rope_kind="none", norm_kind="layernorm", frontend="audio_stub",
    long_context_ok=False, decode_ok=True,
))
