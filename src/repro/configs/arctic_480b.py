"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128e top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf].

Dense-MoE hybrid: every block runs a small dense FFN *in parallel* with the
top-2-of-128 MoE FFN (``moe_dense_residual``).  Expert dispatch is the REX
rehash pattern (tokens = deltas keyed by expert; see models/moe.py).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32_000, head_dim=128,
    unit=("moe",), n_experts=128, top_k=2, moe_dense_residual=True,
    rope_kind="rope", norm_kind="rmsnorm",
    long_context_ok=False, decode_ok=True,
))
