"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE [arXiv:2402.19173; hf]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_ff=12_288,
    vocab=49_152, head_dim=128,
    unit=("dense",), rope_kind="rope", norm_kind="layernorm",
    long_context_ok=False, decode_ok=True,
))
