"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, SWA [arXiv:2401.04088; hf].

Sliding-window attention (4096) bounds the decode KV cache, so the
long_500k cell runs (window-bounded, sub-quadratic).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16_384,
    vocab=32_768, head_dim=128,
    unit=("moe",), n_experts=8, top_k=2, window=4096,
    rope_kind="rope", norm_kind="rmsnorm",
    long_context_ok=True, decode_ok=True,
))
