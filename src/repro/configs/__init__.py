from repro.configs.base import (SHAPES, ArchConfig, Shape, all_archs, cells,
                                get_arch, register)

__all__ = ["SHAPES", "ArchConfig", "Shape", "all_archs", "cells",
           "get_arch", "register"]
