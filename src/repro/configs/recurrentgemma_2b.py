"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attn, 1:2 [arXiv:2402.19427; hf].

Griffin pattern: repeating unit (recurrent, recurrent, local-attention);
26 = 8·3 + 2 ⇒ 8 full units + a (recurrent, recurrent) tail, kept exact.
RG-LRU recurrence (width 2560) is a linear scan ⇒ associative-scan
parallel over time; local attention window 2048.  Constant-size state +
bounded window ⇒ long_500k runs.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256_000, head_dim=256,
    unit=("rec", "rec", "attn_local"), window=2048, rnn_dim=2560,
    conv_width=4, rope_kind="rope", norm_kind="rmsnorm",
    long_context_ok=True, decode_ok=True,
))
