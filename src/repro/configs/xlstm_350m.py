"""xlstm-350m [ssm]: 24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304 —
sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

Alternating (mLSTM, sLSTM) units.  mLSTM's matrix memory is computed in
chunked-parallel form (TPU adaptation; see models/ssm.py); sLSTM's
recurrent connection forces a sequential time scan.  Constant-size state ⇒
long_500k runs.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50_304, head_dim=256,
    unit=("mlstm", "slstm"), rope_kind="none", norm_kind="layernorm",
    mlstm_chunk=64,
    long_context_ok=True, decode_ok=True,
))
