"""Architecture configs + shape registry (assigned pool, 10 archs × 4 shapes).

Every assigned architecture is a selectable config (``--arch <id>``); the
full configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) while smoke tests instantiate ``reduced()`` variants.

Shape semantics (LM family):
  train_4k     — train_step,  seq 4096,   global batch 256
  prefill_32k  — serve prefill, seq 32768, global batch 32
  decode_32k   — serve_step: ONE new token against a 32768 KV cache, batch 128
  long_500k    — serve_step at 524288 context, batch 1 — requires
                 sub-quadratic attention; skipped for pure full-attention
                 archs (recorded per-config in ``long_context_ok``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # Block pattern: repeating unit of layer kinds; n_layers = unit·U + tail.
    unit: Tuple[str, ...] = ("dense",)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False      # arctic: dense FFN in parallel
    capacity_factor: float = 1.25
    # attention
    window: int = 0               # 0 = full attention; >0 = sliding window
    rope_kind: str = "rope"       # rope|mrope|none
    # MLA (minicpm3)
    mla_kv_rank: int = 0
    mla_q_rank: int = 0
    mla_rope_dim: int = 0
    # recurrent dims
    rnn_dim: int = 0              # RG-LRU recurrence width
    conv_width: int = 4
    mlstm_chunk: int = 64
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0          # frames after conv frontend (stub)
    # norms
    norm_kind: str = "rmsnorm"    # rmsnorm|layernorm|nonparam_ln
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # capability flags
    long_context_ok: bool = False # sub-quadratic decode path exists
    decode_ok: bool = True        # False for encoder-only models
    # frontend stubs
    frontend: str = "none"        # none|vision_stub|audio_stub
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.unit)

    @property
    def tail(self) -> Tuple[str, ...]:
        """Layers beyond the last full unit (kept exact, e.g. 26 = 8·3 + 2)."""
        return self.unit[: self.n_layers % len(self.unit)]

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        n_unit = len(self.unit)
        heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv_heads, heads))
        while heads % kv:
            kv -= 1
        return dataclasses.replace(
            self,
            n_layers=2 * n_unit if self.n_layers % n_unit == 0
            else 2 * n_unit + len(self.tail),
            d_model=64, n_heads=heads, n_kv_heads=kv, head_dim=16,
            d_ff=128 if self.d_ff else 0, vocab=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            mla_kv_rank=32 if self.mla_kv_rank else 0,
            mla_q_rank=48 if self.mla_q_rank else 0,
            mla_rope_dim=8 if self.mla_rope_dim else 0,
            rnn_dim=64 if self.rnn_dim else 0,
            window=min(self.window, 16) if self.window else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=32 if self.encoder_seq else 0,
            mlstm_chunk=8, dtype="float32", remat=False)


_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_archs() -> Sequence[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    # Import side-effect registers every assigned config.
    from repro.configs import (arctic_480b, llama3_8b, minicpm3_4b,  # noqa
                               mixtral_8x22b, olmo_1b, qwen2_vl_2b,
                               recurrentgemma_2b, starcoder2_3b,
                               whisper_large_v3, xlstm_350m)


def cells() -> list[tuple[str, str, str]]:
    """All runnable (arch, shape, skip_reason) dry-run cells; 40 assigned
    cells total — skipped cells are listed with their reason (DESIGN.md
    §Arch-applicability)."""
    out = []
    for arch in all_archs():
        cfg = get_arch(arch)
        for shape in SHAPES.values():
            reason = ""
            if shape.kind == "decode" and not cfg.decode_ok:
                reason = "encoder-only: no decode step"
            elif shape.name == "long_500k" and not cfg.long_context_ok:
                reason = "full attention is quadratic at 500k"
            out.append((arch, shape.name, reason))
    return out
