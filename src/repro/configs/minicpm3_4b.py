"""minicpm3-4b [dense]: 62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA
[hf:openbmb/MiniCPM3-4B; hf].

Multi-head Latent Attention: queries from a rank-768 projection, K/V from a
shared rank-256 latent plus a 32-dim decoupled RoPE key.  The decode cache
stores (latent, rope-key) — 288 floats/token instead of 2·H·Dh = 5120 —
MLA's serving advantage, realized in models/attention.py.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab=73_448, head_dim=64,
    unit=("mla",), mla_q_rank=768, mla_kv_rank=256, mla_rope_dim=32,
    rope_kind="rope", norm_kind="rmsnorm",
    long_context_ok=False, decode_ok=True,
))
