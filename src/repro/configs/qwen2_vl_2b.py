"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only: the vision frontend is a stub (``input_specs`` supplies
precomputed patch embeddings alongside text tokens).  M-RoPE splits the
rotary dims into (temporal, height, width) sections driven by 3-row
position ids.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab=151_936, head_dim=128,
    unit=("dense",), rope_kind="mrope", norm_kind="rmsnorm",
    frontend="vision_stub", tie_embeddings=True,
    long_context_ok=False, decode_ok=True,
))
