"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — GQA, 128k vocab [arXiv:2407.21783; unverified]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14_336,
    vocab=128_256, head_dim=128,
    unit=("dense",), rope_kind="rope", norm_kind="rmsnorm",
    long_context_ok=False, decode_ok=True,
))
