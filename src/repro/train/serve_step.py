"""Serving: prefill + batched decode steps.

``prefill``   — full-sequence forward building the KV/recurrent cache
                (the prefill_32k cell lowers this).
``serve_step``— one token for every sequence in the batch against the
                cache (the decode_32k / long_500k cells lower this).
                Greedy sampling; a temperature/top-k head is a pure
                post-map and does not change the lowered compute.

Decode-as-delta: the cache is the mutable set, the new token the one-entry
Δ; recurrent archs (xlstm, recurrentgemma) carry O(1) state — their
long_500k cells cost the same FLOPs per token as short contexts.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models import attention as attn


class ServeState(NamedTuple):
    cache: dict
    pos: jax.Array          # int32[] — next write position
    last_token: jax.Array   # int32[B, 1]


def prefill(cfg, params, tokens: jax.Array, max_len: int,
            enc_out=None) -> tuple[jax.Array, ServeState]:
    """Build a cache by teacher-forcing ``tokens`` one step at a time.

    (For throughput one would chunk this; the cells lower ``forward`` for
    prefill cost and ``serve_step`` for decode cost, so this loop is used
    only by the runnable examples on small shapes.)"""
    b, t = tokens.shape
    cache = transformer.init_cache(cfg, b, max_len)
    if cfg.encoder_layers and enc_out is not None:
        cache = fill_cross_kv(cfg, params, cache, enc_out)

    def body(carry, tk_pos):
        cache, _ = carry
        tk, pos = tk_pos
        logits, cache = transformer.decode_step(cfg, params, tk[:, None],
                                                cache, pos)
        return (cache, logits), None

    (cache, logits), _ = jax.lax.scan(
        body, (cache, jnp.zeros((b, 1, cfg.vocab), jnp.float32)),
        (tokens.T, jnp.arange(t, dtype=jnp.int32)))
    next_tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
    return logits, ServeState(cache=cache, pos=jnp.asarray(t, jnp.int32),
                              last_token=next_tok)


def fill_cross_kv(cfg, params, cache: dict, enc_out: jax.Array) -> dict:
    """Precompute per-layer cross-attention K/V from the encoder output."""
    def fill(unit_p, unit_c):
        for i, kind in enumerate(cfg.unit):
            if kind == "dec_cross":
                name = f"b{i}_{kind}"
                unit_c[name]["cross_kv"] = attn.encode_cross_kv(
                    cfg, unit_p[name]["cross"], enc_out)
        return unit_c

    cache = dict(cache)
    cache["units"] = jax.vmap(fill)(params["units"], cache["units"])
    return cache


def serve_step(cfg, params, state: ServeState
               ) -> tuple[jax.Array, ServeState]:
    """One decode step for the whole batch: returns (token [B,1], state')."""
    logits, cache = transformer.decode_step(
        cfg, params, state.last_token, state.cache, state.pos)
    nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
    return nxt, ServeState(cache=cache, pos=state.pos + 1, last_token=nxt)


def generate(cfg, params, prompt: jax.Array, n_new: int, max_len: int,
             enc_out=None) -> jax.Array:
    """Greedy generation driver (examples/serve_lm.py)."""
    _, state = prefill(cfg, params, prompt, max_len, enc_out=enc_out)

    def body(state, _):
        tok, state = serve_step(cfg, params, state)
        return state, tok[:, 0]

    _, toks = jax.lax.scan(body, state, None, length=n_new)
    return jnp.concatenate([prompt, toks.T], axis=1)
