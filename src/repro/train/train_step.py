"""Training step: CE loss, grad, microbatching, optimizer application.

``make_train_step`` builds a pure (state, batch) → (state, metrics)
function suitable for ``jax.jit`` under a mesh with NamedSharding-annotated
state (launch/sharding.py supplies the specs).  Microbatching accumulates
gradients over a leading microbatch axis with ``lax.scan`` — the standard
compute/communication overlap lever: XLA schedules the DP all-reduce of
microbatch i's gradients against microbatch i+1's backward pass.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.train.optimizer import (AdamWConfig, AdamWState, adamw_init,
                                   adamw_update, compress_tree,
                                   zero_residuals)


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState
    residuals: Optional[dict]      # gradient-compression error feedback


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    compression: str = "none"      # none | int8 | delta
    topk_frac: float = 0.01
    moe_aux_weight: float = 0.01
    moe_strategy: str = "sort"
    use_flash_kernel: bool = False
    label_smoothing: float = 0.0
    unroll: bool = False           # unroll the unit scan (roofline lowering)
    gather_fn: object = None       # ZeRO-3 per-layer weight gather hook


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  smoothing: float = 0.0) -> jax.Array:
    """logits f32[B, T, V]; labels int32[B, T] (−1 = masked)."""
    v = logits.shape[-1]
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if smoothing:
        mean_logit = jnp.mean(logits, axis=-1)
        nll = (1 - smoothing) * nll + smoothing * (logz - mean_logit)
    return jnp.sum(jnp.where(mask, nll, 0.0)) / jnp.maximum(
        jnp.sum(mask), 1)


def make_loss_fn(cfg, tcfg: TrainConfig):
    def loss_fn(params, batch):
        kw = {}
        if "frames" in batch:
            kw["enc_out"] = transformer.encode(cfg, params, batch["frames"],
                                               unroll=tcfg.unroll)
        if "embeds" in batch:
            kw["embeds"] = batch["embeds"]
        if "positions" in batch:
            kw["positions"] = batch["positions"]
        logits, aux = transformer.forward(
            cfg, params, batch["tokens"],
            moe_strategy=tcfg.moe_strategy,
            use_kernel=tcfg.use_flash_kernel, unroll=tcfg.unroll,
            gather_fn=tcfg.gather_fn, **kw)
        loss = cross_entropy(logits, batch["labels"], tcfg.label_smoothing)
        return loss + tcfg.moe_aux_weight * aux, (loss, aux)
    return loss_fn


def init_train_state(cfg, tcfg: TrainConfig, key) -> TrainState:
    params = transformer.init_params(cfg, key)
    residuals = (zero_residuals(params)
                 if tcfg.compression != "none" else None)
    return TrainState(params=params, opt=adamw_init(params),
                      residuals=residuals)


def make_train_step(cfg, tcfg: TrainConfig):
    loss_fn = make_loss_fn(cfg, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: dict):
        if tcfg.microbatches > 1:
            mb = jax.tree.map(
                lambda x: x.reshape((tcfg.microbatches,
                                     x.shape[0] // tcfg.microbatches)
                                    + x.shape[1:]), batch)

            def acc_body(carry, mbatch):
                gsum, lsum = carry
                (_, (loss, _)), g = grad_fn(state.params, mbatch)
                return (jax.tree.map(jnp.add, gsum, g), lsum + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            (grads, loss_sum), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
            loss = loss_sum / tcfg.microbatches
        else:
            (_, (loss, _)), grads = grad_fn(state.params, batch)

        wire_bytes = jnp.zeros((), jnp.float32)
        residuals = state.residuals
        if tcfg.compression != "none":
            grads, residuals, wire_bytes = compress_tree(
                grads, residuals, tcfg.compression, tcfg.topk_frac)

        new_params, new_opt, metrics = adamw_update(
            tcfg.adamw, state.opt, state.params, grads)
        metrics.update({"loss": loss, "wire_bytes": wire_bytes})
        return TrainState(new_params, new_opt, residuals), metrics

    return train_step
