from repro.train.optimizer import (AdamWConfig, AdamWState, adamw_init,
                                   adamw_update, compress_tree)
from repro.train.serve_step import ServeState, generate, prefill, serve_step
from repro.train.train_step import (TrainConfig, TrainState, cross_entropy,
                                    init_train_state, make_loss_fn,
                                    make_train_step)

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "compress_tree", "ServeState", "generate", "prefill",
           "serve_step", "TrainConfig", "TrainState", "cross_entropy",
           "init_train_state", "make_loss_fn", "make_train_step"]
