"""AdamW + schedules + gradient compression (incl. REX-delta compression).

Two gradient compressors for the DP all-reduce, both with **error
feedback** (the residual not transmitted this step is carried and added to
the next step's gradient — guaranteeing no information is permanently
lost, the same role as REX's guarantee that un-propagated Δ mass stays in
operator state):

  * ``int8``  — per-block scale quantization: 4× fewer bytes on the wire.
  * ``delta`` — REX's own idea applied to SGD: ship only the top-|Δ|
    gradient *components* as (index, value) deltas in a fixed-capacity
    DeltaBuffer — the gradient's Δᵢ set.  Sparsity rises as training
    converges, exactly the paper's convergence argument (§1).

Compression wraps the gradient before the data-parallel reduction; in the
GSPMD path this is modeled as compress→decompress around the psum point
(bytes accounted analytically in benchmarks/bench_bandwidth.py); the
shard_map training path applies it around the explicit psum.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.zeros_like, zeros))


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, state: AdamWState, params, grads
                 ) -> tuple[dict, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:     # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# Gradient compression with error feedback.
# ---------------------------------------------------------------------------

BLOCK = 256


def int8_compress(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8: returns (q int8[N], scale f32[N/BLOCK])."""
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blocks = flat.reshape(-1, BLOCK).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)[:, None]
                  ).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    import math
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    return flat[: math.prod(shape)].reshape(shape)


def ef_int8(g: jax.Array, residual: jax.Array
            ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback int8: returns (g_hat, new_residual, wire_bytes)."""
    target = g.astype(jnp.float32) + residual
    q, scale = int8_compress(target)
    g_hat = int8_decompress(q, scale, g.shape)
    bytes_ = jnp.asarray(q.size + scale.size * 4, jnp.float32)
    return g_hat, target - g_hat, bytes_


def ef_topk_delta(g: jax.Array, residual: jax.Array, k: int
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """REX-delta compression: ship only the k largest-|·| components as
    (idx, val) deltas; the rest stays in the residual (error feedback).

    Returns (g_hat dense, new_residual, wire_bytes = 8k)."""
    target = (g.astype(jnp.float32) + residual).reshape(-1)
    k = min(k, target.shape[0])
    _, idx = jax.lax.top_k(jnp.abs(target), k)
    vals = target[idx]
    g_hat = jnp.zeros_like(target).at[idx].set(vals).reshape(g.shape)
    return g_hat, (target.reshape(g.shape) - g_hat), jnp.asarray(
        8.0 * k, jnp.float32)


def compress_tree(grads, residuals, method: str = "int8",
                  topk_frac: float = 0.01):
    """Apply a compressor leaf-wise; returns (grads_hat, residuals, bytes).

    ``none`` passes through (bytes = 4·N, the uncompressed f32 wire cost).
    """
    leaves, treedef = jax.tree.flatten(grads)
    res_leaves = jax.tree.leaves(residuals)
    outs, new_res, total = [], [], jnp.zeros((), jnp.float32)
    for g, r in zip(leaves, res_leaves):
        if method == "none":
            gh, nr, b = g, r, jnp.asarray(4.0 * g.size, jnp.float32)
        elif method == "int8":
            gh, nr, b = ef_int8(g, r)
        elif method == "delta":
            k = max(1, int(g.size * topk_frac))
            gh, nr, b = ef_topk_delta(g, r, k)
        else:
            raise ValueError(method)
        outs.append(gh)
        new_res.append(nr)
        total = total + b
    return (jax.tree.unflatten(treedef, outs),
            jax.tree.unflatten(treedef, new_res), total)


def zero_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
