"""Synthetic LM token pipeline: deterministic, sharded, prefetchable.

Each host generates only its slice of the global batch (``host_id`` /
``num_hosts``), from a counter-based PRNG — no file I/O, bit-reproducible
across restarts (a requirement for recovery replay: after a failure, the
restored step re-reads the same batch).  The token stream mixes a Zipf
unigram distribution with short Markov repeats so the CE loss has real
structure to descend on (quickstart trains to visibly falling loss).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    host_id: int = 0
    num_hosts: int = 1
    seed: int = 0

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.num_hosts

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for ``step`` (counter-based; replayable)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        b, t = self.host_batch, self.seq_len
        # Zipf-ish unigrams over the vocab.
        u = rng.zipf(1.3, size=(b, t + 1))
        toks = (u % self.vocab).astype(np.int32)
        # Inject Markov structure: with p=0.5, next token = f(current).
        repeat = rng.random((b, t)) < 0.5
        nxt = (toks[:, :-1] * 31 + 7) % self.vocab
        toks[:, 1:] = np.where(repeat, nxt, toks[:, 1:])
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
