"""Point sets for k-means (paper §6 "Data": DBPedia geo coordinates,
328,232 points enlarged up to 382M by simulating extra points around each
original).  We reproduce the same construction: a base set of cluster-ish
centers with Gaussian clouds, optionally multiplied by jittered copies."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def make_geo_points(n_points: int, n_true_clusters: int = 32, spread: float = 3.0,
                    jitter: float = 0.15, seed: int = 0) -> jnp.ndarray:
    """2-D points (lon/lat-like) drawn around ``n_true_clusters`` centers."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-90, 90, size=(n_true_clusters, 2))
    assign = rng.integers(0, n_true_clusters, size=n_points)
    pts = centers[assign] + rng.normal(0.0, spread, size=(n_points, 2))
    # The paper "enlarges by simulating up to 1000 additional points around
    # each original coordinate" — the jitter term models that enlargement.
    pts += rng.normal(0.0, jitter, size=pts.shape)
    return jnp.asarray(pts.astype(np.float32))


def sample_initial_centroids(points: jnp.ndarray, k: int, seed: int = 1
                             ) -> jnp.ndarray:
    """KMSampleAgg (paper appendix): sample initial centroids among the
    point coordinates."""
    rng = np.random.default_rng(seed)
    idx = rng.choice(points.shape[0], size=k, replace=False)
    return points[jnp.asarray(idx)]
