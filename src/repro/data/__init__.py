"""Data substrates: synthetic graph / point / token pipelines."""
