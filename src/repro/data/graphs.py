"""Synthetic graphs shaped like the paper's datasets (§6 "Data").

The paper uses the DBPedia article-link graph (48M edges / 3.3M vertices,
avg degree ~14.5) and a Twitter follower graph (1.4B edges / 41M vertices,
avg degree ~34, heavy-tailed).  We generate power-law (Zipf out-degree)
directed graphs with matching shape statistics at configurable scale, stored
as padded CSR partitioned by source vertex — the paper's "edge relation
partitioned by vertexId" (immutable set).

CSR layout per shard (block partition over sources):
  indptr:  int32[block+1]       — local CSR row pointers
  indices: int32[nnz_capacity]  — destination GLOBAL vertex ids (PAD = -1)
  out_degree: int32[block]      — true out-degree per local source

nnz is padded per shard to the max across shards so that shards stack into a
single array (static shapes; the padding models the skew the paper's
consistent hashing tries to avoid).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Single-shard (or global) padded CSR directed graph."""

    indptr: jax.Array      # int32[n_src + 1]
    indices: jax.Array     # int32[nnz_cap], PAD = -1
    out_degree: jax.Array  # int32[n_src]   (global out-degree of each source)

    @property
    def n_src(self) -> int:
        return self.out_degree.shape[0]

    @property
    def nnz_capacity(self) -> int:
        return self.indices.shape[0]


def zipf_outdegrees(n_vertices: int, avg_degree: float, alpha: float,
                    rng: np.random.Generator, max_degree: int | None = None
                    ) -> np.ndarray:
    """Zipf-ish out-degree sequence normalized to the requested average."""
    raw = rng.zipf(alpha, size=n_vertices).astype(np.float64)
    if max_degree is None:
        max_degree = max(int(avg_degree * 50), 8)
    raw = np.minimum(raw, max_degree)
    scale = avg_degree * n_vertices / raw.sum()
    deg = np.maximum(np.round(raw * scale), 0).astype(np.int64)
    deg = np.minimum(deg, n_vertices - 1)
    return deg.astype(np.int32)


def make_powerlaw_graph(n_vertices: int, avg_degree: float = 14.5,
                        alpha: float = 2.1, seed: int = 0) -> tuple[
                            np.ndarray, np.ndarray]:
    """Global CSR (indptr, indices) with Zipf out-degrees.

    avg_degree defaults to DBPedia's ~14.5; use ~34 and alpha≈1.9 for the
    Twitter-shaped configuration.
    """
    rng = np.random.default_rng(seed)
    deg = zipf_outdegrees(n_vertices, avg_degree, alpha, rng)
    indptr = np.zeros(n_vertices + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    nnz = int(indptr[-1])
    # Destinations ~ preferential-attachment-ish: mix uniform with a head
    # bias so in-degree is also heavy-tailed (as in web/social graphs).
    n_head = max(n_vertices // 100, 1)
    n_from_head = nnz // 3
    dst = np.empty(nnz, np.int32)
    dst[:n_from_head] = rng.integers(0, n_head, n_from_head)
    dst[n_from_head:] = rng.integers(0, n_vertices, nnz - n_from_head)
    rng.shuffle(dst)
    return indptr.astype(np.int64), dst


def shard_csr(indptr: np.ndarray, indices: np.ndarray, num_shards: int,
              nnz_capacity: int | None = None) -> CSRGraph:
    """Partition a global CSR by source block into stacked per-shard CSR.

    Returns a CSRGraph whose arrays carry a leading [num_shards] axis
    (matching the simulated engine backend; shard_map splits the same axis).

    ``nnz_capacity`` pins the per-shard edge-slot capacity so that graphs
    rebuilt after base-data mutations keep static shapes (the incremental
    view subsystem relies on this to avoid re-tracing the fixpoint between
    refreshes).  Raises if any shard's edges exceed the pinned capacity.
    """
    n = indptr.shape[0] - 1
    block = -(-n // num_shards)
    padded = block * num_shards
    deg = np.diff(indptr)
    deg_padded = np.zeros(padded, np.int64)
    deg_padded[:n] = deg
    per_shard_nnz = deg_padded.reshape(num_shards, block).sum(axis=1)
    nnz_cap = int(per_shard_nnz.max()) if len(per_shard_nnz) else 0
    nnz_cap = max(nnz_cap, 1)
    if nnz_capacity is not None:
        if nnz_cap > nnz_capacity:
            raise ValueError(
                f"shard nnz {nnz_cap} exceeds pinned capacity {nnz_capacity}")
        nnz_cap = nnz_capacity

    sh_indptr = np.zeros((num_shards, block + 1), np.int32)
    sh_indices = np.full((num_shards, nnz_cap), -1, np.int32)
    sh_deg = np.zeros((num_shards, block), np.int32)
    for s in range(num_shards):
        lo, hi = s * block, min((s + 1) * block, n)
        local_deg = deg_padded[s * block:(s + 1) * block]
        sh_indptr[s, 1:] = np.cumsum(local_deg)
        sh_deg[s] = local_deg
        if hi > lo:
            seg = indices[indptr[lo]:indptr[hi]]
            sh_indices[s, :len(seg)] = seg
    return CSRGraph(indptr=jnp.asarray(sh_indptr),
                    indices=jnp.asarray(sh_indices),
                    out_degree=jnp.asarray(sh_deg))


def csr_to_edges(indptr: np.ndarray, indices: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Global CSR -> (src, dst) edge list (drops PAD=-1 slots)."""
    n = indptr.shape[0] - 1
    src = np.repeat(np.arange(n, dtype=np.int32),
                    np.diff(indptr).astype(np.int64))
    dst = np.asarray(indices[:len(src)], np.int32)
    keep = dst >= 0
    return src[keep], dst[keep]


def edges_to_csr(src: np.ndarray, dst: np.ndarray, n: int
                 ) -> tuple[np.ndarray, np.ndarray]:
    """(src, dst) edge list -> global CSR (indptr int64, indices int32).

    Stable with respect to the input edge order within each source row, so
    rebuilding after a mutation batch is deterministic.
    """
    src = np.asarray(src, np.int64)
    order = np.argsort(src, kind="stable")
    deg = np.bincount(src, minlength=n).astype(np.int64)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    return indptr, np.asarray(dst, np.int32)[order]


def global_csr(indptr: np.ndarray, indices: np.ndarray) -> CSRGraph:
    """Single-shard CSRGraph view of a global CSR."""
    deg = np.diff(indptr).astype(np.int32)
    return CSRGraph(indptr=jnp.asarray(indptr.astype(np.int32)),
                    indices=jnp.asarray(indices),
                    out_degree=jnp.asarray(deg))


# Named dataset shapes (scaled-down analogues of the paper's datasets).
DATASETS = {
    # name: (n_vertices, avg_degree, alpha)
    "dbpedia-small": (4_096, 14.5, 2.1),     # unit tests
    "dbpedia": (65_536, 14.5, 2.1),          # benches (paper: 3.3M x 14.5)
    "twitter-small": (8_192, 34.0, 1.9),
    "twitter": (131_072, 34.0, 1.9),         # benches (paper: 41M x 34)
}


def load_dataset(name: str, num_shards: int = 1, seed: int = 0):
    """Sharded CSR with a leading [num_shards] axis (1 included — the
    engine always expects the shard axis)."""
    n, avg, alpha = DATASETS[name]
    indptr, indices = make_powerlaw_graph(n, avg, alpha, seed)
    return n, shard_csr(indptr, indices, num_shards)
