"""Delta-based k-means clustering (paper Ex.2, Listing 3, Fig 5).

The mutable set is the point→centroid assignment; the Δᵢ set is the points
that *switched* centroids this stratum (paper Fig 3).  The paper's KMAgg
handler emits, per switched point, an adjustment delta ``(cid, +x, +y, +1)``
for the new centroid and ``(oldCid, −x, −y, −1)`` for the old one — the
centroid's (sum, count) state is *incrementally* maintained rather than
recomputed.  KMSampleAgg seeds centroids by sampling point coordinates.

Wire model: switched-point deltas are pre-aggregated per centroid (the §5.2
combiner) before the cross-shard reduction; the no-delta mode ships every
point's assignment record every stratum (the MapReduce shuffle the paper
compares against — Hadoop re-shuffles all N points per iteration, which is
why Fig 5 shows a ~100× gap).

Centroids are replicated on every shard (k is small); the cross-shard
combine of (sum_x, sum_y, count) adjustments is a ``psum`` in SPMD — here
expressed as a sum over the stacked shard axis (identical arithmetic).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.fixpoint import (FixpointResult, StratumOutcome, run_strata)

BYTES_PER_DELTA = 16          # cid:int32 + x:f32 + y:f32 + count:f32
BYTES_PER_POINT_RECORD = 16   # what a MapReduce shuffle ships per point


class KMState(NamedTuple):
    assign: jax.Array   # int32[S, block]  — current centroid per point
    sums: jax.Array     # f32[k, 2]        — Σ coords per centroid (replicated)
    counts: jax.Array   # f32[k]           — points per centroid (replicated)


def assign_points(points: jax.Array, centroids: jax.Array) -> jax.Array:
    """Nearest centroid per point: ‖p‖² − 2p·cᵀ + ‖c‖² argmin (MXU form).

    points f32[..., 2]; centroids f32[k, 2] -> int32[...].
    kernels/kmeans_assign provides the blocked Pallas version of this
    contract; this is the reference used by the engine on CPU.
    """
    d2 = (jnp.sum(points ** 2, -1, keepdims=True)
          - 2.0 * points @ centroids.T
          + jnp.sum(centroids ** 2, -1))
    return jnp.argmin(d2, axis=-1).astype(jnp.int32)


def centroids_of(state: KMState) -> jax.Array:
    return state.sums / jnp.maximum(state.counts, 1.0)[:, None]


def _segment_sums(points, assign, valid, k):
    """Per-centroid (Σx, Σy, n) over the masked points: f32[k, 3]."""
    w = valid.astype(points.dtype)
    data = jnp.concatenate([points * w[:, None], w[:, None]], axis=-1)
    idx = jnp.where(valid, assign, k)
    return jnp.zeros((k + 1, 3), points.dtype).at[idx].add(
        data, mode="drop")[:k]


def initial_state(points_sharded: jax.Array, init_centroids: jax.Array,
                  valid: Optional[jax.Array] = None) -> KMState:
    """Base-case stratum: assign every (valid) point once, build sums."""
    S, block, _ = points_sharded.shape
    k = init_centroids.shape[0]
    if valid is None:
        valid = jnp.ones((S, block), jnp.bool_)
    assign0 = jax.vmap(assign_points, in_axes=(0, None))(
        points_sharded, init_centroids)
    seg0 = jnp.sum(jax.vmap(_segment_sums, in_axes=(0, 0, 0, None))(
        points_sharded, assign0, valid, k), axis=0)          # psum in SPMD
    return KMState(assign=assign0, sums=seg0[:, :2], counts=seg0[:, 2])


def make_stratum(points_sharded: jax.Array, k: int, mode: str = "delta",
                 valid: Optional[jax.Array] = None):
    """One Lloyd stratum over a (possibly masked) point set.

    ``valid`` masks out dead point slots — the incremental view subsystem
    keeps a fixed-capacity point array and toggles slots on insert/remove,
    so shapes stay static across refreshes.  Invalid slots never switch and
    never contribute to centroid sums.
    """
    if mode not in ("delta", "nodelta"):
        raise ValueError(mode)
    S, block, _ = points_sharded.shape
    if valid is None:
        valid = jnp.ones((S, block), jnp.bool_)
    n_points = jnp.sum(valid.astype(jnp.int32))

    def stratum(state: KMState, stratum_idx):
        cents = centroids_of(state)
        new_assign = jax.vmap(assign_points, in_axes=(0, None))(
            points_sharded, cents)
        new_assign = jnp.where(valid, new_assign, state.assign)
        switched = (new_assign != state.assign) & valid
        n_switched = jnp.sum(switched.astype(jnp.int32))     # psum in SPMD

        if mode == "delta":
            # KMAgg: +(x,y,1) to the new centroid, −(x,y,1) from the old —
            # pre-aggregated per centroid locally before the reduction.
            plus = jax.vmap(_segment_sums, in_axes=(0, 0, 0, None))(
                points_sharded, new_assign, switched, k)
            minus = jax.vmap(_segment_sums, in_axes=(0, 0, 0, None))(
                points_sharded, state.assign, switched, k)
            adj = jnp.sum(plus - minus, axis=0)              # psum in SPMD
            sums = state.sums + adj[:, :2]
            counts = state.counts + adj[:, 2]
            bytes_moved = (2 * n_switched * BYTES_PER_DELTA).astype(
                jnp.float32)
            used_dense = jnp.asarray(False)
        else:
            seg = jnp.sum(jax.vmap(_segment_sums, in_axes=(0, 0, 0, None))(
                points_sharded, new_assign, valid, k), axis=0)
            sums, counts = seg[:, :2], seg[:, 2]
            bytes_moved = (n_points * BYTES_PER_POINT_RECORD).astype(
                jnp.float32)
            used_dense = jnp.asarray(True)

        new_state = KMState(assign=new_assign, sums=sums, counts=counts)
        return new_state, StratumOutcome(
            live_count=n_switched, used_dense=used_dense,
            rehash_bytes=bytes_moved, emitted=n_switched)

    return stratum


def run(points_sharded: jax.Array, init_centroids: jax.Array,
        mode: str = "delta", max_iters: int = 60,
        valid: Optional[jax.Array] = None) -> tuple[
            jax.Array, FixpointResult]:
    """points_sharded f32[S, block, 2]; init_centroids f32[k, 2].

    Returns (final centroids, FixpointResult with per-stratum stats).
    """
    k = init_centroids.shape[0]
    state0 = initial_state(points_sharded, init_centroids, valid)
    stratum = make_stratum(points_sharded, k, mode, valid)
    res = run_strata(stratum, state0, jnp.asarray(1, jnp.int32), max_iters)
    return centroids_of(res.state), res


def resume(points_sharded: jax.Array, state: KMState, max_iters: int = 60,
           mode: str = "delta", valid: Optional[jax.Array] = None
           ) -> tuple[jax.Array, FixpointResult]:
    """Resume Lloyd iteration from a warm (repaired) KMState.

    The incremental k-means rule nudges (sums, counts, assign) for the
    inserted/removed points, then calls this to re-converge; the first
    stratum re-checks every valid point's assignment against the nudged
    centroids, so the live count self-corrects to zero when the nudge was
    already a fixpoint."""
    k = state.sums.shape[0]
    stratum = make_stratum(points_sharded, k, mode, valid)
    res = run_strata(stratum, state, jnp.asarray(1, jnp.int32), max_iters)
    return centroids_of(res.state), res


def reference_kmeans(points: jnp.ndarray, init_centroids: jnp.ndarray,
                     max_iters: int = 60) -> jnp.ndarray:
    """Lloyd-iteration oracle over the flat point set."""
    import numpy as np
    pts = np.asarray(points, np.float32).reshape(-1, 2)
    cents = np.asarray(init_centroids, np.float32).copy()
    assign = None
    for _ in range(max_iters):
        d2 = ((pts[:, None, :] - cents[None, :, :]) ** 2).sum(-1)
        new_assign = d2.argmin(1)
        if assign is not None and (new_assign == assign).all():
            break
        assign = new_assign
        for c in range(cents.shape[0]):
            sel = pts[assign == c]
            if len(sel):
                cents[c] = sel.mean(0)
    return jnp.asarray(cents)
