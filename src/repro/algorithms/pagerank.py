"""Delta-based PageRank (paper §1 Ex.1, §3.5, Listing 1, Figs 2/6/8).

Fixpoint: ``pr(v) = 0.15 + 0.85 * Σ_{u→v} sent(u) / outdeg(u)``.

Delta formulation (the paper's PRAgg handler): every vertex tracks the value
it last *propagated* (``sent``) and its accumulated incoming mass (``acc``).
A vertex is in the Δᵢ set when its current value ``pr = 0.15 + 0.85·acc``
differs from ``sent`` by more than the threshold; it then emits
``(pr − sent)/outdeg`` along each out-edge (the paper's
``deltaPr/nbrBucket.size()``) and records ``sent ← pr``.  Receivers fold the
adjustment deltas (δ(E), arithmetic-sum semantics) into ``acc``.

The no-delta mode re-derives every vertex's full contribution each stratum
(Hadoop/HaLoop behaviour): contributions are *replaced*, not adjusted.

Both modes converge to the same fixpoint (property-tested); the delta mode
does O(|Δᵢ| edges) work and moves O(|Δᵢ|) bytes per stratum.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.algorithms import emission
from repro.core.delta import DeltaBuffer
from repro.core.engine import DeltaAlgorithm, ShardedExecutor
from repro.core.fixpoint import FixpointResult
from repro.core.partition import PartitionSnapshot, shard_dense_state
from repro.data.graphs import CSRGraph

DAMPING = 0.85
BASE = 0.15


class PRState(NamedTuple):
    acc: jax.Array    # f32[block] — accumulated incoming mass Σ sent(u)/deg(u)
    sent: jax.Array   # f32[block] — value last propagated to neighbors


def current_pr(state: PRState) -> jax.Array:
    return BASE + DAMPING * state.acc


def make_algorithm(snapshot: PartitionSnapshot, threshold: float = 1e-3,
                   src_capacity: int = 1024, edge_capacity: int = 16384
                   ) -> DeltaAlgorithm:
    block = snapshot.block_size

    def active_fn(state: PRState, graph: CSRGraph):
        diff = jnp.abs(current_pr(state) - state.sent)
        active = diff > threshold
        est_edges = jnp.sum(jnp.where(active, graph.out_degree, 0))
        return active, est_edges

    def make_sparse_emit(src_cap: int, edge_cap: int):
        def sparse_emit(state: PRState, graph: CSRGraph, active, stratum,
                        shard_id):
            pr = current_pr(state)
            deg = jnp.maximum(graph.out_degree, 1).astype(pr.dtype)
            payload = jnp.where(active, (pr - state.sent) / deg, 0.0)
            out = emission.emit_over_edges(graph, active, payload,
                                           src_cap, edge_cap)
            # sent <- pr for the sources whose diff we just shipped.
            new_sent = jnp.where(active, pr, state.sent)
            return PRState(acc=state.acc, sent=new_sent), out
        return sparse_emit

    sparse_emit = make_sparse_emit(src_capacity, edge_capacity)

    def dense_emit(state: PRState, graph: CSRGraph, stratum, shard_id):
        pr = current_pr(state)
        deg = jnp.maximum(graph.out_degree, 1).astype(pr.dtype)
        dst, payload = emission.dense_push(graph, pr / deg)
        n_padded = snapshot.padded_keys
        contrib = jnp.zeros((n_padded + 1,), payload.dtype).at[
            jnp.where(dst >= 0, dst, n_padded)].add(
            payload, mode="drop")[:n_padded]
        # Dense strata REPLACE acc, so sent must reflect the full pr pushed.
        return PRState(acc=state.acc, sent=pr), contrib[:, None]

    def apply_sparse(state: PRState, incoming: DeltaBuffer, graph: CSRGraph,
                     stratum, shard_id):
        inc = emission.scatter_local(incoming, shard_id, block, "add")
        acc = state.acc + inc
        new_state = PRState(acc=acc, sent=state.sent)
        diff = jnp.abs(current_pr(new_state) - new_state.sent)
        return new_state, jnp.sum((diff > threshold).astype(jnp.int32))

    def apply_dense(state: PRState, incoming: jax.Array, graph: CSRGraph,
                    stratum, shard_id):
        acc = incoming[:, 0]                  # full replacement semantics
        new_state = PRState(acc=acc, sent=state.sent)
        diff = jnp.abs(current_pr(new_state) - new_state.sent)
        return new_state, jnp.sum((diff > threshold).astype(jnp.int32))

    return DeltaAlgorithm(
        active_fn=active_fn, sparse_emit=sparse_emit, dense_emit=dense_emit,
        apply_sparse=apply_sparse, apply_dense=apply_dense,
        combiner="add", payload_width=1, bytes_per_delta=8,
        emit_factory=make_sparse_emit)


def initial_state(snapshot: PartitionSnapshot) -> PRState:
    """Δ₀ = every vertex (sent=0, so pr₀ = 0.15 must propagate)."""
    z = jnp.zeros((snapshot.num_shards, snapshot.block_size), jnp.float32)
    return PRState(acc=z, sent=z)


def run(graph_sharded: CSRGraph, snapshot: PartitionSnapshot,
        mode: str = "delta", threshold: float = 1e-3, max_iters: int = 60,
        executor: Optional[ShardedExecutor] = None,
        src_capacity: int = 1024, edge_capacity: int = 16384,
        ladder_tiers: int = 1, route_strategy: str = "sort"
        ) -> tuple[jax.Array, FixpointResult]:
    """Run PageRank; returns (pr values [padded_keys], FixpointResult)."""
    algo = make_algorithm(snapshot, threshold, src_capacity, edge_capacity)
    if executor is None:
        executor = ShardedExecutor(
            snapshot=snapshot, seg_capacity=edge_capacity,
            edge_capacity=edge_capacity, src_capacity=src_capacity,
            ladder_tiers=ladder_tiers, route_strategy=route_strategy)
    state0 = initial_state(snapshot)
    live0 = snapshot.padded_keys
    res = executor.run(algo, state0, live0, graph_sharded, max_iters,
                       mode=mode)
    state = res.state
    pr = current_pr(PRState(*state)).reshape(-1)
    return pr, res


def reference_pagerank(indptr, indices, n: int, iters: int = 100
                       ) -> jnp.ndarray:
    """Dense NumPy-style oracle: pr = 0.15 + 0.85 Σ pr(u)/deg(u)."""
    import numpy as np
    deg = np.maximum(np.diff(indptr), 1)
    pr = np.full(n, BASE, np.float64)
    src_of_edge = np.repeat(np.arange(n), np.diff(indptr))
    for _ in range(iters):
        contrib = np.zeros(n, np.float64)
        np.add.at(contrib, indices, pr[src_of_edge] / deg[src_of_edge])
        pr = BASE + DAMPING * contrib
    return jnp.asarray(pr.astype(np.float32))
