"""Delta-based adsorption / label propagation (paper Fig 3, row 2).

Each vertex carries an L-dimensional label distribution.  Seeded vertices
inject their own label; every vertex's vector is the damped average of its
in-neighbors' vectors plus its injection:

    vec(v) = inj·seed(v) + (1 − inj) · Σ_{u→v} sent(u) / outdeg(u)

The Δᵢ set is "adsorption vector positions with change ≥ 1% since iteration
i−1" — we track per-vertex L∞ change of the whole vector (a vertex re-emits
when any position moved past the threshold), matching the per-position
criterion at vector granularity.  Payloads are W=L columns; everything else
is the PageRank pattern with vector deltas.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.algorithms import emission
from repro.core.delta import DeltaBuffer
from repro.core.engine import DeltaAlgorithm, ShardedExecutor
from repro.core.fixpoint import FixpointResult
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import CSRGraph

INJECTION = 0.25


class AdsorptionState(NamedTuple):
    acc: jax.Array    # f32[block, L] — accumulated incoming mass
    sent: jax.Array   # f32[block, L] — vector last propagated
    seed: jax.Array   # f32[block, L] — injected label (immutable per run)


def current_vec(state: AdsorptionState) -> jax.Array:
    return INJECTION * state.seed + (1.0 - INJECTION) * state.acc


def make_algorithm(snapshot: PartitionSnapshot, n_labels: int,
                   threshold: float = 1e-2, src_capacity: int = 1024,
                   edge_capacity: int = 16384) -> DeltaAlgorithm:
    block = snapshot.block_size

    def active_fn(state: AdsorptionState, graph: CSRGraph):
        diff = jnp.max(jnp.abs(current_vec(state) - state.sent), axis=-1)
        active = diff > threshold
        est_edges = jnp.sum(jnp.where(active, graph.out_degree, 0))
        return active, est_edges

    def make_sparse_emit(src_cap: int, edge_cap: int):
        def sparse_emit(state, graph, active, stratum, shard_id):
            vec = current_vec(state)
            deg = jnp.maximum(graph.out_degree, 1).astype(vec.dtype)[:, None]
            payload = jnp.where(active[:, None], (vec - state.sent) / deg,
                                0.0)
            out = emission.emit_over_edges_vec(graph, active, payload,
                                               src_cap, edge_cap)
            new_sent = jnp.where(active[:, None], vec, state.sent)
            return AdsorptionState(state.acc, new_sent, state.seed), out
        return sparse_emit

    sparse_emit = make_sparse_emit(src_capacity, edge_capacity)

    def dense_emit(state, graph, stratum, shard_id):
        vec = current_vec(state)
        deg = jnp.maximum(graph.out_degree, 1).astype(vec.dtype)
        n_padded = snapshot.padded_keys
        L = vec.shape[-1]
        # Full push: every source contributes vec/deg along every edge.
        nnz = graph.nnz_capacity
        slots = jnp.arange(nnz, dtype=jnp.int32)
        src = jnp.clip(jnp.searchsorted(graph.indptr.astype(jnp.int32),
                                        slots, side="right") - 1,
                       0, block - 1)
        dst = graph.indices
        valid = dst >= 0
        per_edge = jnp.where(valid[:, None], vec[src] / deg[src, None], 0.0)
        contrib = jnp.zeros((n_padded + 1, L), vec.dtype).at[
            jnp.where(valid, dst, n_padded)].add(
            per_edge, mode="drop")[:n_padded]
        return AdsorptionState(state.acc, vec, state.seed), contrib

    def apply_sparse(state, incoming: DeltaBuffer, graph, stratum, shard_id):
        inc = emission.scatter_local_vec(incoming, shard_id, block)
        acc = state.acc + inc
        new_state = AdsorptionState(acc, state.sent, state.seed)
        diff = jnp.max(jnp.abs(current_vec(new_state) - new_state.sent), -1)
        return new_state, jnp.sum((diff > threshold).astype(jnp.int32))

    def apply_dense(state, incoming, graph, stratum, shard_id):
        new_state = AdsorptionState(incoming, state.sent, state.seed)
        diff = jnp.max(jnp.abs(current_vec(new_state) - new_state.sent), -1)
        return new_state, jnp.sum((diff > threshold).astype(jnp.int32))

    return DeltaAlgorithm(
        active_fn=active_fn, sparse_emit=sparse_emit, dense_emit=dense_emit,
        apply_sparse=apply_sparse, apply_dense=apply_dense,
        combiner="add", payload_width=n_labels,
        bytes_per_delta=4 + 4 * n_labels, emit_factory=make_sparse_emit)


def initial_state(snapshot: PartitionSnapshot, seeds: jax.Array
                  ) -> AdsorptionState:
    """seeds: f32[padded_keys, L] one-hot (or zero) injection vectors."""
    S, block = snapshot.num_shards, snapshot.block_size
    L = seeds.shape[-1]
    seed = seeds.reshape(S, block, L)
    z = jnp.zeros((S, block, L), jnp.float32)
    return AdsorptionState(acc=z, sent=z, seed=seed)


def run(graph_sharded: CSRGraph, snapshot: PartitionSnapshot,
        seeds: jax.Array, mode: str = "delta", threshold: float = 1e-2,
        max_iters: int = 50, executor: Optional[ShardedExecutor] = None,
        src_capacity: int = 1024, edge_capacity: int = 16384,
        ladder_tiers: int = 1) -> tuple[jax.Array, FixpointResult]:
    n_labels = seeds.shape[-1]
    algo = make_algorithm(snapshot, n_labels, threshold, src_capacity,
                          edge_capacity)
    if executor is None:
        executor = ShardedExecutor(
            snapshot=snapshot, seg_capacity=edge_capacity,
            edge_capacity=edge_capacity, src_capacity=src_capacity,
            ladder_tiers=ladder_tiers)
    state0 = initial_state(snapshot, seeds)
    res = executor.run(algo, state0, snapshot.padded_keys, graph_sharded,
                       max_iters, mode=mode)
    state = AdsorptionState(*res.state)
    vec = current_vec(state).reshape(-1, n_labels)
    return vec, res
