"""Connected components by min-label propagation (delta form).

Not one of the paper's three benchmark algorithms, but the canonical extra
member of its Δᵢ-set family (same shape as Fig 3's shortest-path row): the
mutable set is each vertex's component label, the Δᵢ set is the vertices
whose label decreased since last propagation.  Reuses the SSSP machinery
with label payloads instead of distances: fixpoint
``label(v) = min(label(v), min_{u→v} label(u))``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.algorithms import emission
from repro.core.delta import DeltaBuffer
from repro.core.engine import DeltaAlgorithm, ShardedExecutor
from repro.core.fixpoint import FixpointResult
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import CSRGraph


class CCState(NamedTuple):
    label: jax.Array  # f32[block] — current component label (vertex ids)
    sent: jax.Array   # f32[block] — label last propagated


def make_algorithm(snapshot: PartitionSnapshot, src_capacity: int = 1024,
                   edge_capacity: int = 16384) -> DeltaAlgorithm:
    block = snapshot.block_size

    def active_fn(state: CCState, graph: CSRGraph):
        active = state.label < state.sent
        est_edges = jnp.sum(jnp.where(active, graph.out_degree, 0))
        return active, est_edges

    def make_sparse_emit(src_cap: int, edge_cap: int):
        def sparse_emit(state, graph, active, stratum, shard_id):
            payload = jnp.where(active, state.label, jnp.inf)
            out = emission.emit_over_edges(graph, active, payload,
                                           src_cap, edge_cap)
            new_sent = jnp.where(active, state.label, state.sent)
            return CCState(label=state.label, sent=new_sent), out
        return sparse_emit

    sparse_emit = make_sparse_emit(src_capacity, edge_capacity)

    def dense_emit(state, graph, stratum, shard_id):
        dst, pay = emission.dense_push(graph, state.label)
        pay = jnp.where(dst >= 0, pay, jnp.inf)
        n_padded = snapshot.padded_keys
        contrib = jnp.full((n_padded + 1,), jnp.inf, pay.dtype).at[
            jnp.where(dst >= 0, dst, n_padded)].min(
            pay, mode="drop")[:n_padded]
        return CCState(label=state.label, sent=state.label), contrib[:, None]

    def apply_sparse(state, incoming: DeltaBuffer, graph, stratum, shard_id):
        inc = emission.scatter_local(incoming, shard_id, block, "min")
        label = jnp.minimum(state.label, inc)
        new_state = CCState(label=label, sent=state.sent)
        return new_state, jnp.sum((label < state.sent).astype(jnp.int32))

    def apply_dense(state, incoming, graph, stratum, shard_id):
        label = jnp.minimum(state.label, incoming[:, 0])
        new_state = CCState(label=label, sent=state.sent)
        return new_state, jnp.sum((label < state.sent).astype(jnp.int32))

    return DeltaAlgorithm(
        active_fn=active_fn, sparse_emit=sparse_emit, dense_emit=dense_emit,
        apply_sparse=apply_sparse, apply_dense=apply_dense,
        combiner="min", payload_width=1, bytes_per_delta=8,
        emit_factory=make_sparse_emit)


def initial_state(snapshot: PartitionSnapshot) -> CCState:
    S, block = snapshot.num_shards, snapshot.block_size
    ids = jnp.arange(S * block, dtype=jnp.float32).reshape(S, block)
    return CCState(label=ids, sent=jnp.full((S, block), jnp.inf, jnp.float32))


def run(graph_sharded: CSRGraph, snapshot: PartitionSnapshot,
        mode: str = "delta", max_iters: int = 80,
        executor: Optional[ShardedExecutor] = None,
        src_capacity: int = 1024, edge_capacity: int = 16384,
        ladder_tiers: int = 1) -> tuple[jax.Array, FixpointResult]:
    algo = make_algorithm(snapshot, src_capacity, edge_capacity)
    if executor is None:
        executor = ShardedExecutor(
            snapshot=snapshot, seg_capacity=edge_capacity,
            edge_capacity=edge_capacity, src_capacity=src_capacity,
            ladder_tiers=ladder_tiers)
    state0 = initial_state(snapshot)
    res = executor.run(algo, state0, snapshot.padded_keys, graph_sharded,
                       max_iters, mode=mode)
    label = CCState(*res.state).label.reshape(-1)
    return label, res


def reference_components(indptr, indices, n: int) -> jnp.ndarray:
    """Union-find oracle over the undirected view... the propagation model is
    DIRECTED min-label (labels flow along edge direction only), so the oracle
    iterates the same fixpoint densely."""
    import numpy as np
    label = np.arange(n, dtype=np.float64)
    src_of_edge = np.repeat(np.arange(n), np.diff(indptr))
    for _ in range(n):  # worst-case diameter
        contrib = np.full(n, np.inf)
        np.minimum.at(contrib, indices, label[src_of_edge])
        new = np.minimum(label, contrib)
        if (new == label).all():
            break
        label = new
    return jnp.asarray(label.astype(np.float32))
