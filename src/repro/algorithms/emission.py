"""Sparse delta emission over CSR adjacency.

This is the TPU realization of the paper's join-handler emission (PRAgg /
SPAgg ``update`` returning a ``resBag`` of per-neighbor deltas): for the set
of *active* sources, walk their out-edges and emit one delta per edge.

The work must be O(|Δ| edges), not O(|E|) — that is the whole point of REX.
With static shapes we achieve it by giving the stratum an *edge-slot budget*
``edge_capacity``:

  1. compact active sources into a list (≤ ``src_capacity``),
  2. prefix-sum their degrees,
  3. map each edge slot e ∈ [0, edge_capacity) to (source rank, offset)
     by binary search over the prefix sums,
  4. gather destination + payload per slot.

If the active sources' total degree exceeds the budget the stratum reports
overflow and the fixpoint driver re-runs it densely (core/fixpoint.py).
The pure-jnp path below is the oracle; kernels/edge_propagate provides the
Pallas TPU kernel of the same contract.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.delta import ANN_ADJUST, PAD_KEY, DeltaBuffer
from repro.data.graphs import CSRGraph


def emit_over_edges(graph: CSRGraph, active_mask: jax.Array,
                    payload_of_src: jax.Array, src_capacity: int,
                    edge_capacity: int) -> DeltaBuffer:
    """Emit one delta per out-edge of each active source.

    graph           — local CSR shard (indptr[int32; B+1], indices global).
    active_mask     — bool[B] over local sources.
    payload_of_src  — f32[B]: per-edge payload emitted by source v (already
                      divided by degree etc. by the caller).
    Returns a DeltaBuffer with capacity ``edge_capacity`` keyed by GLOBAL
    destination vertex.  ``overflowed`` is set when either the active-source
    list or the edge budget is exceeded.
    """
    B = active_mask.shape[0]
    # 1. Compact the active sources.
    src_db = DeltaBuffer.from_dense_mask(
        active_mask, jnp.arange(B, dtype=jnp.int32),
        payload_of_src[:, None], src_capacity)
    src_idx = jnp.clip(src_db.keys, 0, B - 1)
    live_src = src_db.keys != PAD_KEY
    # 2. Degrees + prefix sums of the compacted sources.
    deg = jnp.where(live_src, graph.indptr[src_idx + 1] - graph.indptr[src_idx],
                    0)
    starts = jnp.concatenate(
        [jnp.zeros((1,), deg.dtype), jnp.cumsum(deg)])  # [src_capacity + 1]
    total_edges = starts[-1]
    # 3. Edge slot -> (source rank, offset) via binary search.
    slots = jnp.arange(edge_capacity, dtype=starts.dtype)
    owner = jnp.searchsorted(starts, slots, side="right") - 1
    owner = jnp.clip(owner, 0, src_capacity - 1)
    offset = slots - starts[owner]
    valid = slots < total_edges
    # 4. Gather destination + payload.
    src_local = src_idx[owner]
    pos = graph.indptr[src_local].astype(slots.dtype) + offset
    pos = jnp.clip(pos, 0, graph.nnz_capacity - 1).astype(jnp.int32)
    dst = graph.indices[pos]
    valid = valid & (dst >= 0)
    payload = src_db.payload[owner, 0]
    return DeltaBuffer(
        keys=jnp.where(valid, dst, PAD_KEY),
        payload=jnp.where(valid, payload, 0.0)[:, None],
        ann=jnp.full((edge_capacity,), ANN_ADJUST, jnp.int8),
        count=jnp.sum(valid.astype(jnp.int32)),
        overflowed=src_db.overflowed | (total_edges > edge_capacity),
    )


def dense_push(graph: CSRGraph, payload_of_src: jax.Array) -> jax.Array:
    """Dense analogue: every source pushes payload along ALL its edges;
    returns the per-destination accumulated mass as a global-keyed dense
    contribution computed via a full edge scan (O(|E|)).

    Used by the nodelta baseline and the overflow fallback.  Output is
    (dst_global_keys[int32; nnz_cap], per_edge_payload[f32; nnz_cap]) folded
    into a dense accumulator by the caller — here we return the per-edge
    arrays so callers with different key spaces can scatter themselves.
    """
    nnz = graph.nnz_capacity
    B = graph.n_src
    # source id of each edge slot: searchsorted over indptr
    slots = jnp.arange(nnz, dtype=jnp.int32)
    src = jnp.searchsorted(graph.indptr.astype(jnp.int32), slots,
                           side="right") - 1
    src = jnp.clip(src, 0, B - 1)
    dst = graph.indices
    valid = dst >= 0
    payload = jnp.where(valid, payload_of_src[src], 0.0)
    return jnp.where(valid, dst, -1), payload


def emit_over_edges_vec(graph: CSRGraph, active_mask: jax.Array,
                        payload_of_src: jax.Array, src_capacity: int,
                        edge_capacity: int) -> DeltaBuffer:
    """Vector-payload variant of :func:`emit_over_edges`.

    payload_of_src: f32[B, W] — W-column payload per source (adsorption
    ships whole label-distribution diffs; paper Fig 3 row 2).
    """
    B, W = payload_of_src.shape
    src_db = DeltaBuffer.from_dense_mask(
        active_mask, jnp.arange(B, dtype=jnp.int32), payload_of_src,
        src_capacity)
    src_idx = jnp.clip(src_db.keys, 0, B - 1)
    live_src = src_db.keys != PAD_KEY
    deg = jnp.where(live_src,
                    graph.indptr[src_idx + 1] - graph.indptr[src_idx], 0)
    starts = jnp.concatenate([jnp.zeros((1,), deg.dtype), jnp.cumsum(deg)])
    total_edges = starts[-1]
    slots = jnp.arange(edge_capacity, dtype=starts.dtype)
    owner = jnp.searchsorted(starts, slots, side="right") - 1
    owner = jnp.clip(owner, 0, src_capacity - 1)
    offset = slots - starts[owner]
    valid = slots < total_edges
    src_local = src_idx[owner]
    pos = graph.indptr[src_local].astype(slots.dtype) + offset
    pos = jnp.clip(pos, 0, graph.nnz_capacity - 1).astype(jnp.int32)
    dst = graph.indices[pos]
    valid = valid & (dst >= 0)
    payload = src_db.payload[owner]                        # [E, W]
    return DeltaBuffer(
        keys=jnp.where(valid, dst, PAD_KEY),
        payload=jnp.where(valid[:, None], payload, 0.0),
        ann=jnp.full((edge_capacity,), ANN_ADJUST, jnp.int8),
        count=jnp.sum(valid.astype(jnp.int32)),
        overflowed=src_db.overflowed | (total_edges > edge_capacity),
    )


def scatter_local_vec(db: DeltaBuffer, shard_id: jax.Array, block: int
                      ) -> jax.Array:
    """Vector add-scatter of an incoming buffer: returns f32[block, W]."""
    local = to_local_keys(db, shard_id, block)
    mask = (local >= 0) & (local < block)
    idx = jnp.where(mask, local, block)
    vals = jnp.where(mask[:, None], db.payload, 0.0)
    return jnp.zeros((block + 1, db.payload_width), db.payload.dtype).at[
        idx].add(vals, mode="drop")[:block]


def to_local_keys(db: DeltaBuffer, shard_id: jax.Array, block: int
                  ) -> jax.Array:
    """Global → local key conversion under the block partition scheme."""
    local = db.keys - shard_id * block
    return jnp.where(db.keys == PAD_KEY, -1, local)


def scatter_local(db: DeltaBuffer, shard_id: jax.Array, block: int,
                  combiner: str = "add") -> jax.Array:
    """Scatter an incoming (post-rehash) delta buffer into a dense local
    block using the requested combiner; returns f32[block]."""
    local = to_local_keys(db, shard_id, block)
    mask = (local >= 0) & (local < block)
    idx = jnp.where(mask, local, block)
    if combiner == "add":
        vals = jnp.where(mask, db.payload[:, 0], 0.0)
        return jnp.zeros((block + 1,), db.payload.dtype).at[idx].add(
            vals, mode="drop")[:block]
    if combiner == "min":
        vals = jnp.where(mask, db.payload[:, 0], jnp.inf)
        return jnp.full((block + 1,), jnp.inf, db.payload.dtype).at[idx].min(
            vals, mode="drop")[:block]
    if combiner == "max":
        vals = jnp.where(mask, db.payload[:, 0], -jnp.inf)
        return jnp.full((block + 1,), -jnp.inf,
                        db.payload.dtype).at[idx].max(
            vals, mode="drop")[:block]
    raise ValueError(combiner)
