"""Delta-based single-source shortest path (paper Listing 2, Figs 7/9).

Fixpoint: ``dist(v) = min(dist(v), min_{u→v} dist(u) + 1)`` (unweighted, as
in the paper's DBPedia/Twitter experiments; a weighted variant only changes
the payload).

Delta formulation (the paper's SPAgg handler): a vertex is in the Δᵢ set —
the *frontier* — when its distance improved since it last propagated.  It
emits ``dist+1`` to each out-neighbor; receivers fold with a min-combiner.
This is exactly the paper's "frontier set" observation: Δᵢ is the BFS
frontier, expanding one hop per stratum.

No-delta re-relaxes EVERY settled vertex each stratum (the Hadoop/HaLoop
behaviour even with relation-level Δ updates the paper grants them).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.algorithms import emission
from repro.core.delta import DeltaBuffer
from repro.core.engine import DeltaAlgorithm, ShardedExecutor
from repro.core.fixpoint import FixpointResult
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import CSRGraph

INF = jnp.float32(jnp.inf)


class SPState(NamedTuple):
    dist: jax.Array  # f32[block] — current best distance
    sent: jax.Array  # f32[block] — distance last propagated (inf = never)


def make_algorithm(snapshot: PartitionSnapshot, src_capacity: int = 1024,
                   edge_capacity: int = 16384) -> DeltaAlgorithm:
    block = snapshot.block_size

    def active_fn(state: SPState, graph: CSRGraph):
        active = state.dist < state.sent          # improved since last send
        est_edges = jnp.sum(jnp.where(active, graph.out_degree, 0))
        return active, est_edges

    def make_sparse_emit(src_cap: int, edge_cap: int):
        def sparse_emit(state: SPState, graph: CSRGraph, active, stratum,
                        shard_id):
            payload = jnp.where(active, state.dist + 1.0, INF)
            out = emission.emit_over_edges(graph, active, payload,
                                           src_cap, edge_cap)
            new_sent = jnp.where(active, state.dist, state.sent)
            return SPState(dist=state.dist, sent=new_sent), out
        return sparse_emit

    sparse_emit = make_sparse_emit(src_capacity, edge_capacity)

    def dense_emit(state: SPState, graph: CSRGraph, stratum, shard_id):
        reachable = state.dist < INF
        payload = jnp.where(reachable, state.dist + 1.0, INF)
        dst, pay = emission.dense_push(graph, payload)
        # dense_push zeroes invalid payload slots; min-combine needs +inf.
        pay = jnp.where(dst >= 0, pay, INF)
        n_padded = snapshot.padded_keys
        contrib = jnp.full((n_padded + 1,), INF, pay.dtype).at[
            jnp.where(dst >= 0, dst, n_padded)].min(
            pay, mode="drop")[:n_padded]
        return SPState(dist=state.dist, sent=state.dist), contrib[:, None]

    def apply_sparse(state: SPState, incoming: DeltaBuffer, graph: CSRGraph,
                     stratum, shard_id):
        inc = emission.scatter_local(incoming, shard_id, block, "min")
        dist = jnp.minimum(state.dist, inc)
        new_state = SPState(dist=dist, sent=state.sent)
        return new_state, jnp.sum((dist < state.sent).astype(jnp.int32))

    def apply_dense(state: SPState, incoming: jax.Array, graph: CSRGraph,
                    stratum, shard_id):
        dist = jnp.minimum(state.dist, incoming[:, 0])
        new_state = SPState(dist=dist, sent=state.sent)
        return new_state, jnp.sum((dist < state.sent).astype(jnp.int32))

    return DeltaAlgorithm(
        active_fn=active_fn, sparse_emit=sparse_emit, dense_emit=dense_emit,
        apply_sparse=apply_sparse, apply_dense=apply_dense,
        combiner="min", payload_width=1, bytes_per_delta=8,
        emit_factory=make_sparse_emit)


def initial_state(snapshot: PartitionSnapshot, source: int = 0) -> SPState:
    S, block = snapshot.num_shards, snapshot.block_size
    dist = jnp.full((S, block), INF, jnp.float32)
    owner = source // block
    dist = dist.at[owner, source % block].set(0.0)
    sent = jnp.full((S, block), INF, jnp.float32)
    return SPState(dist=dist, sent=sent)


def run(graph_sharded: CSRGraph, snapshot: PartitionSnapshot,
        source: int = 0, mode: str = "delta", max_iters: int = 80,
        executor: Optional[ShardedExecutor] = None,
        src_capacity: int = 1024, edge_capacity: int = 16384,
        ladder_tiers: int = 1, route_strategy: str = "sort"
        ) -> tuple[jax.Array, FixpointResult]:
    algo = make_algorithm(snapshot, src_capacity, edge_capacity)
    if executor is None:
        executor = ShardedExecutor(
            snapshot=snapshot, seg_capacity=edge_capacity,
            edge_capacity=edge_capacity, src_capacity=src_capacity,
            ladder_tiers=ladder_tiers, route_strategy=route_strategy)
    state0 = initial_state(snapshot, source)
    res = executor.run(algo, state0, 1, graph_sharded, max_iters, mode=mode)
    dist = SPState(*res.state).dist.reshape(-1)
    return dist, res


def reference_sssp(indptr, indices, n: int, source: int = 0) -> jnp.ndarray:
    """BFS oracle (unweighted shortest path)."""
    import collections

    import numpy as np
    dist = np.full(n, np.inf, np.float32)
    dist[source] = 0.0
    q = collections.deque([source])
    while q:
        u = q.popleft()
        for v in indices[indptr[u]:indptr[u + 1]]:
            if v >= 0 and dist[v] == np.inf:
                dist[v] = dist[u] + 1
                q.append(v)
    return jnp.asarray(dist)
