"""Delta-oriented implementations of the paper's algorithms (§3.5, §6,
appendix): PageRank, single-source shortest path, k-means clustering —
each in ``delta`` and ``nodelta`` (dense re-derivation) modes — plus
connected components and adsorption from the paper's Figure 3 table."""
