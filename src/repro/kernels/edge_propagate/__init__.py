from repro.kernels.edge_propagate.edge_propagate import edge_propagate
from repro.kernels.edge_propagate.ops import build_tiled_csc, propagate
from repro.kernels.edge_propagate.ref import edge_propagate_ref

__all__ = ["edge_propagate", "build_tiled_csc", "propagate",
           "edge_propagate_ref"]
