"""Pallas TPU kernel: fused edge propagation (join → rehash-local → group-by).

The REX hot loop is: for every (active) source u, push ``payload(u)·w(u,v)``
along each out-edge and accumulate per destination.  On a GPU this is a
gather + atomic-scatter over COO edges.  The TPU adaptation restructures it
around the memory hierarchy:

  * the graph is pre-tiled into **CSC (pull) form, grouped by destination
    tile** — a one-time cost on the *immutable set* (REX's key locality
    property: the graph never changes, so the tiling is amortized across all
    strata and queries);
  * the per-source payload vector stays **VMEM-resident** (one shard's block
    of the mutable set: ≤ ~1 Mi sources ⇒ ≤ 4 MiB — fits v5e's 16 MiB VMEM
    next to the tiles);
  * each grid instance (dst-tile t, edge-chunk c) gathers payload[src] for
    its chunk, scales by the edge weight, and folds into the output tile via
    a **one-hot MXU contraction** (add) or masked VPU reduction (min) —
    replacing atomics with dense deterministic compute.

Grid: (dst tiles ×parallel, edge chunks ×arbitrary).  Edge chunks are padded
(src = −1) to uniform length per tile; padding contributes the combiner
identity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 512
DEFAULT_CHUNK = 256


def _kernel(src_ref, dstl_ref, w_ref, payload_ref, out_ref, *, tile_n,
            combiner):
    c = pl.program_id(1)
    identity = {"add": 0.0, "min": jnp.inf, "max": -jnp.inf}[combiner]

    @pl.when(c == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref[...], identity)

    src = src_ref[0]                                      # int32[CHUNK]
    dstl = dstl_ref[0]                                    # int32[CHUNK]
    w = w_ref[0]                                          # f32[CHUNK]
    valid = src >= 0
    gathered = payload_ref[jnp.where(valid, src, 0)]      # f32[CHUNK]
    val = jnp.where(valid, gathered * w, identity)

    if combiner == "add":
        lanes = jax.lax.broadcasted_iota(jnp.int32,
                                         (tile_n, src.shape[0]), 0)
        onehot = (lanes == dstl[None, :]).astype(val.dtype)
        out_ref[...] += jax.lax.dot(
            onehot, val[:, None], preferred_element_type=jnp.float32)[:, 0]
    else:
        lanes = jax.lax.broadcasted_iota(jnp.int32,
                                         (src.shape[0], tile_n), 1)
        masked = jnp.where(lanes == dstl[:, None], val[:, None], identity)
        red = (jnp.min(masked, axis=0) if combiner == "min"
               else jnp.max(masked, axis=0))
        cur = out_ref[...]
        out_ref[...] = (jnp.minimum(cur, red) if combiner == "min"
                        else jnp.maximum(cur, red))


@functools.partial(jax.jit, static_argnames=("n_dst", "combiner", "tile_n",
                                              "chunk", "interpret"))
def edge_propagate(payload: jax.Array, src_idx: jax.Array,
                   dst_local: jax.Array, weight: jax.Array, n_dst: int,
                   combiner: str = "add", tile_n: int = DEFAULT_TILE_N,
                   chunk: int = DEFAULT_CHUNK, interpret: bool = True
                   ) -> jax.Array:
    """payload f32[N_src]; src_idx/dst_local int32[T, E_T]; weight f32[T, E_T]
    with T = n_dst // tile_n and E_T % chunk == 0.  Returns f32[n_dst]."""
    if n_dst % tile_n:
        raise ValueError(f"n_dst={n_dst} not a multiple of tile_n={tile_n}")
    t_tiles, e_t = src_idx.shape
    if t_tiles != n_dst // tile_n:
        raise ValueError("src_idx leading dim must be n_dst // tile_n")
    if e_t % chunk:
        raise ValueError(f"edge budget {e_t} not a multiple of chunk={chunk}")
    grid = (t_tiles, e_t // chunk)
    kernel = functools.partial(_kernel, tile_n=tile_n, combiner=combiner)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk), lambda t, c: (t, c)),
            pl.BlockSpec((1, chunk), lambda t, c: (t, c)),
            pl.BlockSpec((1, chunk), lambda t, c: (t, c)),
            pl.BlockSpec(payload.shape, lambda t, c: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda t, c: (t,)),
        out_shape=jax.ShapeDtypeStruct((n_dst,), payload.dtype),
        interpret=interpret,
    )(src_idx, dst_local, weight, payload)
