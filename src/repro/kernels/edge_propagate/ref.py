"""Pure-jnp oracle for edge_propagate."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def edge_propagate_ref(payload: jax.Array, src_idx: jax.Array,
                       dst_local: jax.Array, weight: jax.Array, n_dst: int,
                       combiner: str = "add", tile_n: int = 512
                       ) -> jax.Array:
    """Same contract as the kernel: tiled CSC edges, per-dst accumulation."""
    t_tiles, e_t = src_idx.shape
    src = src_idx.reshape(-1)
    dstl = dst_local.reshape(-1)
    w = weight.reshape(-1)
    tile_of_edge = jnp.repeat(jnp.arange(t_tiles, dtype=jnp.int32), e_t)
    dst = tile_of_edge * tile_n + dstl
    valid = src >= 0
    gathered = payload[jnp.where(valid, src, 0)] * w
    tgt = jnp.where(valid, dst, n_dst)
    if combiner == "add":
        vals = jnp.where(valid, gathered, 0.0)
        return jnp.zeros((n_dst + 1,), payload.dtype).at[tgt].add(
            vals, mode="drop")[:n_dst]
    if combiner == "min":
        vals = jnp.where(valid, gathered, jnp.inf)
        return jnp.full((n_dst + 1,), jnp.inf, payload.dtype).at[tgt].min(
            vals, mode="drop")[:n_dst]
    if combiner == "max":
        vals = jnp.where(valid, gathered, -jnp.inf)
        return jnp.full((n_dst + 1,), -jnp.inf, payload.dtype).at[tgt].max(
            vals, mode="drop")[:n_dst]
    raise ValueError(combiner)
