"""Public op + one-time CSC tiling of the immutable set.

``build_tiled_csc`` converts a CSR graph into the destination-tiled pull
layout the kernel consumes.  Because the edge relation is REX's *immutable
set*, this preprocessing is paid once per dataset and reused by every
stratum of every query — the same amortization argument the paper makes for
never re-shuffling the graph.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.edge_propagate.edge_propagate import (DEFAULT_CHUNK,
                                                         DEFAULT_TILE_N,
                                                         edge_propagate)
from repro.kernels.edge_propagate.ref import edge_propagate_ref


def build_tiled_csc(indptr: np.ndarray, indices: np.ndarray, n_dst: int,
                    tile_n: int = DEFAULT_TILE_N, chunk: int = DEFAULT_CHUNK,
                    weights: np.ndarray | None = None):
    """CSR → destination-tiled CSC arrays (numpy preprocessing).

    Returns (src_idx[T, E_T], dst_local[T, E_T], weight[T, E_T]) with
    T = ceil(n_dst / tile_n) rows padded (src = −1) to a uniform E_T that is
    a multiple of ``chunk``.
    """
    n_src = len(indptr) - 1
    deg = np.diff(indptr)
    src_of_edge = np.repeat(np.arange(n_src, dtype=np.int32),
                            deg.astype(np.int64))
    dst = np.asarray(indices, np.int64)
    keep = (dst >= 0) & (dst < n_dst)
    src_of_edge, dst = src_of_edge[keep], dst[keep]
    w = (np.ones(len(dst), np.float32) if weights is None
         else np.asarray(weights, np.float32)[keep])
    order = np.argsort(dst, kind="stable")
    src_of_edge, dst, w = src_of_edge[order], dst[order], w[order]
    tile = (dst // tile_n).astype(np.int64)
    t_tiles = -(-n_dst // tile_n)
    counts = np.bincount(tile, minlength=t_tiles)
    e_t = int(counts.max()) if len(counts) else 0
    e_t = max(-(-e_t // chunk) * chunk, chunk)
    src_out = np.full((t_tiles, e_t), -1, np.int32)
    dstl_out = np.zeros((t_tiles, e_t), np.int32)
    w_out = np.zeros((t_tiles, e_t), np.float32)
    starts = np.zeros(t_tiles + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    for t in range(t_tiles):
        lo, hi = starts[t], starts[t + 1]
        m = hi - lo
        src_out[t, :m] = src_of_edge[lo:hi]
        dstl_out[t, :m] = (dst[lo:hi] - t * tile_n).astype(np.int32)
        w_out[t, :m] = w[lo:hi]
    return (jnp.asarray(src_out), jnp.asarray(dstl_out), jnp.asarray(w_out))


def propagate(payload: jax.Array, tiled_csc, n_dst: int,
              combiner: str = "add", use_kernel: bool = True,
              interpret: bool = True, tile_n: int = DEFAULT_TILE_N
              ) -> jax.Array:
    src_idx, dst_local, weight = tiled_csc
    padded_dst = src_idx.shape[0] * tile_n
    if use_kernel:
        out = edge_propagate(payload, src_idx, dst_local, weight, padded_dst,
                             combiner=combiner, tile_n=tile_n,
                             interpret=interpret)
    else:
        out = edge_propagate_ref(payload, src_idx, dst_local, weight,
                                 padded_dst, combiner=combiner, tile_n=tile_n)
    return out[:n_dst]
