"""Pallas TPU kernels for the paper's compute hot-spots.

Each subpackage ships the kernel (pl.pallas_call + explicit BlockSpec VMEM
tiling), a jit'd public wrapper (ops.py), and a pure-jnp oracle (ref.py)
validated in interpret mode over shape/dtype sweeps:

  delta_scatter    — AGGSTATE: delta buffer → dense keyed state (one-hot
                     MXU contraction instead of scatter atomics)
  delta_route      — rehash bucketing: delta buffer → per-owner segments
                     (per-owner histogram + prefix-sum one-hot contraction
                     instead of argsort)
  scatter_route    — sort-free combine-route: delta buffer → per-owner
                     segments merged per key (dense slab accumulate +
                     prefix-sum compaction on the MXU; the scatter
                     strategy of ShardedExecutor.route_strategy)
  edge_propagate   — the REX hot loop: fused join→rehash-local→group-by
                     over destination-tiled CSC (the immutable set)
  kmeans_assign    — blocked point×centroid distances + argmin (MXU)
  flash_attention  — blocked online-softmax attention, GQA-aware (the LM
                     serving/training hot spot)
"""
