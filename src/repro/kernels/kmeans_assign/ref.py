"""Pure-jnp oracle for kmeans_assign."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kmeans_assign_ref(points: jax.Array, centroids: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    d2 = (jnp.sum(points ** 2, -1, keepdims=True)
          - 2.0 * points @ centroids.T
          + jnp.sum(centroids ** 2, -1))
    return jnp.argmin(d2, axis=-1).astype(jnp.int32), jnp.min(d2, axis=-1)
