"""Pallas TPU kernel: blocked point→centroid assignment (k-means hot spot).

Per point tile: ``d²(p, c) = ‖p‖² − 2·p·cᵀ + ‖c‖²`` — the cross term is a
[TILE_P, D] × [D, K] MXU matmul; the argmin over K runs on the VPU.  The
centroid table (K ≤ a few hundred, D small) is VMEM-resident for every grid
instance; points stream HBM→VMEM tile by tile.

Outputs the assignment AND the best distance so the caller can form the
switch-set (the k-means Δᵢ set) without a second pass.

Grid: (point tiles ×parallel).  TILE_P is a multiple of 8 sublanes; D and K
should be padded to lane multiples (128) for peak MXU utilization on real
hardware — the kernel is shape-generic and validated at many (D, K).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_P = 1024


def _kernel(pts_ref, cents_ref, assign_ref, dist_ref):
    pts = pts_ref[...]                                    # f32[TILE_P, D]
    cents = cents_ref[...]                                # f32[K, D]
    p2 = jnp.sum(pts * pts, axis=-1, keepdims=True)       # [TILE_P, 1]
    c2 = jnp.sum(cents * cents, axis=-1)                  # [K]
    cross = jax.lax.dot_general(
        pts, cents, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # [TILE_P, K]
    d2 = p2 - 2.0 * cross + c2[None, :]
    assign_ref[...] = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    dist_ref[...] = jnp.min(d2, axis=-1)


@functools.partial(jax.jit, static_argnames=("tile_p", "interpret"))
def kmeans_assign(points: jax.Array, centroids: jax.Array,
                  tile_p: int = DEFAULT_TILE_P, interpret: bool = True
                  ) -> tuple[jax.Array, jax.Array]:
    """points f32[N, D] (N % tile_p == 0); centroids f32[K, D].

    Returns (assign int32[N], d2 f32[N])."""
    n, d = points.shape
    k, d2 = centroids.shape
    if d != d2:
        raise ValueError("dimension mismatch")
    if n % tile_p:
        raise ValueError(f"N={n} not a multiple of tile_p={tile_p}")
    grid = (n // tile_p,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_p, d), lambda t: (t, 0)),
            pl.BlockSpec((k, d), lambda t: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_p,), lambda t: (t,)),
            pl.BlockSpec((tile_p,), lambda t: (t,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(points, centroids)
