from repro.kernels.kmeans_assign.kmeans_assign import kmeans_assign
from repro.kernels.kmeans_assign.ops import assign
from repro.kernels.kmeans_assign.ref import kmeans_assign_ref

__all__ = ["kmeans_assign", "assign", "kmeans_assign_ref"]
