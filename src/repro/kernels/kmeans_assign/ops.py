"""Public op: padded dispatch for kmeans_assign."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.kmeans_assign.kmeans_assign import kmeans_assign
from repro.kernels.kmeans_assign.ref import kmeans_assign_ref


def assign(points: jax.Array, centroids: jax.Array, tile_p: int = 1024,
           use_kernel: bool = True, interpret: bool = True
           ) -> tuple[jax.Array, jax.Array]:
    n = points.shape[0]
    if not use_kernel:
        return kmeans_assign_ref(points, centroids)
    pad = (-n) % tile_p
    if pad:
        points = jnp.concatenate(
            [points, jnp.zeros((pad, points.shape[1]), points.dtype)])
    a, d = kmeans_assign(points, centroids, tile_p=tile_p,
                         interpret=interpret)
    return a[:n], d[:n]
