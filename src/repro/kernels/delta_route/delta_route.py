"""Pallas TPU kernel: bucket a delta buffer into per-owner rehash segments.

This is the routing half of the paper's ``rehash`` operator (the local
shuffle before the all_to_all): place delta i at segment slot
``owner[i] * cap + rank[i]`` where ``rank`` is the delta's stable position
among earlier deltas with the same owner.  The jnp reference
(``core/delta.py:route_by_owner``) computes ranks with an argsort; sorting
is control-flow-heavy on TPU, so the kernel instead derives ranks from a
**per-owner histogram + prefix-sum one-hot contraction on the MXU**:

    onehot[CHUNK, SP]  = (owner_iota == owner)                (VPU compare)
    prior[CHUNK, SP]   = tril_strict · onehot                 (MXU matmul:
                         prior[i, s] = #deltas j<i in chunk with owner s)
    rank[i]            = Σ_s (prior + base)[i, s]·onehot[i, s] (VPU reduce)

with ``base[SP]`` the running histogram carried across delta chunks.
Placement is the same one-hot contraction trick as kernels/delta_scatter:
for each output segment the kernel builds ``match[CAP, CHUNK] = (lane ==
slot)`` and contracts it with the payload on the MXU; every slot receives
at most one delta, so a plain sum places exactly.  Keys and annotations
ride the same contraction in f32 (+1 offset so empty slots decode to the
-1 PAD key) — exact while keys < 2^24, enforced by the ops wrapper.

Grid: (segments ×parallel, delta chunks ×arbitrary).  The histogram and
key/ann accumulators live in VMEM scratch across the chunk loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 256
OWNER_LANES = 128          # padded owner axis (MXU/VREG lane alignment)
MAX_EXACT_KEY = (1 << 24) - 2   # keys+1 must stay exact in f32


def _kernel_route(keys_ref, pay_ref, ann_ref, own_ref,
                  keys_out, pay_out, ann_out,
                  base_ref, keysum_ref, annsum_ref,
                  *, cap, num_shards, chunk):
    s = pl.program_id(0)
    c = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        base_ref[...] = jnp.zeros_like(base_ref)
        keysum_ref[...] = jnp.zeros_like(keysum_ref)
        annsum_ref[...] = jnp.zeros_like(annsum_ref)
        pay_out[...] = jnp.zeros_like(pay_out)

    keys = keys_ref[...]                                  # int32[CHUNK]
    pay = pay_ref[...]                                    # f32[CHUNK, W]
    ann = ann_ref[...]                                    # int32[CHUNK]
    own = own_ref[...]                                    # int32[CHUNK]
    live = (keys != -1) & (own >= 0) & (own < num_shards)
    own_s = jnp.where(live, own, num_shards)

    # Per-owner histogram one-hot + within-chunk prefix counts (MXU).
    sp_iota = jax.lax.broadcasted_iota(jnp.int32, (chunk, OWNER_LANES), 1)
    onehot = (sp_iota == own_s[:, None]).astype(pay.dtype)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tril_strict = (rows > cols).astype(pay.dtype)
    prior = jax.lax.dot(tril_strict, onehot,
                        preferred_element_type=jnp.float32)
    base = base_ref[...]                                  # f32[OWNER_LANES]
    rank = jnp.sum((prior + base[None, :]) * onehot, axis=1)
    ok = live & (rank < cap)
    slot = jnp.where(ok, own_s * cap + rank.astype(jnp.int32), -1)

    # Direct segment placement: one-hot contraction, slots hit <= once.
    lanes = s * cap + jax.lax.broadcasted_iota(jnp.int32, (cap, chunk), 0)
    match = (lanes == slot[None, :]).astype(pay.dtype)    # [CAP, CHUNK]
    pay_out[...] += jax.lax.dot(match, pay,
                                preferred_element_type=jnp.float32)
    keysum_ref[...] += jax.lax.dot(
        match, (keys + 1).astype(pay.dtype)[:, None],
        preferred_element_type=jnp.float32)
    annsum_ref[...] += jax.lax.dot(match, ann.astype(pay.dtype)[:, None],
                                   preferred_element_type=jnp.float32)
    # Ranks count every live delta of the owner (overflowed slots keep
    # consuming ranks, matching route_by_owner), so update pre rank-clip.
    base_ref[...] = base + jnp.sum(jnp.where(live[:, None], onehot, 0.0),
                                   axis=0)

    @pl.when(c == nc - 1)
    def _finalize():
        keys_out[...] = keysum_ref[..., 0].astype(jnp.int32) - 1
        ann_out[...] = annsum_ref[..., 0].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_shards",
                                             "per_shard_capacity", "chunk",
                                             "interpret"))
def delta_route(keys: jax.Array, payload: jax.Array, ann: jax.Array,
                owners: jax.Array, num_shards: int, per_shard_capacity: int,
                chunk: int = DEFAULT_CHUNK, interpret: bool = True
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """keys int32[C] (-1 = padding); payload f32[C, W]; ann int32[C];
    owners int32[C] (out-of-range = dropped).  C % chunk == 0.  Returns
    (keys', payload', ann') of length num_shards * per_shard_capacity with
    segment s holding owner-s deltas in stable input order."""
    c_total = keys.shape[0]
    w = payload.shape[1]
    if c_total % chunk:
        raise ValueError(f"C={c_total} not a multiple of chunk={chunk}")
    if num_shards >= OWNER_LANES:
        raise ValueError(f"num_shards={num_shards} needs the jnp path "
                         f"(owner axis is padded to {OWNER_LANES} lanes)")
    cap = per_shard_capacity
    total = num_shards * cap
    kernel = functools.partial(_kernel_route, cap=cap,
                               num_shards=num_shards, chunk=chunk)
    grid = (num_shards, c_total // chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk,), lambda s, c: (c,)),
            pl.BlockSpec((chunk, w), lambda s, c: (c, 0)),
            pl.BlockSpec((chunk,), lambda s, c: (c,)),
            pl.BlockSpec((chunk,), lambda s, c: (c,)),
        ],
        out_specs=[
            pl.BlockSpec((cap,), lambda s, c: (s,)),
            pl.BlockSpec((cap, w), lambda s, c: (s, 0)),
            pl.BlockSpec((cap,), lambda s, c: (s,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((total,), jnp.int32),
            jax.ShapeDtypeStruct((total, w), payload.dtype),
            jax.ShapeDtypeStruct((total,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((OWNER_LANES,), jnp.float32),
            pltpu.VMEM((cap, 1), jnp.float32),
            pltpu.VMEM((cap, 1), jnp.float32),
        ],
        interpret=interpret,
    )(keys, payload, ann, owners)
