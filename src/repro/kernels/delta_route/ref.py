"""Pure-jnp oracle for the delta_route kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def delta_route_ref(keys: jax.Array, payload: jax.Array, ann: jax.Array,
                    owners: jax.Array, num_shards: int,
                    per_shard_capacity: int
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Same contract as kernels.delta_route.delta_route.

    Rank computation via an exclusive per-owner running count (O(C·S)
    memory — oracle only); placement by scatter.
    """
    c_total = keys.shape[0]
    cap = per_shard_capacity
    live = (keys != -1) & (owners >= 0) & (owners < num_shards)
    own_s = jnp.where(live, owners, num_shards)
    onehot = (own_s[:, None] == jnp.arange(num_shards + 1)[None, :]
              ).astype(jnp.int32)
    rank = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=0) - onehot, own_s[:, None], axis=1)[:, 0]
    ok = live & (rank < cap)
    total = num_shards * cap
    slot = jnp.where(ok, own_s * cap + rank, total)
    out_keys = jnp.full((total + 1,), -1, jnp.int32).at[slot].set(
        keys, mode="drop")[:total]
    out_pay = jnp.zeros((total + 1, payload.shape[1]), payload.dtype).at[
        slot].set(payload, mode="drop")[:total]
    out_ann = jnp.zeros((total + 1,), ann.dtype).at[slot].set(
        ann, mode="drop")[:total]
    return out_keys, out_pay, out_ann
