from repro.kernels.delta_route.delta_route import delta_route
from repro.kernels.delta_route.ops import route_deltas
from repro.kernels.delta_route.ref import delta_route_ref

__all__ = ["delta_route", "delta_route_ref", "route_deltas"]
