"""Public op: delta-buffer routing with automatic padding + dispatch.

``route_deltas(db, owners, num_shards, per_shard_capacity)`` pads the
buffer to kernel-friendly shapes and calls the Pallas kernel
(interpret-mode on CPU; compiled on TPU) — the same dispatch machinery as
kernels/delta_scatter.  Falls back to the jnp oracle when the kernel's
exactness bounds don't hold (num_shards >= 127 lanes, keys >= 2^24) or
shapes degenerate.  The result matches ``core/delta.py:route_by_owner``
slot-for-slot.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.delta import PAD_KEY, DeltaBuffer
from repro.kernels.delta_route.delta_route import (DEFAULT_CHUNK,
                                                   MAX_EXACT_KEY,
                                                   OWNER_LANES, delta_route)
from repro.kernels.delta_route.ref import delta_route_ref
from repro.kernels.pad import pad_to as _pad_to


def route_deltas(db: DeltaBuffer, owners: jax.Array, num_shards: int,
                 per_shard_capacity: int, max_key: int = MAX_EXACT_KEY,
                 use_kernel: bool = True, interpret: bool = True
                 ) -> DeltaBuffer:
    """Bucket ``db`` into per-owner segments (route_by_owner contract).

    ``max_key``: largest key value the caller can produce — the kernel
    rides keys through an f32 contraction, exact only below 2^24.
    """
    mask = db.keys != PAD_KEY
    owners = jnp.where(mask, owners, num_shards)
    ok_kernel = (use_kernel and num_shards < OWNER_LANES
                 and max_key <= MAX_EXACT_KEY)
    ann32 = db.ann.astype(jnp.int32)
    if ok_kernel:
        keys_p = _pad_to(db.keys, DEFAULT_CHUNK, -1)
        pay_p = _pad_to(db.payload, DEFAULT_CHUNK, 0.0)
        ann_p = _pad_to(ann32, DEFAULT_CHUNK, 0)
        own_p = _pad_to(owners, DEFAULT_CHUNK, num_shards)
        out_keys, out_pay, out_ann = delta_route(
            keys_p, pay_p, ann_p, own_p, num_shards, per_shard_capacity,
            interpret=interpret)
    else:
        out_keys, out_pay, out_ann = delta_route_ref(
            db.keys, db.payload, ann32, owners, num_shards,
            per_shard_capacity)
    live = mask & (owners >= 0) & (owners < num_shards)
    per_owner = jnp.zeros((num_shards + 1,), jnp.int32).at[
        jnp.clip(owners, 0, num_shards)].add(
        live.astype(jnp.int32), mode="drop")[:num_shards]
    return DeltaBuffer(
        keys=out_keys, payload=out_pay, ann=out_ann.astype(jnp.int8),
        count=jnp.sum(jnp.minimum(per_owner, per_shard_capacity)),
        overflowed=db.overflowed | jnp.any(per_owner > per_shard_capacity))
