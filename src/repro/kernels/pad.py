"""Shared input padding for the kernel ops wrappers: every dispatch pads
its delta arrays up to a chunk multiple before the pallas_call."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pad_to(x: jax.Array, m: int, fill) -> jax.Array:
    """Pad axis 0 of ``x`` up to the next multiple of ``m`` with ``fill``."""
    pad = (-x.shape[0]) % m
    if pad == 0:
        return x
    pad_block = jnp.full((pad,) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, pad_block])
