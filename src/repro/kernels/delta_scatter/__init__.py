from repro.kernels.delta_scatter.delta_scatter import delta_scatter
from repro.kernels.delta_scatter.ops import apply_delta
from repro.kernels.delta_scatter.ref import delta_scatter_ref

__all__ = ["delta_scatter", "apply_delta", "delta_scatter_ref"]
