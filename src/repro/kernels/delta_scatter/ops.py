"""Public op: delta-buffer application with automatic padding + dispatch.

``apply_delta(state, db, combiner)`` pads (idx, payload) to kernel-friendly
shapes and calls the Pallas kernel (interpret-mode on CPU; compiled on TPU).
Falls back to the jnp oracle for combiners the kernel does not implement
(replace) or degenerate shapes.
"""
from __future__ import annotations

import jax

from repro.core.delta import DeltaBuffer
from repro.kernels.pad import pad_to as _pad_to
from repro.kernels.delta_scatter.delta_scatter import (DEFAULT_CHUNK,
                                                       DEFAULT_TILE_N,
                                                       delta_scatter)
from repro.kernels.delta_scatter.ref import delta_scatter_ref


def apply_delta(state: jax.Array, db: DeltaBuffer, combiner: str = "add",
                use_kernel: bool = True, interpret: bool = True
                ) -> jax.Array:
    """Fold a DeltaBuffer into dense state[N] or state[N, W]."""
    squeeze = state.ndim == 1
    st = state[:, None] if squeeze else state
    n, w = st.shape
    idx = db.keys
    pay = db.payload[:, :w]
    ok_shapes = (n % DEFAULT_TILE_N == 0) and (
        combiner == "add" or w == 1)
    if use_kernel and ok_shapes:
        idx_p = _pad_to(idx, DEFAULT_CHUNK, -1)
        pay_p = _pad_to(pay, DEFAULT_CHUNK, 0.0)
        out = delta_scatter(st, idx_p, pay_p, combiner=combiner,
                            interpret=interpret)
    else:
        out = delta_scatter_ref(st, idx, pay, combiner=combiner)
    return out[:, 0] if squeeze else out
