"""Pallas TPU kernel: apply a delta buffer into dense keyed state.

This is the group-by/AGGSTATE hot spot: fold ``count`` deltas
``(idx[i], payload[i])`` into ``state[N, W]`` with a combiner.  On GPU one
would use atomics; the TPU adaptation replaces the scatter with a **one-hot
contraction on the MXU**: for each (state-tile, delta-chunk) pair the kernel
builds ``onehot[TILE_N, CHUNK] = (idx − tile_start == local)`` and computes

    out_tile += onehotᵀ·payload      (add combiner — a dense MXU matmul)
    out_tile  = min(out_tile, masked-broadcast-min)   (min/max — VPU select)

Work is O(N·C / (TILE_N·CHUNK)) MXU ops — dense, deterministic, and layout-
friendly, which on TPU beats emulated scatter for the delta sizes REX
produces (C ≲ 64Ki).  Collisions (several deltas on one key) combine
correctly because the contraction sums/bounds over the whole chunk.

Grid: (state tiles ×parallel, delta chunks ×arbitrary).  The output tile
lives in VMEM across the chunk loop; the state tile is read once at chunk 0.
Tile sizes are multiples of 128 on the lane axis (MXU/VREG alignment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 512
DEFAULT_CHUNK = 256


def _kernel_add(idx_ref, pay_ref, state_ref, out_ref, *, tile_n):
    t = pl.program_id(0)
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        out_ref[...] = state_ref[...]

    idx = idx_ref[...]                                    # int32[CHUNK]
    pay = pay_ref[...]                                    # f32[CHUNK, W]
    local = idx - t * tile_n                              # int32[CHUNK]
    # onehot[TILE_N, CHUNK]: row d hits chunk slots whose local index == d.
    lanes = jax.lax.broadcasted_iota(jnp.int32, (tile_n, idx.shape[0]), 0)
    onehot = (lanes == local[None, :]).astype(pay.dtype)
    out_ref[...] += jax.lax.dot(onehot, pay,
                                preferred_element_type=jnp.float32)


def _kernel_minmax(idx_ref, pay_ref, state_ref, out_ref, *, tile_n, is_min):
    t = pl.program_id(0)
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        out_ref[...] = state_ref[...]

    idx = idx_ref[...]
    pay = pay_ref[..., 0]                                 # f32[CHUNK] (W=1)
    local = idx - t * tile_n
    fill = jnp.inf if is_min else -jnp.inf
    lanes = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], tile_n), 1)
    masked = jnp.where(lanes == local[:, None], pay[:, None], fill)
    red = jnp.min(masked, axis=0) if is_min else jnp.max(masked, axis=0)
    cur = out_ref[..., 0]
    out_ref[..., 0] = jnp.minimum(cur, red) if is_min else jnp.maximum(
        cur, red)


@functools.partial(jax.jit, static_argnames=("combiner", "tile_n", "chunk",
                                              "interpret"))
def delta_scatter(state: jax.Array, idx: jax.Array, payload: jax.Array,
                  combiner: str = "add", tile_n: int = DEFAULT_TILE_N,
                  chunk: int = DEFAULT_CHUNK, interpret: bool = True
                  ) -> jax.Array:
    """state f32[N, W]; idx int32[C] (out-of-range = padding); payload
    f32[C, W].  N % tile_n == 0 and C % chunk == 0 (pad with idx = -1)."""
    n, w = state.shape
    c_total = idx.shape[0]
    if n % tile_n:
        raise ValueError(f"N={n} not a multiple of tile_n={tile_n}")
    if c_total % chunk:
        raise ValueError(f"C={c_total} not a multiple of chunk={chunk}")
    if combiner == "add":
        kernel = functools.partial(_kernel_add, tile_n=tile_n)
    elif combiner in ("min", "max"):
        if w != 1:
            raise ValueError("min/max combiners support W=1 payloads")
        kernel = functools.partial(_kernel_minmax, tile_n=tile_n,
                                   is_min=combiner == "min")
    else:
        raise ValueError(f"unsupported combiner {combiner!r}")

    grid = (n // tile_n, c_total // chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk,), lambda t, c: (c,)),
            pl.BlockSpec((chunk, w), lambda t, c: (c, 0)),
            pl.BlockSpec((tile_n, w), lambda t, c: (t, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, w), lambda t, c: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n, w), state.dtype),
        interpret=interpret,
    )(idx, payload, state)
