"""Pure-jnp oracle for the delta_scatter kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def delta_scatter_ref(state: jax.Array, idx: jax.Array, payload: jax.Array,
                      combiner: str = "add") -> jax.Array:
    """Same contract as kernels.delta_scatter.delta_scatter.

    Out-of-range indices (including -1 padding) are dropped.
    """
    n, w = state.shape
    safe = (idx >= 0) & (idx < n)
    tgt = jnp.where(safe, idx, n)
    if combiner == "add":
        pay = jnp.where(safe[:, None], payload, 0.0)
        return jnp.concatenate(
            [state, jnp.zeros((1, w), state.dtype)]).at[tgt].add(
            pay, mode="drop")[:n]
    if combiner == "min":
        pay = jnp.where(safe[:, None], payload, jnp.inf)
        return jnp.concatenate(
            [state, jnp.zeros((1, w), state.dtype)]).at[tgt].min(
            pay, mode="drop")[:n]
    if combiner == "max":
        pay = jnp.where(safe[:, None], payload, -jnp.inf)
        return jnp.concatenate(
            [state, jnp.zeros((1, w), state.dtype)]).at[tgt].max(
            pay, mode="drop")[:n]
    raise ValueError(combiner)
