"""Public op: sort-free combine-route with automatic padding + dispatch.

``scatter_route_deltas(db, owners, num_shards, per_shard_capacity,
combiner, snapshot=...)`` pads the buffer to kernel-friendly shapes and
calls the Pallas kernel (interpret-mode on CPU; compiled on TPU) — the
same dispatch machinery as kernels/delta_route.  Falls back to the jnp
oracle when the kernel's bounds don't hold (non-"add" combiners, hash
partition scheme, block_size beyond the VMEM slab bound, cap·block
beyond the finalize match-matrix bound, padded_keys ≥ 2^24) or shapes
degenerate.  The result matches
``core/delta.py:combine_route_scatter`` slot-for-slot (payloads to float
addition order for "add").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.delta import PAD_KEY, DeltaBuffer
from repro.kernels.pad import pad_to as _pad_to
from repro.kernels.scatter_route.ref import scatter_route_ref
from repro.kernels.scatter_route.scatter_route import (DEFAULT_CHUNK,
                                                       MAX_BLOCK,
                                                       MAX_EXACT_KEY,
                                                       MAX_MATCH_CELLS,
                                                       scatter_route)


def scatter_route_deltas(db: DeltaBuffer, owners: jax.Array,
                         num_shards: int, per_shard_capacity: int,
                         combiner: str = "add", *, snapshot,
                         use_kernel: bool = True, interpret: bool = True
                         ) -> DeltaBuffer:
    """Combine + route ``db`` into per-owner segments, sort-free.

    Same contract as ``core.delta.combine_route_scatter`` (and therefore
    ``combine_route``): merged per key, segments in ascending-key order,
    overflowing owners keep their smallest keys.  ``owners`` must be a
    function of the key via ``snapshot`` (out-of-range owners drop the
    whole key).
    """
    if snapshot.scheme != "block":
        # (owner, local) slab addressing is only injective under the
        # block scheme; the hash scheme goes through the global-key slab
        # of the core implementation.
        from repro.core.delta import combine_route_scatter
        return combine_route_scatter(db, owners, num_shards,
                                     per_shard_capacity, combiner,
                                     snapshot=snapshot)
    S = num_shards
    B = snapshot.block_size
    mask = db.keys != PAD_KEY
    owners = jnp.where(mask, owners, S)
    local = snapshot.local_index(db.keys)
    ok_kernel = (use_kernel and combiner == "add"
                 and B <= MAX_BLOCK
                 and per_shard_capacity * B <= MAX_MATCH_CELLS
                 and snapshot.padded_keys <= MAX_EXACT_KEY)
    if ok_kernel:
        keys_p = _pad_to(db.keys, DEFAULT_CHUNK, -1)
        pay_p = _pad_to(db.payload, DEFAULT_CHUNK, 0.0)
        loc_p = _pad_to(local, DEFAULT_CHUNK, -1)
        own_p = _pad_to(owners, DEFAULT_CHUNK, S)
        out_keys, out_pay, out_ann = scatter_route(
            keys_p, pay_p, loc_p, own_p, S, B, per_shard_capacity,
            interpret=interpret)
    else:
        out_keys, out_pay, out_ann = scatter_route_ref(
            db.keys, db.payload, local, owners, S, B, per_shard_capacity,
            combiner)
    # Count / overflow from MERGED key occupancy (jnp; cheap): an owner
    # overflows when it has more distinct live keys than capacity.
    valid = (mask & (owners >= 0) & (owners < S)
             & (db.keys >= 0) & (db.keys < snapshot.padded_keys))
    n_cells = S * B
    addr = jnp.where(valid, owners * B + local, n_cells)
    occ = jnp.zeros((n_cells + 1,), jnp.int32).at[addr].max(
        valid.astype(jnp.int32), mode="drop")[:n_cells]
    per_owner = jnp.sum(occ.reshape(S, B), axis=1)
    return DeltaBuffer(
        keys=out_keys, payload=out_pay, ann=out_ann.astype(jnp.int8),
        count=jnp.sum(jnp.minimum(per_owner, per_shard_capacity)),
        overflowed=db.overflowed | jnp.any(
            per_owner > per_shard_capacity))
