"""Pure-jnp oracle for the scatter_route kernel.

Same raw-array contract as ``scatter_route.scatter_route`` but supporting
every composable combiner (add/min/max/replace); the kernel itself only
implements "add" and the ops wrapper falls back here for the rest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

ANN_ADJUST = 3  # == repro.core.delta.ANN_ADJUST (kept literal: no dep)


def scatter_route_ref(keys: jax.Array, payload: jax.Array,
                      local: jax.Array, owners: jax.Array, num_shards: int,
                      block_size: int, per_shard_capacity: int,
                      combiner: str = "add"
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Slab combine + prefix-sum compaction, scatter-based (no Pallas).

    Returns (keys', payload', ann') of length
    ``num_shards * per_shard_capacity``; segment s holds owner-s deltas
    merged per key in ascending-key order.  Keys are reconstructed as
    ``owner * block_size + local`` (block-partition contract).
    """
    c_total = keys.shape[0]
    w = payload.shape[1]
    S, B, cap = num_shards, block_size, per_shard_capacity
    n_cells = S * B
    live = ((keys != -1) & (owners >= 0) & (owners < S)
            & (local >= 0) & (local < B))
    addr = jnp.where(live, owners * B + local, n_cells)
    iota = jnp.arange(c_total, dtype=jnp.int32)
    if combiner == "add":
        slab = jnp.zeros((n_cells + 1, w), payload.dtype).at[addr].add(
            jnp.where(live[:, None], payload, 0.0), mode="drop")
    elif combiner == "min":
        slab = jnp.full((n_cells + 1, w), jnp.inf, payload.dtype).at[
            addr].min(jnp.where(live[:, None], payload, jnp.inf),
                      mode="drop")
    elif combiner == "max":
        slab = jnp.full((n_cells + 1, w), -jnp.inf, payload.dtype).at[
            addr].max(jnp.where(live[:, None], payload, -jnp.inf),
                      mode="drop")
    elif combiner == "replace":
        # Last (stable slot order) wins — mirrors
        # core.delta._last_writer_mask, duplicated so the oracle stays
        # dependency-free of the module it validates.
        win = jnp.full((n_cells + 1,), -1, jnp.int32).at[addr].max(
            jnp.where(live, iota, -1), mode="drop")
        is_winner = live & (win[addr] == iota)
        slab = jnp.zeros((n_cells + 1, w), payload.dtype).at[addr].add(
            jnp.where(is_winner[:, None], payload, 0.0), mode="drop")
    else:
        raise ValueError(f"unknown combiner {combiner!r}")
    occ = jnp.zeros((n_cells + 1,), jnp.int32).at[addr].add(
        live.astype(jnp.int32), mode="drop")[:n_cells]
    slab = slab[:n_cells]
    live_cell = (occ > 0).reshape(S, B)
    rank = (jnp.cumsum(live_cell.astype(jnp.int32), axis=1) - 1
            ).reshape(n_cells)
    ok = live_cell.reshape(n_cells) & (rank < cap)
    row = jnp.repeat(jnp.arange(S, dtype=jnp.int32), B)
    total = S * cap
    slot = jnp.where(ok, row * cap + rank, total)
    cell_key = row * B + jnp.tile(jnp.arange(B, dtype=jnp.int32), S)
    out_keys = jnp.full((total + 1,), -1, jnp.int32).at[slot].set(
        cell_key, mode="drop")[:total]
    out_pay = jnp.zeros((total + 1, w), payload.dtype).at[slot].set(
        slab, mode="drop")[:total]
    out_ann = jnp.zeros((total + 1,), jnp.int32).at[slot].set(
        ANN_ADJUST, mode="drop")[:total]
    return out_keys, out_pay, out_ann
