"""Pallas TPU kernel: sort-free combine-route via a per-owner slab.

This is the scatter-based physical implementation of the fused
combine + rehash-local operator (the jnp reference is
``core/delta.py:combine_route_scatter``).  Where the sort-based path pays
an O(C log C) lexicographic sort per stratum, this kernel exploits that —
under a block partition snapshot — the destination slot of a key is a pure
function of the key itself: deltas are **scatter-accumulated into a dense
slab** addressed by the key's local index inside its owner block, then the
slab is **compacted by a prefix sum over cell occupancy** into the owner's
segment, in ascending-key order (identical slot layout to the sort path).

Per grid step (output segment s × delta chunk c):

    onehot[B, CHUNK] = (cell_iota == local) & (owner == s)   (VPU compare)
    slab[B, W]      += onehot · payload                      (MXU matmul)
    occ[B, 1]       += onehot · 1                            (MXU matmul)

and at the final chunk the compaction:

    rank[B]          = cumsum(occ > 0) − 1                   (prefix sum)
    match[CAP, B]    = (slot_iota == rank) & live & rank<cap (VPU compare)
    payload_out      = match · slab                          (MXU matmul)
    keys_out         = match · (s·B + cell + 1) − 1          (MXU matmul)

Keys are decoded from the cell index itself (s·B + cell), so — unlike
kernels/delta_route — no key rides an f32 contraction *per delta*; only
the final decode does, bounding exactness at padded_keys < 2^24 (enforced
by the ops wrapper).  The slab and occupancy accumulators live in VMEM
scratch across the chunk loop.  The kernel implements the "add" combiner
(the engine's PageRank/adsorption hot path); min/max/replace fall back to
the jnp oracle in the ops wrapper, like delta_scatter does for replace.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 256
MAX_BLOCK = 4096                # slab cells per owner kept in VMEM scratch
MAX_MATCH_CELLS = 1 << 22       # cap·block bound: the finalize one-hot
#                                 match is a (cap, block) f32 (16 MB here)
MAX_EXACT_KEY = (1 << 24) - 2   # keys+1 must stay exact in f32


def _kernel_scatter_route(keys_ref, pay_ref, local_ref, own_ref,
                          keys_out, pay_out, ann_out,
                          slab_ref, occ_ref,
                          *, cap, block, num_shards, chunk):
    s = pl.program_id(0)
    c = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        slab_ref[...] = jnp.zeros_like(slab_ref)
        occ_ref[...] = jnp.zeros_like(occ_ref)

    keys = keys_ref[...]                                  # int32[CHUNK]
    pay = pay_ref[...]                                    # f32[CHUNK, W]
    local = local_ref[...]                                # int32[CHUNK]
    own = own_ref[...]                                    # int32[CHUNK]
    live = ((keys != -1) & (own == s)
            & (local >= 0) & (local < block))
    local_s = jnp.where(live, local, block)               # block = dead lane

    # Slab accumulate: one-hot cell match, contracted on the MXU.  Every
    # delta hits exactly one cell; duplicate keys accumulate there.
    cell_iota = jax.lax.broadcasted_iota(jnp.int32, (block, chunk), 0)
    onehot = (cell_iota == local_s[None, :]).astype(pay.dtype)
    slab_ref[...] += jax.lax.dot(onehot, pay,
                                 preferred_element_type=jnp.float32)
    occ_ref[...] += jax.lax.dot(
        onehot, jnp.ones((chunk, 1), pay.dtype),
        preferred_element_type=jnp.float32)

    @pl.when(c == nc - 1)
    def _finalize():
        occ = occ_ref[..., 0]                             # f32[B]
        live_cell = occ > 0.0
        # Prefix-sum compaction: rank = #occupied cells before me.  Cell
        # order IS key order under the block scheme, so segments come out
        # ascending-key exactly like the sort path.
        rank = jnp.cumsum(
            live_cell.astype(jnp.int32).reshape(1, block), axis=1
        ).reshape(block) - 1
        ok = live_cell & (rank < cap)
        rank_s = jnp.where(ok, rank, cap)                 # cap = dead lane
        slot_iota = jax.lax.broadcasted_iota(jnp.int32, (cap, block), 0)
        match = (slot_iota == rank_s[None, :]).astype(jnp.float32)
        pay_out[...] = jax.lax.dot(match, slab_ref[...],
                                   preferred_element_type=jnp.float32)
        cell_key = (s * block
                    + jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0))
        keysum = jax.lax.dot(match, (cell_key + 1).astype(jnp.float32),
                             preferred_element_type=jnp.float32)
        keys_out[...] = keysum[:, 0].astype(jnp.int32) - 1
        filled = jax.lax.dot(match, jnp.ones((block, 1), jnp.float32),
                             preferred_element_type=jnp.float32)[:, 0]
        # Merged slots carry the ADJUST annotation (code 3), like the jnp
        # combine paths; empty slots carry 0.
        ann_out[...] = jnp.where(filled > 0.0, 3, 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_shards", "block_size",
                                             "per_shard_capacity", "chunk",
                                             "interpret"))
def scatter_route(keys: jax.Array, payload: jax.Array, local: jax.Array,
                  owners: jax.Array, num_shards: int, block_size: int,
                  per_shard_capacity: int, chunk: int = DEFAULT_CHUNK,
                  interpret: bool = True
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """keys int32[C] (-1 = padding); payload f32[C, W]; local int32[C]
    (key's index inside its owner block, out-of-range = dropped); owners
    int32[C] (out-of-range = dropped).  C % chunk == 0.  Block partition +
    "add" combiner only (callers dispatch through ops.py).  Returns
    (keys', payload', ann') of length num_shards * per_shard_capacity with
    segment s holding owner-s deltas merged per key, ascending-key order.
    """
    c_total = keys.shape[0]
    w = payload.shape[1]
    if c_total % chunk:
        raise ValueError(f"C={c_total} not a multiple of chunk={chunk}")
    if block_size > MAX_BLOCK:
        raise ValueError(f"block_size={block_size} exceeds the VMEM slab "
                         f"bound {MAX_BLOCK}; use the jnp path")
    if per_shard_capacity * block_size > MAX_MATCH_CELLS:
        raise ValueError(
            f"cap·block = {per_shard_capacity * block_size} exceeds the "
            f"finalize match-matrix bound {MAX_MATCH_CELLS}; use the jnp "
            "path")
    cap = per_shard_capacity
    total = num_shards * cap
    kernel = functools.partial(_kernel_scatter_route, cap=cap,
                               block=block_size, num_shards=num_shards,
                               chunk=chunk)
    grid = (num_shards, c_total // chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk,), lambda s, c: (c,)),
            pl.BlockSpec((chunk, w), lambda s, c: (c, 0)),
            pl.BlockSpec((chunk,), lambda s, c: (c,)),
            pl.BlockSpec((chunk,), lambda s, c: (c,)),
        ],
        out_specs=[
            pl.BlockSpec((cap,), lambda s, c: (s,)),
            pl.BlockSpec((cap, w), lambda s, c: (s, 0)),
            pl.BlockSpec((cap,), lambda s, c: (s,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((total,), jnp.int32),
            jax.ShapeDtypeStruct((total, w), payload.dtype),
            jax.ShapeDtypeStruct((total,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_size, w), jnp.float32),
            pltpu.VMEM((block_size, 1), jnp.float32),
        ],
        interpret=interpret,
    )(keys, payload, local, owners)
