from repro.kernels.scatter_route.ops import scatter_route_deltas
from repro.kernels.scatter_route.ref import scatter_route_ref
from repro.kernels.scatter_route.scatter_route import scatter_route

__all__ = ["scatter_route", "scatter_route_ref", "scatter_route_deltas"]
