"""Public op: attention dispatch (kernel on TPU-shaped inputs, oracle else).

``use_kernel`` selects the Pallas path; models use the oracle by default on
CPU (XLA fuses it well there) and the kernel under TPU deployment — the
switch is a config flag threaded through ModelConfig.attn_impl.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
              use_kernel: bool = False, interpret: bool = True,
              block_q: int = 128, block_k: int = 128) -> jax.Array:
    t, s = q.shape[2], k.shape[2]
    ok = (t % block_q == 0) and (s % block_k == 0)
    if use_kernel and ok:
        return flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    return attention_ref(q, k, v, causal=causal)
