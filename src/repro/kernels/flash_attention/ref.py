"""Pure-jnp oracle for flash_attention (materialized-scores attention)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """q f32[B, H, T, D]; k/v f32[B, H_kv, S, D].  GQA by head repeat."""
    b, h, t, d = q.shape
    _, h_kv, s, _ = k.shape
    group = h // h_kv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((t, s), jnp.bool_), k=s - t)
        scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", probs, v)
