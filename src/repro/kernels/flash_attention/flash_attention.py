"""Pallas TPU kernel: blocked online-softmax (flash) attention, GQA-aware.

The LM-side compute hot spot.  Standard flash-attention restructuring for
the TPU memory hierarchy: Q tiles stay VMEM-resident while K/V tiles stream
HBM→VMEM; the running (max, sum, acc) statistics live in VMEM scratch across
the KV-block loop, so the [T, S] score matrix never materializes in HBM.

GQA: query head h reads KV head ``h // (H // H_kv)`` — expressed in the
K/V BlockSpec index maps, so grouped queries share K/V tile fetches.

Causal masking skips fully-masked KV blocks via the grid bound (each Q block
only loops over KV blocks with start ≤ its end) and applies the per-element
mask on the diagonal blocks.

Grid: (batch·heads ×parallel, Q blocks ×parallel, KV blocks ×arbitrary).
Block sizes are multiples of 128 on the lane axis; dims fixed at Dh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, block_q, block_k, kv_blocks):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q_start = qi * block_q
    k_start = kj * block_k
    # Causal: skip blocks entirely above the diagonal.
    run = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(run)
    def _attend():
        q = q_ref[0]                                      # [block_q, d]
        k = k_ref[0, 0]                                   # [block_k, d]
        v = v_ref[0, 0]                                   # [block_k, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]
        if causal:
            rows = jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0) + q_start
            cols = jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1) + k_start
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]                               # [bq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])                   # [bq, bk]
        corr = jnp.exp(m_prev - m_new)                    # [bq]
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot(p.astype(v.dtype), v,
                                      preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(kj == kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K, interpret: bool = True
                    ) -> jax.Array:
    """q f32[B, H, T, D]; k/v f32[B, H_kv, S, D] with H % H_kv == 0.

    T % block_q == 0 and S % block_k == 0.  Returns f32[B, H, T, D].
    """
    b, h, t, d = q.shape
    _, h_kv, s, _ = k.shape
    if h % h_kv:
        raise ValueError("H must be a multiple of H_kv (GQA)")
    group = h // h_kv
    if t % block_q or s % block_k:
        raise ValueError("sequence not a multiple of block size")
    scale = 1.0 / (d ** 0.5)
    kv_blocks = s // block_k
    grid = (b * h, t // block_q, kv_blocks)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, kv_blocks=kv_blocks)
    qs = q.reshape(b * h, t, d)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bh, qi, kj, g=group, hh=h:
                         (bh // hh, (bh % hh) // g, kj, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bh, qi, kj, g=group, hh=h:
                         (bh // hh, (bh % hh) // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qs, k, v).reshape(b, h, t, d)
