"""Straggler mitigation: speculative re-execution of slow shards.

REX strata are bulk-synchronous (punctuation barrier), so one slow node
stalls every stratum — the same pathology MapReduce mitigates with
*backup tasks*.  The driver-side policy here: track per-shard stratum
latencies; when a shard's latency exceeds ``threshold ×`` the rolling
median, re-issue its stratum work to the shard's replica (which holds the
replicated mutable Δ state — paper §4.1's replica chain makes speculation
cheap) and take whichever finishes first.

On a TPU pod the analogue is re-dispatching a slice's step to a hot spare;
the policy layer is identical, so it is implemented (and tested) against
the simulated per-shard timing model.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class SpeculationPolicy:
    threshold: float = 2.0        # p_shard > threshold × median ⇒ speculate
    min_history: int = 3          # strata before speculation activates
    max_concurrent: int = 2       # replicas a shard may be speculated on


class StragglerMitigator:
    """Feed per-stratum shard latencies; emits speculation decisions and
    accounts the wall-clock the barrier would have paid vs. did pay."""

    def __init__(self, num_shards: int,
                 policy: Optional[SpeculationPolicy] = None,
                 replicas_of: Optional[Callable[[int], List[int]]] = None):
        self.num_shards = num_shards
        self.policy = policy or SpeculationPolicy()
        self.replicas_of = replicas_of or (
            lambda s: [(s + 1) % num_shards])
        self.history: Dict[int, List[float]] = {s: []
                                                for s in range(num_shards)}
        self.speculated: List[dict] = []
        self.verified: List[dict] = []
        self.saved_time = 0.0
        self.strata = 0
        self.timeouts: Dict[int, int] = {}

    def note_timeout(self, shard: int) -> None:
        """An I/O timeout on this shard's replica path is a straggler
        signal: mark the shard so the next observed stratum treats it as
        over-threshold even when its compute latency alone would not
        trip the policy."""
        self.timeouts[shard] = self.timeouts.get(shard, 0) + 1

    def record_verification(self, shard: int, ok: bool,
                            stratum: int = -1) -> None:
        """Log the outcome of validating a speculation against the shard's
        replica chain: the resilient driver rebuilds the slow shard's
        mutable state from replicas ONLY and checks bit-equality with the
        live shard — the proof that the re-issued stratum work would have
        produced identical results had the replica won the race."""
        self.verified.append({"shard": shard, "ok": ok,
                              "stratum": stratum})

    def observe_stratum(self, latencies: List[float],
                        replica_latency: Optional[Callable[[int], float]]
                        = None) -> dict:
        """latencies[s] = shard s's stratum time.  replica_latency(s) =
        the time the replica would take (defaults to median).  Returns the
        stratum's barrier time with and without speculation."""
        self.strata += 1
        med = statistics.median(latencies)
        # Pending timeout flags (note_timeout) promote their shard to
        # straggler for THIS stratum: its effective latency is lifted
        # just past the speculation threshold, then the flag clears.
        flagged, self.timeouts = self.timeouts, {}
        latencies = [lat if s not in flagged
                     else max(lat, self.policy.threshold * med * 1.001)
                     for s, lat in enumerate(latencies)]
        barrier_without = max(latencies)
        effective = list(latencies)
        decisions = []
        if self.strata > self.policy.min_history:
            for s, lat in enumerate(latencies):
                if lat > self.policy.threshold * med:
                    rep = self.replicas_of(s)[0]
                    rep_lat = (replica_latency(s) if replica_latency
                               else med)
                    # Speculation launches when the threshold trips (at
                    # threshold×med elapsed); winner = min(original,
                    # launch-time + replica run).
                    launch = self.policy.threshold * med
                    effective[s] = min(lat, launch + rep_lat)
                    decisions.append({"shard": s, "replica": rep,
                                      "original": lat,
                                      "effective": effective[s]})
        for s, lat in enumerate(latencies):
            self.history[s].append(lat)
        barrier_with = max(effective)
        self.saved_time += barrier_without - barrier_with
        self.speculated.extend(decisions)
        return {"barrier_without": barrier_without,
                "barrier_with": barrier_with,
                "speculations": decisions}
