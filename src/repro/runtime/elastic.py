"""Elastic re-scaling: partition re-snapshot + state migration.

Paper §4.1: every query carries a partition snapshot; when the node set
changes (failure recovery, scale-up/down), a NEW snapshot is taken and
data is routed according to it from then on.  Here:

  * analytics — ``remap_state`` moves the dense keyed mutable set from an
    S₁-shard layout to an S₂-shard layout (the all_to_all the real cluster
    would run), preserving key→value contents exactly.
  * training  — ``reshard_tree`` re-commits a param/optimizer PyTree onto
    a new mesh via ``jax.device_put`` with freshly derived NamedShardings
    (GSPMD emits the minimal movement collective).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.partition import (PartitionSnapshot, shard_dense_state,
                                  unshard_dense_state)


def remap_state(old: PartitionSnapshot, new: PartitionSnapshot,
                state_sharded: jax.Array) -> jax.Array:
    """[S1, block1, ...] -> [S2, block2, ...] preserving global keys.

    The flatten→reshape is the logical effect of the migration
    all_to_all: every key lands on its new owner."""
    flat = unshard_dense_state(old, state_sharded)
    return shard_dense_state(new, flat)


def grow(snapshot: PartitionSnapshot, new_num_shards: int,
         *state_arrays):
    """Re-snapshot to ``new_num_shards`` and migrate every state array."""
    new_snap = snapshot.resnapshot(new_num_shards)
    return new_snap, tuple(remap_state(snapshot, new_snap, s)
                           for s in state_arrays)


def reshard_tree(tree, mesh, spec_fn):
    """Re-commit a PyTree onto ``mesh`` with specs from ``spec_fn(tree,
    mesh)`` — the training-side elastic move (new device set ⇒ new mesh ⇒
    same logical params, new physical layout)."""
    specs = spec_fn(tree, mesh)
    shardings = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    return jax.device_put(tree, shardings)
