"""Elastic re-scaling: partition re-snapshot + state migration.

Paper §4.1: every query carries a partition snapshot; when the node set
changes (failure recovery, scale-up/down), a NEW snapshot is taken and
data is routed according to it from then on.  Here:

  * analytics — ``remap_state`` moves the dense keyed mutable set from an
    S₁-shard layout to an S₂-shard layout (the all_to_all the real cluster
    would run), preserving key→value contents exactly.
  * training  — ``reshard_tree`` re-commits a param/optimizer PyTree onto
    a new mesh via ``jax.device_put`` with freshly derived NamedShardings
    (GSPMD emits the minimal movement collective).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.delta import PAD_KEY, DeltaBuffer, combine_route
from repro.core.partition import (PartitionSnapshot, shard_dense_state,
                                  unshard_dense_state)


def remap_state(old: PartitionSnapshot, new: PartitionSnapshot,
                state_sharded: jax.Array) -> jax.Array:
    """[S1, block1, ...] -> [S2, block2, ...] preserving global keys.

    The flatten→reshape is the logical effect of the migration
    all_to_all: every key lands on its new owner."""
    flat = unshard_dense_state(old, state_sharded)
    return shard_dense_state(new, flat)


def grow(snapshot: PartitionSnapshot, new_num_shards: int,
         *state_arrays):
    """Re-snapshot to ``new_num_shards`` and migrate every state array."""
    new_snap = snapshot.resnapshot(new_num_shards)
    return new_snap, tuple(remap_state(snapshot, new_snap, s)
                           for s in state_arrays)


def migrate_route_buffers(new: PartitionSnapshot, entries,
                          payload_width: int,
                          combiner: str = "replace") -> DeltaBuffer:
    """Re-route in-flight delta buffers under a NEW partition snapshot.

    ``entries`` is a chronologically-ordered iterable of ``(keys,
    payload)`` host arrays with GLOBAL keys — e.g. a replica chain's
    changed-entry buffers accumulated under the old snapshot, or deltas
    that were mid-rehash when the node set changed.  They are concatenated
    in order and pushed through the engine's own ``combine_route`` under
    the new snapshot, so each new owner receives exactly the entries it
    now owns, grouped into its segment.  The default ``"replace"``
    combiner collapses the chain: the chronologically LAST value per key
    wins (``combine_route``'s stable last-writer rule), which is precisely
    the chain-replay semantics — so the returned buffer's segment for new
    shard s, applied over the migrated baseline, reproduces the pre-
    migration state of every key s now owns.

    Returns a segmented DeltaBuffer with ``new.num_shards`` segments of
    ``new.block_size`` slots (an owner can receive at most one entry per
    key it owns, so the segment can never overflow).
    """
    keys_list, payload_list = [], []
    for keys, payload in entries:
        keys = np.asarray(keys, np.int32).reshape(-1)
        payload = np.asarray(payload, np.float32).reshape(
            len(keys), payload_width)
        keys_list.append(keys)
        payload_list.append(payload)
    if keys_list:
        all_keys = np.concatenate(keys_list)
        all_payload = np.concatenate(payload_list)
    else:
        all_keys = np.empty((0,), np.int32)
        all_payload = np.empty((0, payload_width), np.float32)
    n = len(all_keys)
    if n == 0:
        seg = new.block_size
        return DeltaBuffer.empty(new.num_shards * seg, payload_width)
    db = DeltaBuffer(
        keys=jnp.asarray(all_keys),
        payload=jnp.asarray(all_payload),
        ann=jnp.zeros((n,), jnp.int8),
        count=jnp.asarray(n, jnp.int32),
        overflowed=jnp.asarray(False))
    owners = new.owner_of(db.keys)
    return combine_route(db, owners, new.num_shards, new.block_size,
                         combiner=combiner)


def apply_route_buffer(routed: DeltaBuffer, new: PartitionSnapshot,
                       shard: int, block: np.ndarray) -> np.ndarray:
    """Fold new-shard ``shard``'s segment of a migrated route buffer into
    its dense mutable block (host-side replace of the live rows)."""
    seg = new.block_size
    keys = np.asarray(routed.keys[shard * seg:(shard + 1) * seg])
    payload = np.asarray(routed.payload[shard * seg:(shard + 1) * seg])
    live = keys != int(PAD_KEY)
    local = np.asarray(
        new.local_index(jnp.asarray(keys[live], jnp.int32)))
    out = np.array(block, copy=True)
    out[local] = payload[live]
    return out


def reshard_tree(tree, mesh, spec_fn):
    """Re-commit a PyTree onto ``mesh`` with specs from ``spec_fn(tree,
    mesh)`` — the training-side elastic move (new device set ⇒ new mesh ⇒
    same logical params, new physical layout)."""
    specs = spec_fn(tree, mesh)
    shardings = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    return jax.device_put(tree, shardings)
