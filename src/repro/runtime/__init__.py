from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.elastic import (apply_route_buffer, grow,
                                   migrate_route_buffers, remap_state,
                                   reshard_tree)
from repro.runtime.recovery import (FaultPlan, ReplicaChain,
                                    ResilientDriver, ResilientResult,
                                    StratumRunner, pack_state,
                                    run_with_failure, unpack_state)
from repro.runtime.straggler import SpeculationPolicy, StragglerMitigator

__all__ = ["CheckpointManager", "grow", "remap_state", "reshard_tree",
           "migrate_route_buffers", "apply_route_buffer",
           "StratumRunner", "run_with_failure", "FaultPlan",
           "ReplicaChain", "ResilientDriver", "ResilientResult",
           "pack_state", "unpack_state",
           "SpeculationPolicy", "StragglerMitigator"]
