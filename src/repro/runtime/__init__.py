from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.elastic import grow, remap_state, reshard_tree
from repro.runtime.recovery import StratumRunner, run_with_failure
from repro.runtime.straggler import SpeculationPolicy, StragglerMitigator

__all__ = ["CheckpointManager", "grow", "remap_state", "reshard_tree",
           "StratumRunner", "run_with_failure", "SpeculationPolicy",
           "StragglerMitigator"]
