from repro.runtime.checkpoint import (CheckpointCorruption,
                                      CheckpointManager, atomic_write_json)
from repro.runtime.chaos import ChaosConfig, generate_schedule
from repro.runtime.elastic import (apply_route_buffer, grow,
                                   migrate_route_buffers, remap_state,
                                   reshard_tree)
from repro.runtime.health import (HealthConfig, HealthMonitor,
                                  HealthReport, WorkerStatus,
                                  write_heartbeat)
from repro.runtime.recovery import (FaultEvent, FaultPlan, FaultSchedule,
                                    ReplicaChain, ResilientDriver,
                                    ResilientResult, StratumRunner,
                                    as_schedule, pack_state,
                                    run_with_failure, unpack_state)
from repro.runtime.retry import (IO_RETRYABLE, OperationTimeout,
                                 RecoveryExhausted, Retrier, RetryBudget,
                                 RetryPolicy)
from repro.runtime.straggler import SpeculationPolicy, StragglerMitigator

__all__ = ["CheckpointManager", "CheckpointCorruption", "atomic_write_json",
           "ChaosConfig", "generate_schedule",
           "HealthConfig", "HealthMonitor", "HealthReport",
           "WorkerStatus", "write_heartbeat",
           "grow", "remap_state", "reshard_tree",
           "migrate_route_buffers", "apply_route_buffer",
           "StratumRunner", "run_with_failure", "FaultPlan", "FaultEvent",
           "FaultSchedule", "as_schedule",
           "ReplicaChain", "ResilientDriver", "ResilientResult",
           "pack_state", "unpack_state",
           "RetryPolicy", "RetryBudget", "Retrier", "RecoveryExhausted",
           "OperationTimeout", "IO_RETRYABLE",
           "SpeculationPolicy", "StragglerMitigator"]
