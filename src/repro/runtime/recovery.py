"""Failure injection + the two recovery strategies of paper §6.6 (Fig 12).

Two layers:

  * The original toy harness — ``StratumRunner`` + ``run_with_failure`` —
    drives any one-stratum function with caller-supplied mutable
    extraction; it remains for the unit tests that pioneered the replay
    semantics.
  * The production integration — :class:`ResilientDriver`, reached
    through ``ShardedExecutor.run_resilient`` — makes the REAL engine
    fault-tolerant and elastic: the executor's own stratum function
    (density ladder, per-rung rehash strategy and all) runs one stratum
    per call; a :class:`ReplicaChain` persists each shard's changed-entry
    Δ set per stratum (a DeltaBuffer per shard, ring-replicated as in
    paper §4.1); an injected shard failure rebuilds the lost shard from
    replicas ONLY and resumes warm; an elastic rescale takes a fresh
    ``PartitionSnapshot``, migrates the dense state (``elastic.
    remap_state``) and pushes the chain's in-flight route buffers through
    ``combine_route`` under the new snapshot; and a straggler
    ``SpeculationPolicy`` re-issues slow shards against their replica.

Recovery strategies (paper §6.6, Fig 12):

  * ``restart``     — discard everything, start from stratum 0 (the Fig 12
    baseline; needs no mutable-state replication).
  * ``incremental`` — per stratum, every node replicates the *changed*
    entries of its mutable shard (the Δᵢ set — indices + payloads only) to
    its replica chain; on failure the lost shard is rebuilt by replaying
    those deltas onto the baseline, and execution resumes from the
    current stratum.  Monotone delta algorithms (min/sum refinement)
    re-converge from the restored shard — the paper's forward-progress
    guarantee under repeated failures.

The restored shard is reconstructed ONLY from replica checkpoints (never
from driver memory) — the simulation honors real failure semantics.
"""
from __future__ import annotations

import dataclasses
import os
import shutil
import time
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.delta import PAD_KEY, DeltaBuffer
from repro.core.fixpoint import (FixpointResult, StratumOutcome,
                                 stats_from_outcomes)
from repro.core.partition import PartitionSnapshot
from repro.runtime.checkpoint import CheckpointCorruption, CheckpointManager
from repro.runtime.elastic import migrate_route_buffers, remap_state
from repro.runtime.retry import (IO_RETRYABLE, RecoveryExhausted, Retrier,
                                 RetryBudget, RetryPolicy)
from repro.runtime.straggler import SpeculationPolicy, StragglerMitigator


@dataclasses.dataclass
class StratumRunner:
    """One-stratum-at-a-time fixpoint execution (same stratum_fn as the
    fused engine loop — functionally identical)."""

    stratum_fn: Callable          # (state, stratum_idx) -> (state, outcome)
    state: object
    live: int
    stratum: int = 0
    work_units: int = 0           # Σ emitted deltas ≈ work performed

    def step(self) -> StratumOutcome:
        new_state, outcome = self.stratum_fn(self.state,
                                             jnp.asarray(self.stratum))
        self.state = new_state
        self.live = int(outcome.live_count)
        self.stratum += 1
        self.work_units += max(int(outcome.emitted), 1)
        return outcome

    def done(self) -> bool:
        return self.live <= 0


def run_with_failure(make_runner: Callable[[], StratumRunner],
                     ckpt: CheckpointManager,
                     mutable_of: Callable[[object], np.ndarray],
                     restore_mutable: Callable[[object, np.ndarray, int],
                                               object],
                     fail_at: Optional[int], failed_node: int,
                     strategy: str = "incremental", max_strata: int = 500
                     ) -> dict:
    """Execute to convergence with one injected failure at ``fail_at``.

    mutable_of(state) -> np [nodes, block, W] — the full replicable
    mutable set (pack value+sent columns); restore_mutable(state, shard,
    node) writes one node's shard back.

    Returns Fig-12 metrics: total work (incl. redone), bytes replicated.
    """
    if strategy not in ("incremental", "restart"):
        raise ValueError(strategy)
    runner = make_runner()
    init_mut = np.asarray(mutable_of(runner.state)).copy()
    prev_mut = init_mut.copy()
    total_work = 0
    strata_executed = 0
    bytes_replicated = 0
    failed = False

    while not runner.done() and strata_executed < max_strata:
        if fail_at is not None and not failed \
                and runner.stratum == fail_at:
            failed = True
            ckpt.wipe_node(failed_node)          # node dies; disk gone
            if strategy == "restart":
                total_work += runner.work_units
                runner = make_runner()
                prev_mut = init_mut.copy()
                continue
            # Incremental: rebuild the lost shard from REPLICA deltas only.
            shard = init_mut[failed_node].copy()
            for _, keys, payload in ckpt.replay_deltas(
                    failed_node, since_step=-1, from_replica=True):
                shard[keys] = payload
            runner.state = restore_mutable(runner.state, shard,
                                           failed_node)
            prev_mut[failed_node] = shard

        runner.step()
        strata_executed += 1
        if strategy == "incremental":
            mut = np.asarray(mutable_of(runner.state))
            for node in range(mut.shape[0]):
                changed = np.any(mut[node] != prev_mut[node], axis=-1)
                keys = np.nonzero(changed)[0].astype(np.int32)
                if len(keys) == 0:
                    continue
                bytes_replicated += ckpt.save_delta(
                    node, runner.stratum, keys, mut[node][keys]
                ) * ckpt.replication
            prev_mut = mut.copy()

    total_work += runner.work_units
    return {
        "strategy": strategy,
        "fail_at": fail_at,
        "strata_executed": strata_executed,
        "total_work_units": total_work,
        "bytes_replicated": bytes_replicated,
        "converged": runner.done(),
        "final_state": runner.state,
    }


# ---------------------------------------------------------------------------
# Production integration: replica chains + the resilient elastic driver.
# ---------------------------------------------------------------------------

def pack_state(state) -> np.ndarray:
    """Default mutable-set packing: stack every state leaf (each
    ``[S, block]`` float32) along a trailing W axis -> ``[S, block, W]``.

    All shipped graph algorithms (PageRank, SSSP, CC, adsorption state
    vectors) satisfy the leaf contract; exotic states pass explicit
    ``pack``/``unpack`` callables to the driver instead."""
    leaves = jax.tree.leaves(state)
    if not leaves or any(getattr(leaf, "ndim", 0) != 2 for leaf in leaves) \
            or len({leaf.shape for leaf in leaves}) != 1 \
            or any(leaf.dtype != jnp.float32 for leaf in leaves):
        raise ValueError(
            "default packing needs uniform float32 [S, block] state "
            "leaves (a non-f32 leaf would silently round-trip through "
            "f32 on restore); provide pack/unpack callables for this "
            "state pytree")
    return np.stack([np.asarray(leaf, np.float32) for leaf in leaves],
                    axis=-1)


def unpack_state(template, packed: np.ndarray):
    """Inverse of :func:`pack_state`: ``template`` supplies the pytree
    structure (its leaf SHAPES may differ — rescale changes them)."""
    leaves, treedef = jax.tree.flatten(template)
    new = [jnp.asarray(packed[..., i], np.float32)
           for i in range(len(leaves))]
    return jax.tree.unflatten(treedef, new)


class ReplicaChain:
    """Per-shard replica chain of changed-entry DeltaBuffers (paper §4.1).

    Epoch layout under ``root``: each epoch (opened at query start and at
    every restart/rescale — the lifetime of one partition snapshot) holds
    one full *baseline* checkpoint per shard (step 0) plus one
    changed-entry delta checkpoint per (shard, stratum) — global keys +
    full replacement payload rows — all ring-replicated onto the next
    ``snapshot.replication − 1`` nodes by the CheckpointManager.

    ``restore_shard`` rebuilds a shard from replicas only: baseline +
    in-order replay (each entry overwrites its rows — values are full
    replacements, so replay is exact to the last persisted stratum).

    ``migrate`` is the elastic path: chain entries are *in-flight route
    buffers* keyed by GLOBAL key, so a fresh snapshot re-routes them
    through the engine's own ``combine_route`` (``"replace"`` combiner =
    chronological last-writer per key) onto the new owners' chains, and
    the new epoch's baseline is the remapped initial state.

    The chain OWNS ``root``: with the default ``fresh=True`` any existing
    contents are deleted at construction (a replica chain is an
    intra-query structure — stale entries from a previous query would
    poison replay).  Point it at a dedicated directory.
    """

    def __init__(self, root: str, snapshot: PartitionSnapshot,
                 payload_width: int, fresh: bool = True,
                 retrier=None, keep_epochs: int = 2):
        self.root = root
        self.snapshot = snapshot
        self.payload_width = payload_width
        self.epoch = -1
        self.bytes_replicated = 0
        self.bytes_baseline = 0
        # runtime.retry.Retrier shared by every epoch's
        # CheckpointManager: replica reads retry transient errors with
        # seeded backoff; corrupt checkpoints quarantine and fall back.
        self.retrier = retrier
        # Epoch GC (paper: accumulated iteration state is discarded when
        # no longer useful): once a partition snapshot is superseded,
        # only the last ``keep_epochs`` epochs stay on disk — the
        # current one plus the fallback.
        self.keep_epochs = max(int(keep_epochs), 1)
        self.quarantined = 0
        if fresh and os.path.isdir(root):
            shutil.rmtree(root)

    # ---- epoch lifecycle -------------------------------------------------
    def open_epoch(self, snapshot: Optional[PartitionSnapshot] = None
                   ) -> None:
        if snapshot is not None:
            self.snapshot = snapshot
        if hasattr(self, "ckpt"):
            self.quarantined += len(self.ckpt.quarantined)
        self.epoch += 1
        self.ckpt = CheckpointManager(
            os.path.join(self.root, f"epoch{self.epoch}"),
            num_nodes=self.snapshot.num_shards,
            replication=self.snapshot.replication,
            retrier=self.retrier)
        self._step = 0
        self.prev: Optional[np.ndarray] = None
        self._gc_epochs()

    @property
    def total_quarantined(self) -> int:
        """Corrupt checkpoint files quarantined across every epoch."""
        current = len(self.ckpt.quarantined) if hasattr(self, "ckpt") else 0
        return self.quarantined + current

    def _gc_epochs(self) -> None:
        """Delete epoch directories superseded beyond ``keep_epochs``."""
        cutoff = self.epoch - self.keep_epochs
        if cutoff < 0 or not os.path.isdir(self.root):
            return
        for name in os.listdir(self.root):
            if not name.startswith("epoch"):
                continue
            try:
                k = int(name[len("epoch"):])
            except ValueError:
                continue
            if k <= cutoff:
                shutil.rmtree(os.path.join(self.root, name),
                              ignore_errors=True)

    def baseline(self, packed: np.ndarray) -> None:
        """Full per-shard snapshot (step 0) every restore replays from."""
        for s in range(self.snapshot.num_shards):
            self.ckpt.save_full(s, 0, {"mut": packed[s]})
        self.bytes_baseline += packed.nbytes * self.ckpt.replication
        self.prev = np.array(packed)
        self._step = 0

    # ---- per-stratum write side -----------------------------------------
    def append(self, packed: np.ndarray) -> int:
        """Persist each shard's changed-entry DeltaBuffer for the stratum
        just completed; returns bytes written across all replicas."""
        assert self.prev is not None, "baseline() must precede append()"
        self._step += 1
        written = 0
        for s in range(self.snapshot.num_shards):
            changed = np.any(packed[s] != self.prev[s], axis=-1)
            local = np.nonzero(changed)[0].astype(np.int32)
            if local.size == 0:
                continue
            gkeys = np.asarray(self.snapshot.global_keys(s, local),
                               np.int32)
            rows = packed[s][local]
            written += self.ckpt.save_delta(s, self._step, gkeys, rows) \
                * self.ckpt.replication
        self.prev = np.array(packed)
        self.bytes_replicated += written
        return written

    # ---- failure side ----------------------------------------------------
    def wipe(self, shard: int) -> None:
        self.ckpt.wipe_node(shard)

    def reseed(self, packed: np.ndarray) -> None:
        """Full re-replication barrier after a node replacement: every
        shard re-persists its current block at the chain's current step.
        The dead node's disk held replica copies of OTHER shards'
        baselines too — without re-seeding, a later restore (or
        speculation) of those shards would find holes in the ring."""
        for s in range(self.snapshot.num_shards):
            self.ckpt.save_full(s, self._step, {"mut": packed[s]})
        self.bytes_baseline += packed.nbytes * self.ckpt.replication
        self.prev = np.array(packed)

    def restore_shard(self, shard: int,
                      exclude_self: bool = False) -> np.ndarray:
        """Rebuild one shard's mutable block from replica checkpoints ONLY
        (baseline + in-order changed-entry replay)."""
        block = self.prev.shape[1] if self.prev is not None \
            else self.snapshot.block_size
        like = {"mut": np.zeros((block, self.payload_width), np.float32)}
        tree, base_step = self.ckpt.load_full(
            shard, like, from_replica=True, exclude_self=exclude_self)
        out = np.array(tree["mut"], np.float32)
        # merge_sources: after a wipe + partial re-write of the shard's
        # own directory, the complete history is the UNION of its own
        # post-recovery entries and the replicas' older ones.
        for _, keys, payload in self.ckpt.replay_deltas(
                shard, since_step=base_step, from_replica=True,
                exclude_self=exclude_self, merge_sources=True):
            local = np.asarray(self.snapshot.local_index(
                jnp.asarray(keys, jnp.int32)))
            out[local] = payload
        return out

    # ---- elastic side ----------------------------------------------------
    def migrate(self, new_snapshot: PartitionSnapshot,
                new_init_packed: np.ndarray,
                current_packed: np.ndarray) -> DeltaBuffer:
        """Fresh snapshot taken (rescale): open a new epoch whose baseline
        is the REMAPPED initial state, and re-route the old chain's
        in-flight buffers through ``combine_route`` under the new
        snapshot so each new owner's chain starts with exactly the
        changed entries of the keys it now owns."""
        entries = []
        for s in range(self.snapshot.num_shards):
            for step, keys, payload in self.ckpt.replay_deltas(
                    s, since_step=0, from_replica=True,
                    merge_sources=True):
                entries.append((step, keys, payload))
        entries.sort(key=lambda t: t[0])          # chronological per key
        routed = migrate_route_buffers(
            new_snapshot, [(k, p) for _, k, p in entries],
            self.payload_width)
        self.open_epoch(new_snapshot)
        self.baseline(new_init_packed)
        if int(routed.count) > 0:
            self._step = 1
            seg = new_snapshot.block_size
            keys = np.asarray(routed.keys)
            payload = np.asarray(routed.payload)
            for s in range(new_snapshot.num_shards):
                k = keys[s * seg:(s + 1) * seg]
                p = payload[s * seg:(s + 1) * seg]
                live = k != int(PAD_KEY)
                if not live.any():
                    continue
                self.bytes_replicated += self.ckpt.save_delta(
                    s, 1, k[live].astype(np.int32), p[live]) \
                    * self.ckpt.replication
        self.prev = np.array(current_packed)
        return routed


@dataclasses.dataclass
class FaultPlan:
    """Deterministic single-fault/elasticity plan for one resilient run.

    ``fail_at``/``rescale_at`` are stratum indices: the event fires at the
    START of that stratum (after stratum ``k−1``'s replica persistence —
    the paper's punctuation barrier includes replication).  Both may be
    set; ``failed_shard`` is interpreted under the snapshot current at
    failure time.  ``strategy`` picks the Fig 12 recovery mode.

    This is the one-fault-per-run legacy interface; compound runs
    (repeated failures, correlated replica loss, failure during
    recovery/rescale, stragglers) use :class:`FaultSchedule` — a
    FaultPlan converts losslessly via :meth:`to_schedule`.
    """

    fail_at: Optional[int] = None
    failed_shard: int = 0
    strategy: str = "incremental"        # "incremental" | "restart"
    rescale_at: Optional[int] = None
    new_num_shards: Optional[int] = None

    def __post_init__(self):
        if self.strategy not in ("incremental", "restart"):
            raise ValueError(
                f"FaultPlan.strategy must be 'incremental' or 'restart', "
                f"got {self.strategy!r}")
        if (self.rescale_at is not None) != (self.new_num_shards
                                             is not None):
            raise ValueError(
                "FaultPlan.rescale_at and FaultPlan.new_num_shards must "
                f"be set together, got rescale_at={self.rescale_at!r}, "
                f"new_num_shards={self.new_num_shards!r}")
        for field in ("fail_at", "rescale_at"):
            v = getattr(self, field)
            if v is not None and v < 0:
                raise ValueError(
                    f"FaultPlan.{field} must be a stratum index >= 0, "
                    f"got {v!r}")
        if self.failed_shard < 0:
            raise ValueError(
                f"FaultPlan.failed_shard must be >= 0, got "
                f"{self.failed_shard!r}")
        if self.new_num_shards is not None and self.new_num_shards < 1:
            raise ValueError(
                f"FaultPlan.new_num_shards must be >= 1, got "
                f"{self.new_num_shards!r}")
        if self.fail_at is not None and self.fail_at == self.rescale_at:
            raise ValueError(
                f"FaultPlan.fail_at and FaultPlan.rescale_at collide on "
                f"stratum {self.fail_at}: the firing order would be "
                "ambiguous — use FaultSchedule, whose event list order "
                "is the firing order, for compound same-stratum events")

    def to_schedule(self) -> "FaultSchedule":
        events = []
        if self.rescale_at is not None:
            events.append(FaultEvent(
                kind="rescale", at=self.rescale_at,
                new_num_shards=self.new_num_shards))
        if self.fail_at is not None:
            events.append(FaultEvent(kind="fail", at=self.fail_at,
                                     shard=self.failed_shard))
        events.sort(key=lambda e: e.at)
        return FaultSchedule(events=tuple(events), strategy=self.strategy)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted chaos event.

    ``at`` is the stratum at whose START the event fires (events sharing
    a stratum fire in schedule order).  Kinds:

      * ``"fail"``     — shard ``shard``'s node dies (disk wiped).  With
        ``correlated=True`` its first ring replica dies too — the
        compound loss that forces recovery to the surviving replica, or
        (when none survives) the restart fallback.  ``during`` places
        the failure relative to ongoing control flow: ``"stratum"``
        (default) at the stratum barrier, ``"recovery"`` while an
        earlier failure's recovery is in flight (recovery must be
        re-entrant), ``"rescale"`` in the middle of an elastic rescale's
        migration (fires under the NEW snapshot).
      * ``"rescale"``  — elastic re-snapshot to ``new_num_shards``.
      * ``"straggle"`` — transient straggler: shard ``shard``'s measured
        latency for that stratum is multiplied by ``slowdown`` (feeds
        the SpeculationPolicy; never changes results).
    """

    kind: str
    at: int
    shard: int = 0
    correlated: bool = False
    during: str = "stratum"       # "stratum" | "recovery" | "rescale"
    new_num_shards: Optional[int] = None
    slowdown: float = 0.0

    def __post_init__(self):
        if self.kind not in ("fail", "rescale", "straggle"):
            raise ValueError(
                f"FaultEvent.kind must be 'fail', 'rescale' or "
                f"'straggle', got {self.kind!r}")
        if self.at < 0:
            raise ValueError(
                f"FaultEvent.at must be a stratum index >= 0, got "
                f"{self.at!r}")
        if self.shard < 0:
            raise ValueError(
                f"FaultEvent.shard must be >= 0, got {self.shard!r}")
        if self.during not in ("stratum", "recovery", "rescale"):
            raise ValueError(
                f"FaultEvent.during must be 'stratum', 'recovery' or "
                f"'rescale', got {self.during!r}")
        if self.kind == "rescale":
            if self.new_num_shards is None or self.new_num_shards < 1:
                raise ValueError(
                    f"FaultEvent(kind='rescale') needs new_num_shards "
                    f">= 1, got {self.new_num_shards!r}")
            if self.during != "stratum":
                raise ValueError(
                    "FaultEvent(kind='rescale') only supports "
                    f"during='stratum', got {self.during!r}")
        if self.kind != "rescale" and self.new_num_shards is not None:
            raise ValueError(
                f"FaultEvent.new_num_shards only applies to "
                f"kind='rescale', got kind={self.kind!r} with "
                f"new_num_shards={self.new_num_shards!r}")
        if self.kind == "straggle":
            if self.slowdown <= 1.0:
                raise ValueError(
                    f"FaultEvent(kind='straggle') needs slowdown > 1.0, "
                    f"got {self.slowdown!r}")
            if self.during != "stratum":
                raise ValueError(
                    "FaultEvent(kind='straggle') only supports "
                    f"during='stratum', got {self.during!r}")


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """Ordered multi-event chaos schedule for one resilient run.

    Events must be ordered by ``at`` (non-decreasing); events sharing a
    stratum fire in list order, which makes compound scenarios explicit
    where FaultPlan would be ambiguous: ``[rescale@k, fail@k]`` is a
    failure immediately after the rescale (under the new snapshot).
    Every event fires at most once — after a restart the run re-passes
    earlier strata without re-firing spent events.
    """

    events: tuple = ()
    strategy: str = "incremental"        # "incremental" | "restart"

    def __post_init__(self):
        if self.strategy not in ("incremental", "restart"):
            raise ValueError(
                f"FaultSchedule.strategy must be 'incremental' or "
                f"'restart', got {self.strategy!r}")
        object.__setattr__(self, "events", tuple(self.events))
        for i, ev in enumerate(self.events):
            if not isinstance(ev, FaultEvent):
                raise ValueError(
                    f"FaultSchedule.events[{i}] must be a FaultEvent, "
                    f"got {ev!r}")
            if i and ev.at < self.events[i - 1].at:
                raise ValueError(
                    f"FaultSchedule.events must be ordered by 'at' "
                    f"(non-decreasing): events[{i}].at={ev.at} < "
                    f"events[{i - 1}].at={self.events[i - 1].at}")
            if ev.during == "recovery" and not any(
                    e.kind == "fail" and e.during != "recovery"
                    and e.at <= ev.at for e in self.events[:i]):
                raise ValueError(
                    f"FaultSchedule.events[{i}] has during='recovery' "
                    f"(at={ev.at}) but no earlier fail event triggers a "
                    "recovery for it to interrupt")
            if ev.during == "rescale" and not any(
                    e.kind == "rescale" and e.at == ev.at
                    for e in self.events[:i]):
                raise ValueError(
                    f"FaultSchedule.events[{i}] has during='rescale' "
                    f"(at={ev.at}) but no rescale event at that stratum "
                    "precedes it")

    @property
    def fail_count(self) -> int:
        return sum(1 for e in self.events if e.kind == "fail")

    @property
    def has_straggles(self) -> bool:
        return any(e.kind == "straggle" for e in self.events)


def as_schedule(plan) -> FaultSchedule:
    """Accept FaultPlan | FaultSchedule | None uniformly."""
    if plan is None:
        return FaultSchedule()
    if isinstance(plan, FaultSchedule):
        return plan
    if isinstance(plan, FaultPlan):
        return plan.to_schedule()
    raise ValueError(
        f"fault_plan must be a FaultPlan or FaultSchedule, got "
        f"{type(plan).__name__}")


@dataclasses.dataclass
class ResilientResult:
    """``result`` matches ``ShardedExecutor.run``'s FixpointResult (state +
    per-stratum stats of the surviving pass); ``metrics`` carries the
    Fig 12 accounting and every recovery/elastic/speculation event."""

    result: FixpointResult
    metrics: dict


class ResilientDriver:
    """Stratum-sliced fault-tolerant elastic fixpoint over the real engine.

    Uses ``executor.make_stratum_fn`` — the SAME laddered,
    route-strategy-dispatching stratum body the fused ``run`` loop
    compiles — so a failure-free resilient run is bit-identical to
    ``executor.run`` on both backends, stratum for stratum.
    """

    def __init__(self, executor, algo, state0, live0, immutable,
                 max_iters: int, mode: str = "delta",
                 explicit_cond: Optional[Callable] = None, *,
                 ckpt_root: str,
                 fault_plan=None,
                 policy: Optional[SpeculationPolicy] = None,
                 latency_model: Optional[Callable] = None,
                 remake: Optional[Callable] = None,
                 pack: Callable = pack_state,
                 unpack: Callable = unpack_state,
                 retry: Optional[RetryPolicy] = None,
                 budget: Optional[RetryBudget] = None,
                 tracer=None, metrics=None):
        self.executor = executor
        self.algo = algo
        self.immutable = immutable
        self.max_iters = int(max_iters)
        self.mode = mode
        self.explicit_cond = explicit_cond
        # ``fault_plan`` accepts the legacy single-fault FaultPlan or a
        # multi-event FaultSchedule; internally everything runs off the
        # schedule (events fire at most once, in order).
        self.schedule = as_schedule(fault_plan)
        self._pending = list(self.schedule.events)
        self.remake = remake
        self.latency_model = latency_model
        # Observability: the driver shares the executor's tracer unless
        # given its own; per-stratum wall clocks are ALWAYS measured
        # (host perf_counter around each stratum slice) — they are the
        # measured latency feed for SpeculationPolicy when no synthetic
        # latency_model is supplied.
        self.tracer = tracer if tracer is not None \
            else getattr(executor, "tracer", None)
        self.metrics = metrics
        from repro.obs.trace import MeasuredLatencies
        self.measured = MeasuredLatencies()
        self.stratum_walls: list[float] = []
        self._pack, self._unpack = pack, unpack
        self.snapshot = executor.snapshot
        self.stratum_fn = executor.make_stratum_fn(
            algo, immutable, mode, explicit_cond=explicit_cond)
        self.state = state0
        self.live = int(live0)
        self.live0 = int(live0)
        self._init_packed = pack(state0)
        self.replicate = self.schedule.strategy == "incremental"
        self.stratum = 0
        self.outcomes: list[StratumOutcome] = []
        self.work_units = 0
        self.strata_executed = 0
        self.events: list[dict] = []
        # Retry/timeout/backoff for every recovery-path disk touch.  The
        # budget (when given) is the run's hard recovery allowance:
        # exhausting it raises RecoveryExhausted, the signal the view
        # layer converts into a staleness-tagged degraded answer.
        self.budget = budget
        self.retrier = Retrier(policy=retry or RetryPolicy(),
                               budget=budget,
                               on_event=self._on_retry_event)
        self.chain = ReplicaChain(ckpt_root, self.snapshot,
                                  self._init_packed.shape[-1],
                                  retrier=self.retrier)
        self.policy = policy
        # Straggler mitigation activates for an explicit policy, a
        # synthetic latency model, or a schedule injecting stragglers
        # (chaos runs get the default policy so injected stragglers
        # actually exercise speculation).
        want_mitigator = (policy is not None or latency_model is not None
                          or self.schedule.has_straggles)
        self.mitigator = (StragglerMitigator(
            self.snapshot.num_shards, policy,
            replicas_of=self.snapshot.replicas_of)
            if want_mitigator else None)
        # Armed transient-straggler injections: stratum -> [(shard, x)].
        self._straggles: dict[int, list] = {}
        # Re-entrant recovery: failures arriving while recovery is in
        # flight join the queue instead of recursing.
        self._recovery_queue: list[int] = []
        self._recovering = False
        self.recoveries = 0
        self.restarts = 0
        # Wall spent inside _recover (restore + replay + reseed): the
        # "recovery work" a failure costs, comparable across the
        # simulated and distributed drivers (same code path).
        self.recovery_wall_s = 0.0

    # ---- helpers ---------------------------------------------------------
    def _packed(self) -> np.ndarray:
        return self._pack(self.state)

    def done(self) -> bool:
        return self.live <= 0

    def _event(self, ev: dict) -> None:
        """Record a recovery/elastic event everywhere at once: the
        metrics dict the caller gets back, the tracer timeline, and the
        metrics registry counters."""
        self.events.append(ev)
        if self.tracer is not None:
            self.tracer.instant(ev["event"],
                                **{k: v for k, v in ev.items()
                                   if k != "event"})
        if self.metrics is not None:
            self.metrics.counter(f"recovery.{ev['event']}s").inc()

    # ---- retry / timeout observability ----------------------------------
    def _on_retry_event(self, ev: dict) -> None:
        """Every retry/timeout on the checkpoint I/O path lands in the
        run's event stream, and a TIMEOUT on a shard's replica read is a
        straggler signal: it feeds the SpeculationPolicy so the next
        barrier speculates that shard exactly as a slow stratum would."""
        self._event({"event": f"io_{ev['kind']}",
                     **{k: v for k, v in ev.items() if k != "kind"}})
        if ev["kind"] == "timeout" and ev.get("shard") is not None \
                and self.mitigator is not None:
            self.mitigator.note_timeout(ev["shard"])

    # ---- fault handling --------------------------------------------------
    def _fire_events(self) -> bool:
        """Fire every pending start-of-stratum event for the current
        stratum, in schedule order.  Returns True when handling ended in
        a restart (the caller re-enters the loop from stratum 0)."""
        while self._pending and self._pending[0].at == self.stratum:
            if self._pending[0].during != "stratum":
                # A during='recovery' event whose anchoring recovery
                # never reached it (the anchor fell back to restart, or
                # recovered before this stratum): the interrupt window
                # is gone — fire it as an ordinary barrier failure so
                # the schedule still injects every fault exactly once.
                # (during='rescale' events are always consumed by their
                # same-stratum rescale, which precedes them in order.)
                ev = self._pending.pop(0)
                if self._do_fail(ev):
                    return True
                continue
            ev = self._pending.pop(0)
            if ev.kind == "rescale":
                self._do_rescale(ev)
                if self.done():
                    return False
            elif ev.kind == "straggle":
                self._straggles.setdefault(ev.at, []).append(
                    (ev.shard, ev.slowdown))
                self._event({"event": "straggle_injected",
                             "stratum": ev.at, "shard": ev.shard,
                             "slowdown": ev.slowdown})
            else:
                if self._do_fail(ev):
                    return True
        return False

    def _pop_nested(self, during: str) -> list:
        """Pending ``during='recovery'|'rescale'`` events that are due
        (their stratum reached) — fired from inside the handler they
        interrupt."""
        due, rest = [], []
        for ev in self._pending:
            if ev.during == during and ev.at <= self.stratum:
                due.append(ev)
            else:
                rest.append(ev)
        self._pending = rest
        return due

    def _wipe_for(self, ev) -> list[int]:
        """Wipe the event's shard (and, for a correlated failure, its
        first ring replica) — returns the dead shards."""
        dead = [ev.shard]
        if ev.correlated:
            reps = self.snapshot.replicas_of(ev.shard)
            if reps:
                dead.append(reps[0])
        for s in dead:
            self.chain.wipe(s)                   # node dies; disk gone
        self._event({"event": "failure", "stratum": self.stratum,
                     "shard": ev.shard, "correlated": ev.correlated,
                     "during": ev.during,
                     "strategy": self.schedule.strategy})
        return dead

    def _do_fail(self, ev) -> bool:
        """Returns True when the run restarted (skip this stratum's body
        and re-enter the loop from stratum 0)."""
        dead = self._wipe_for(ev)
        if self.schedule.strategy == "restart":
            self._restart()
            return True
        return self._recover(dead)

    def _restart(self) -> None:
        """Fig 12 restart: discard everything, re-enter from stratum 0.
        Also the fallback when replicas are insufficient to rebuild a
        shard (correlated loss beyond the replication factor)."""
        if self.budget is not None:
            self.budget.draw_recovery("restart")
        self.restarts += 1
        self._event({"event": "restart", "stratum": self.stratum})
        self.state = self._unpack(self.state, self._init_packed)
        self.live = int(self.executor.live_count(
            self.algo, self.state, self.immutable)) or self.live0
        self.stratum = 0
        self.outcomes = []           # stats describe the surviving pass
        self._recovery_queue.clear()
        self.chain.open_epoch()
        if self.replicate:
            self.chain.baseline(self._init_packed)

    def _recover(self, shards: list[int]) -> bool:
        """Queue-driven incremental recovery; RE-ENTRANT: failures that
        strike while recovery is in flight (scheduled ``during=
        'recovery'`` events, or real wipe races surfacing as retryable
        I/O errors) join the queue and are drained in turn.  Returns
        True when recovery fell back to a restart."""
        self._recovery_queue.extend(shards)
        if self._recovering:
            return False              # nested call: the outer loop drains
        self._recovering = True
        t_rec = time.perf_counter()
        try:
            first = True
            while self._recovery_queue:
                shard = self._recovery_queue.pop(0)
                if self.budget is not None:
                    self.budget.draw_recovery(f"restore shard {shard}")
                self.recoveries += 1
                try:
                    restored = self.retrier.call(
                        self.chain.restore_shard, shard,
                        op=f"restore:{shard}", shard=shard,
                        retryable=IO_RETRYABLE)
                except RecoveryExhausted as e:
                    if e.kind.startswith("budget:"):
                        raise          # run-wide budget gone: degrade
                    return self._recovery_fallback(shard, e)
                except (FileNotFoundError, CheckpointCorruption) as e:
                    # Replicas insufficient (correlated loss beyond the
                    # replication factor) or every copy corrupt: fall
                    # back — older epoch via restart-from-initial.
                    return self._recovery_fallback(shard, e)
                packed = self._packed()
                packed[shard] = restored
                self.state = self._unpack(self.state, packed)
                self.chain.prev = packed
                self._event({"event": "recovery", "stratum": self.stratum,
                             "shard": shard})
                if first:
                    first = False
                    # Mid-recovery failures scheduled for this stratum
                    # strike NOW — while the recovery that the first
                    # restore started is still in flight.
                    for ev in self._pop_nested("recovery"):
                        self._recovery_queue.extend(self._wipe_for(ev))
            # Replacement nodes are live again: re-seed full replication
            # so the ring has no holes where the dead nodes' disks held
            # OTHER shards' replica copies.
            self.chain.reseed(self._packed())
            # Resume warm: Δ₀ of the restored state re-derived from
            # active_fn, execution continues from the CURRENT stratum.
            self.live = int(self.executor.live_count(
                self.algo, self.state, self.immutable))
            return False
        finally:
            self._recovering = False
            self.recovery_wall_s += time.perf_counter() - t_rec

    def _recovery_fallback(self, shard: int, err: Exception) -> bool:
        """Incremental restore impossible for ``shard`` — restart from
        the initial state (always reachable: the driver re-baselines a
        fresh epoch), keeping the run recoverable at restart cost."""
        self._event({"event": "recovery_fallback", "stratum": self.stratum,
                     "shard": shard, "reason": type(err).__name__,
                     "detail": str(err)[:200]})
        self._restart()
        return True

    def _do_rescale(self, ev) -> None:
        if self.remake is None:
            raise ValueError(
                "rescale requires remake(new_snapshot) -> (executor, "
                "algo, immutable)")
        new_snap = self.snapshot.resnapshot(ev.new_num_shards)
        new_exec, new_algo, new_imm = self.remake(new_snap)
        if new_exec.snapshot != new_snap:
            raise ValueError("remake returned an executor with a "
                             "mismatched snapshot")
        # Dense state migration — the all_to_all a real cluster would run.
        packed = self._packed()
        new_packed = np.asarray(remap_state(
            self.snapshot, new_snap, jnp.asarray(packed)))
        new_init = np.asarray(remap_state(
            self.snapshot, new_snap, jnp.asarray(self._init_packed)))
        self.state = self._unpack(self.state, new_packed)
        self._init_packed = new_init
        if self.replicate:
            self.chain.migrate(new_snap, new_init, new_packed)
        self._event({"event": "rescale", "stratum": self.stratum,
                     "from_shards": self.snapshot.num_shards,
                     "to_shards": new_snap.num_shards})
        self.snapshot = new_snap
        self.executor = new_exec
        self.algo = new_algo           # capacities are snapshot-bound
        self.immutable = new_imm
        self.stratum_fn = new_exec.make_stratum_fn(
            self.algo, new_imm, self.mode,
            explicit_cond=self.explicit_cond)
        if self.mitigator is not None:
            self.mitigator = StragglerMitigator(
                new_snap.num_shards, self.policy,
                replicas_of=new_snap.replicas_of)
        self.live = int(new_exec.live_count(
            self.algo, self.state, self.immutable))
        # Failure-during-rescale: scheduled mid-rescale failures strike
        # under the NEW snapshot, with the migrated chain barely landed —
        # recovery must rebuild from the just-migrated epoch.
        for fev in self._pop_nested("rescale"):
            self._do_fail(fev)

    # ---- straggler speculation ------------------------------------------
    def _observe_straggler(self) -> None:
        # Speculation re-issues work against a shard's REPLICA — without
        # a replica chain (restart strategy, replication < 2, single
        # shard) there is nothing to re-issue against, so no speculation
        # or saved-time credit is recorded at all.
        if not self.replicate or self.snapshot.num_shards < 2 \
                or self.snapshot.replication < 2:
            return
        if self.latency_model is not None:
            latencies = list(self.latency_model(self.stratum - 1))
            if len(latencies) != self.snapshot.num_shards:
                raise ValueError(
                    f"latency_model returned {len(latencies)} latencies "
                    f"for {self.snapshot.num_shards} shards — after a "
                    "rescale it must track the new shard count")
        else:
            # Measured feed (ROADMAP item 5 follow-up): the per-shard
            # wall clocks this driver just recorded for the completed
            # stratum — tracer probe arrivals under shard_map, the host
            # stratum wall on the simulated backend.
            latencies = self.measured(self.stratum - 1)
        # Armed transient-straggler injections (chaos schedule): inflate
        # the affected shard's measured latency for exactly this stratum
        # — the policy sees a real outlier, speculates, verifies; results
        # never change (the paper's straggler story is latency-only).
        for shard, slowdown in self._straggles.pop(self.stratum - 1, []):
            if shard < len(latencies):
                latencies[shard] *= slowdown
        report = self.mitigator.observe_stratum(latencies)
        if not report["speculations"]:
            return
        packed = self._packed()
        for decision in report["speculations"]:
            s = decision["shard"]
            # The replica chain is what makes speculation cheap (§4.1):
            # the replica rebuilds the slow shard's mutable state WITHOUT
            # the slow node's disk and must reach a bit-identical block.
            try:
                rebuilt = self.chain.restore_shard(s, exclude_self=True)
            except (FileNotFoundError, CheckpointCorruption) as e:
                # Replica hole (e.g. chaos wiped the ring neighbors):
                # speculation is impossible for this shard, not fatal —
                # the original (slow) shard's result stands.
                self._event({"event": "speculation_unavailable",
                             "stratum": self.stratum - 1, "shard": s,
                             "reason": type(e).__name__})
                continue
            ok = bool(np.array_equal(rebuilt, packed[s], equal_nan=True))
            self.mitigator.record_verification(s, ok, self.stratum - 1)
            self._event({"event": "speculation", "stratum": self.stratum - 1,
                         "shard": s, "replica": decision["replica"],
                         "verified": ok})

    # ---- external (real) failure signals ---------------------------------
    def _external_events(self) -> bool:
        """Barrier hook for drivers that bridge REAL failure signals —
        process death, missed leases, late heartbeats — into this
        driver's recovery machinery (see ``launch/distributed.py``).
        Called once per punctuation barrier, after scheduled injections.
        Returns True when handling ended in a restart (the caller
        re-enters the loop from stratum 0).  The base driver has no
        external signal source."""
        return False

    # ---- main loop -------------------------------------------------------
    def step(self) -> StratumOutcome:
        S = self.snapshot.num_shards
        stratum = self.stratum
        if self.tracer is not None:
            self.tracer.mark_shards(S)
        t0 = time.perf_counter()
        new_state, outcome = self.stratum_fn(
            self.state, jnp.asarray(self.stratum, jnp.int32))
        self.live = int(outcome.live_count)   # device sync: wall is real
        wall = time.perf_counter() - t0
        self.state = new_state
        self.stratum += 1
        self.strata_executed += 1
        self.work_units += max(int(outcome.emitted), 1)
        self.outcomes.append(outcome)
        # Measured per-shard latency for this stratum: per-shard probe
        # arrivals when the executor's tracer saw them (shard_map), the
        # host stratum wall for every shard otherwise.
        self.stratum_walls.append(wall)
        if self.tracer is not None:
            per_shard = self.tracer.per_shard_latencies(stratum, S,
                                                        default=wall)
        else:
            per_shard = [wall] * S
        self.measured.observe(per_shard)
        if self.tracer is not None:
            self.tracer.instant("stratum_sliced", tid="driver",
                               stratum=stratum, wall_s=wall,
                               emitted=int(outcome.emitted),
                               tier=int(outcome.tier),
                               route=int(outcome.route),
                               live_after=self.live)
        if self.metrics is not None:
            self.metrics.histogram(
                "recovery.stratum_seconds").observe(wall)
        return outcome

    def run(self) -> ResilientResult:
        self.chain.open_epoch()
        if self.replicate:
            self.chain.baseline(self._packed())
        while not self.done() and self.stratum < self.max_iters:
            if self._fire_events():
                continue                           # restarted from zero
            if self._external_events():
                continue                           # restarted from zero
            if self.done():
                break
            self.step()
            if self.replicate:
                if self.tracer is not None:
                    with self.tracer.span("replicate", tid="driver",
                                          stratum=self.stratum - 1) as a:
                        a["bytes"] = self.chain.append(self._packed())
                else:
                    self.chain.append(self._packed())
            if self.mitigator is not None:
                self._observe_straggler()
        result = FixpointResult(
            state=self.state,
            stats=stats_from_outcomes(self.outcomes, self.max_iters))
        if self.metrics is not None:
            self.metrics.counter("recovery.bytes_replicated").inc(
                self.chain.bytes_replicated)
        metrics = {
            "strategy": self.schedule.strategy,
            "converged": self.done(),
            "strata_executed": self.strata_executed,
            "total_work_units": self.work_units,
            "bytes_replicated": self.chain.bytes_replicated,
            "bytes_baseline": self.chain.bytes_baseline,
            "events": self.events,
            "final_num_shards": self.snapshot.num_shards,
            "stratum_wall_s": list(self.stratum_walls),
            "faults_injected": self.schedule.fail_count,
            "recoveries": self.recoveries,
            "restarts": self.restarts,
            "recovery_wall_s": round(self.recovery_wall_s, 6),
            "io_retries": sum(1 for e in self.retrier.events
                              if e["kind"] == "retry"),
            "io_timeouts": sum(1 for e in self.retrier.events
                               if e["kind"] == "timeout"),
            "checkpoints_quarantined": self.chain.total_quarantined,
        }
        if self.budget is not None:
            metrics["budget"] = self.budget.snapshot()
        if self.mitigator is not None:
            metrics["speculations"] = self.mitigator.speculated
            metrics["speculation_verified"] = self.mitigator.verified
            metrics["speculation_saved_time"] = self.mitigator.saved_time
            metrics["latency_source"] = (
                "model" if self.latency_model is not None else "measured")
        return ResilientResult(result=result, metrics=metrics)
