"""Failure injection + the two recovery strategies of paper §6.6 (Fig 12).

``StratumRunner`` drives a REX fixpoint one stratum per call (outside the
fused ``lax.while_loop``), so a node failure can be injected between
strata; ``run_with_failure`` then recovers with either strategy:

  * ``restart``     — discard everything, start from stratum 0 (the Fig 12
    baseline; needs no mutable-state replication).
  * ``incremental`` — per stratum, every node replicates the *changed*
    entries of its mutable shard (the Δᵢ set — indices + payloads only) to
    its replica chain; on failure the lost shard is rebuilt by replaying
    those deltas onto the initial state, and execution resumes from the
    current stratum.  Monotone delta algorithms (min/sum refinement)
    re-converge from the restored shard — the paper's forward-progress
    guarantee under repeated failures.

The restored shard is reconstructed ONLY from replica checkpoints (never
from driver memory) — the simulation honors real failure semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.fixpoint import StratumOutcome
from repro.runtime.checkpoint import CheckpointManager


@dataclasses.dataclass
class StratumRunner:
    """One-stratum-at-a-time fixpoint execution (same stratum_fn as the
    fused engine loop — functionally identical)."""

    stratum_fn: Callable          # (state, stratum_idx) -> (state, outcome)
    state: object
    live: int
    stratum: int = 0
    work_units: int = 0           # Σ emitted deltas ≈ work performed

    def step(self) -> StratumOutcome:
        new_state, outcome = self.stratum_fn(self.state,
                                             jnp.asarray(self.stratum))
        self.state = new_state
        self.live = int(outcome.live_count)
        self.stratum += 1
        self.work_units += max(int(outcome.emitted), 1)
        return outcome

    def done(self) -> bool:
        return self.live <= 0


def run_with_failure(make_runner: Callable[[], StratumRunner],
                     ckpt: CheckpointManager,
                     mutable_of: Callable[[object], np.ndarray],
                     restore_mutable: Callable[[object, np.ndarray, int],
                                               object],
                     fail_at: Optional[int], failed_node: int,
                     strategy: str = "incremental", max_strata: int = 500
                     ) -> dict:
    """Execute to convergence with one injected failure at ``fail_at``.

    mutable_of(state) -> np [nodes, block, W] — the full replicable
    mutable set (pack value+sent columns); restore_mutable(state, shard,
    node) writes one node's shard back.

    Returns Fig-12 metrics: total work (incl. redone), bytes replicated.
    """
    if strategy not in ("incremental", "restart"):
        raise ValueError(strategy)
    runner = make_runner()
    init_mut = np.asarray(mutable_of(runner.state)).copy()
    prev_mut = init_mut.copy()
    total_work = 0
    strata_executed = 0
    bytes_replicated = 0
    failed = False

    while not runner.done() and strata_executed < max_strata:
        if fail_at is not None and not failed \
                and runner.stratum == fail_at:
            failed = True
            ckpt.wipe_node(failed_node)          # node dies; disk gone
            if strategy == "restart":
                total_work += runner.work_units
                runner = make_runner()
                prev_mut = init_mut.copy()
                continue
            # Incremental: rebuild the lost shard from REPLICA deltas only.
            shard = init_mut[failed_node].copy()
            for _, keys, payload in ckpt.replay_deltas(
                    failed_node, since_step=-1, from_replica=True):
                shard[keys] = payload
            runner.state = restore_mutable(runner.state, shard,
                                           failed_node)
            prev_mut[failed_node] = shard

        runner.step()
        strata_executed += 1
        if strategy == "incremental":
            mut = np.asarray(mutable_of(runner.state))
            for node in range(mut.shape[0]):
                changed = np.any(mut[node] != prev_mut[node], axis=-1)
                keys = np.nonzero(changed)[0].astype(np.int32)
                if len(keys) == 0:
                    continue
                bytes_replicated += ckpt.save_delta(
                    node, runner.stratum, keys, mut[node][keys]
                ) * ckpt.replication
            prev_mut = mut.copy()

    total_work += runner.work_units
    return {
        "strategy": strategy,
        "fail_at": fail_at,
        "strata_executed": strata_executed,
        "total_work_units": total_work,
        "bytes_replicated": bytes_replicated,
        "converged": runner.done(),
        "final_state": runner.state,
    }
