"""Heartbeat/lease failure detection for the multi-process launch path.

The simulated chaos layer (``runtime/chaos.py``) injects failures by
fiat; a REAL worker process dies without telling anyone.  This module is
the coordinator-side machinery that turns real process behavior into the
exact event vocabulary the recovery stack already speaks:

  * Workers **lease** their shards from the coordinator and renew the
    lease by heartbeating over a lightweight file channel (one atomic
    JSON per worker, written with the same tmp+rename discipline as
    checkpoint manifests — a reader never sees a torn heartbeat).
  * :class:`HealthMonitor` polls the channel at every punctuation
    barrier.  A worker whose lease deadline passes — or whose process is
    observably gone, the fast local path — is declared dead, and every
    shard it leased becomes a ``FaultEvent(kind="fail")``: the SAME
    event an injected :class:`~repro.runtime.recovery.FaultSchedule`
    failure produces, so the resilient driver's queue-driven recovery
    handles real process loss verbatim.
  * A worker that is late but inside its lease (a real SIGSTOP, GC
    pause, or network wobble) is a **straggle signal**: the monitor
    reports the shard + measured age so the driver feeds it to the
    ``SpeculationPolicy`` exactly as a slow stratum would.

All channel I/O goes through the existing ``runtime/retry.py``
``RetryPolicy`` machinery (a heartbeat read can race its writer's
rename on some filesystems), and every state transition is mirrored to
the tracer (per-worker timeline rows: ``lease_expired`` /
``heartbeat_late`` instants) and the metrics registry (``health.*``).

Timestamps are ``time.monotonic()``: on one host it is comparable
across processes (CLOCK_MONOTONIC is system-wide), which is all the
single-box multi-process regime needs; a true multi-NIC deployment
would swap in coordinator-stamped receive times — the monitor only ever
compares against its own clock reads.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

# The channel layout + atomic I/O live in the import-light
# ``launch/channel.py`` (workers must not import repro.runtime before
# ``jax.distributed.initialize``); re-exported here for the
# coordinator-side API.
from repro.launch.channel import (ack_path, heartbeat_path,  # noqa: F401
                                  lease_path, read_json, stratum_path,
                                  worker_dir, write_heartbeat,
                                  write_json)
from repro.runtime.recovery import FaultEvent
from repro.runtime.retry import IO_RETRYABLE, Retrier


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Lease/heartbeat tuning knobs (seconds).

    ``lease_ttl`` is the missed-lease deadline: a worker silent longer
    than this has lost every shard it leased.  ``straggle_after`` is the
    late-but-alive threshold feeding speculation.  Keep
    ``heartbeat_interval << straggle_after < lease_ttl`` — the defaults
    give a worker ~15 missed beats before it is declared dead.
    """

    lease_ttl: float = 1.5
    straggle_after: float = 0.4
    heartbeat_interval: float = 0.1
    ack_timeout: float = 1.0      # per-stratum work-ack deadline
    ready_timeout: float = 60.0   # worker bring-up deadline
    poll_interval: float = 0.005  # coordinator file-poll cadence

    def __post_init__(self):
        if not (0 < self.heartbeat_interval < self.straggle_after
                < self.lease_ttl):
            raise ValueError(
                "HealthConfig needs 0 < heartbeat_interval < "
                f"straggle_after < lease_ttl, got "
                f"{self.heartbeat_interval}/{self.straggle_after}/"
                f"{self.lease_ttl}")


# ---------------------------------------------------------------------------
# Coordinator-side monitor.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WorkerStatus:
    worker_id: int
    shards: Tuple[int, ...]
    state: str                 # "ok" | "late" | "dead"
    age: float                 # seconds since last renewal (inf: never)
    seq: int = -1
    pid: Optional[int] = None


@dataclasses.dataclass
class HealthReport:
    """One barrier's health observation.

    ``fail_events`` carry one :class:`FaultEvent` per shard whose lease
    just died — ready to hand to the resilient driver's recovery queue.
    ``straggles`` are ``(shard, age_seconds)`` late-but-alive signals.
    """

    statuses: List[WorkerStatus]
    fail_events: List[FaultEvent]
    dead_workers: List[int]
    straggles: List[Tuple[int, float]]

    @property
    def alive(self) -> int:
        return sum(1 for s in self.statuses if s.state != "dead")


class HealthMonitor:
    """Coordinator-side lease table over the heartbeat channel.

    ``ownership`` maps worker id → the shards it leases; a worker's
    missed deadline emits a fail event per leased shard, stamped with
    the stratum the caller passes to :meth:`observe` (so the event is
    indistinguishable from an injected one at the same barrier).  A
    worker is reported dead exactly once; :meth:`reinstate` re-arms it
    after a replacement process takes over its lease.

    ``proc_alive(worker_id) -> bool | None`` is the optional fast local
    path (``Popen.poll``): an observably-dead process fails its lease
    immediately instead of waiting out the TTL — the file channel alone
    remains sufficient (and is all a multi-box deployment would have).
    """

    def __init__(self, root: str, ownership: Dict[int, List[int]],
                 config: Optional[HealthConfig] = None, *,
                 clock: Callable[[], float] = time.monotonic,
                 retrier: Optional[Retrier] = None,
                 proc_alive: Optional[Callable[[int], Optional[bool]]]
                 = None,
                 tracer=None, metrics=None):
        self.root = root
        self.ownership = {int(w): list(s) for w, s in ownership.items()}
        self.config = config or HealthConfig()
        self.clock = clock
        self.retrier = retrier or Retrier()
        self.proc_alive = proc_alive
        self.tracer = tracer
        self.metrics = metrics
        self._dead: set = set()
        # Leases granted at construction: write the grant per worker so
        # the channel itself documents who leases what (observability +
        # the worker echoes it back in heartbeats).
        for w, shards in self.ownership.items():
            self._grant(w, shards)

    # ---- lease table ----------------------------------------------------
    def _grant(self, worker_id: int, shards: List[int]) -> None:
        write_json(lease_path(self.root, worker_id), {
            "worker_id": worker_id, "shards": list(shards),
            "ttl_s": self.config.lease_ttl, "granted_t": self.clock()})

    def set_ownership(self, ownership: Dict[int, List[int]]) -> None:
        """Re-grant every lease (elastic rescale / worker replacement)."""
        self.ownership = {int(w): list(s) for w, s in ownership.items()}
        for w, shards in self.ownership.items():
            self._grant(w, shards)

    def reinstate(self, worker_id: int) -> None:
        """A replacement process holds the lease again: future missed
        deadlines are reportable anew."""
        self._dead.discard(worker_id)
        self._grant(worker_id, self.ownership.get(worker_id, []))

    # ---- observation ----------------------------------------------------
    def _read_heartbeat(self, worker_id: int) -> Optional[dict]:
        return self.retrier.call(
            read_json, heartbeat_path(self.root, worker_id),
            op=f"heartbeat:{worker_id}", retryable=IO_RETRYABLE)

    def observe(self, stratum: int = 0) -> HealthReport:
        """Classify every leased worker at this barrier."""
        now = self.clock()
        statuses, fail_events, dead_workers, straggles = [], [], [], []
        for w in sorted(self.ownership):
            shards = tuple(self.ownership[w])
            if w in self._dead:
                statuses.append(WorkerStatus(w, shards, "dead",
                                             float("inf")))
                continue
            hb = self._read_heartbeat(w)
            age = (now - hb["t"]) if hb else float("inf")
            proc_dead = (self.proc_alive is not None
                         and self.proc_alive(w) is False)
            if proc_dead or age > self.config.lease_ttl:
                state = "dead"
                self._dead.add(w)
                dead_workers.append(w)
                for s in shards:
                    fail_events.append(FaultEvent(kind="fail",
                                                  at=max(stratum, 0),
                                                  shard=s))
                if self.tracer is not None:
                    self.tracer.instant(
                        "lease_expired", tid=f"worker{w}",
                        worker=w, stratum=stratum, age_s=age,
                        proc_dead=proc_dead, shards=list(shards))
                if self.metrics is not None:
                    self.metrics.counter("health.lease_expiries").inc()
            elif age > self.config.straggle_after:
                state = "late"
                straggles.extend((s, age) for s in shards)
                if self.tracer is not None:
                    self.tracer.instant("heartbeat_late",
                                        tid=f"worker{w}", worker=w,
                                        stratum=stratum, age_s=age)
                if self.metrics is not None:
                    self.metrics.counter("health.straggle_signals").inc()
            else:
                state = "ok"
            if self.metrics is not None and hb:
                self.metrics.counter("health.heartbeats_seen").inc()
                self.metrics.gauge(
                    f"health.heartbeat_age_s.worker{w}").set(
                        age if age != float("inf") else -1.0)
            statuses.append(WorkerStatus(
                w, shards, state, age,
                seq=hb.get("seq", -1) if hb else -1,
                pid=hb.get("pid") if hb else None))
        report = HealthReport(statuses=statuses, fail_events=fail_events,
                              dead_workers=dead_workers,
                              straggles=straggles)
        if self.metrics is not None:
            self.metrics.gauge("health.workers_alive").set(report.alive)
        return report

    # ---- bring-up -------------------------------------------------------
    def wait_ready(self, worker_ids: Optional[List[int]] = None,
                   timeout: Optional[float] = None,
                   sleep: Callable[[float], None] = time.sleep) -> None:
        """Block until every worker has heartbeat at least once (lease
        taken up).  Raises TimeoutError naming the silent workers."""
        ids = sorted(self.ownership) if worker_ids is None \
            else list(worker_ids)
        deadline = self.clock() + (timeout if timeout is not None
                                   else self.config.ready_timeout)
        pending = set(ids)
        while pending:
            for w in sorted(pending):
                if self._read_heartbeat(w) is not None:
                    pending.discard(w)
            if not pending:
                return
            if self.clock() > deadline:
                raise TimeoutError(
                    f"workers {sorted(pending)} never heartbeat within "
                    f"{timeout if timeout is not None else self.config.ready_timeout}s "
                    f"(channel root {self.root})")
            sleep(self.config.poll_interval)
