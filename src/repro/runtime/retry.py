"""Deterministic retry/timeout/backoff for recovery-path I/O.

Every disk touch on the recovery path — checkpoint reads, journal
writes, replica restores — can fail transiently (NFS hiccup, a replica
mid-rebuild, a file being replaced under the reader) or hang.  The
chaos layer demands that all of them be (a) retried under a *bounded*
budget, (b) backed off deterministically so a seeded chaos schedule
replays bit-identically, and (c) reported upward instead of hanging the
punctuation barrier: a per-operation timeout is a *straggler signal*,
fed to the existing ``SpeculationPolicy`` so a slow replica read
triggers the same speculative re-issue a slow stratum does.

Design points:

  * **Seeded jitter.**  Backoff jitter is derived from
    ``crc32(seed, op, attempt)`` — not the process RNG — so two runs of
    the same chaos schedule sleep identically and interleave replays
    identically.  (``hash()`` is salted per process; never use it here.)
  * **Shared budget.**  ``RetryBudget`` caps total retry *attempts* and
    total *recoveries* across one resilient run; exhausting either
    raises :class:`RecoveryExhausted`, the signal the view layer turns
    into graceful degradation (serve the last converged snapshot with
    staleness metadata) instead of an exception to the user.
  * **Injectable clock/sleep.**  Tests and the chaos harness pass
    ``sleep=lambda s: None`` — the schedule of attempts is what matters,
    not wall time.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Callable, Optional, Sequence


class OperationTimeout(TimeoutError):
    """One attempt exceeded the policy's per-operation timeout."""

    def __init__(self, op: str, elapsed: float, timeout: float,
                 shard: Optional[int] = None):
        super().__init__(
            f"operation {op!r} took {elapsed:.3f}s "
            f"(timeout {timeout:.3f}s)")
        self.op = op
        self.elapsed = elapsed
        self.timeout = timeout
        self.shard = shard


class RecoveryExhausted(RuntimeError):
    """The retry/recovery budget ran out before the run could be healed.

    Carries enough context for the caller to degrade gracefully: what
    exhausted (``kind`` is "attempts" or "recoveries"), the per-event
    history, and the last underlying error.
    """

    def __init__(self, kind: str, op: str, attempts: int,
                 last_error: Optional[BaseException] = None,
                 events: Optional[list] = None):
        super().__init__(
            f"recovery budget exhausted ({kind}) during {op!r} "
            f"after {attempts} attempt(s)"
            + (f": {last_error!r}" if last_error else ""))
        self.kind = kind
        self.op = op
        self.attempts = attempts
        self.last_error = last_error
        self.events = events or []


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Budgeted attempts + exponential backoff + seeded jitter + timeout.

    ``backoff(attempt)`` for attempt k (0-based) is
    ``min(base_delay * 2**k, max_delay)`` scaled by a deterministic
    jitter factor in ``[1 - jitter, 1 + jitter]``.
    """

    max_attempts: int = 3         # attempts per operation (>= 1)
    base_delay: float = 0.005     # first backoff, seconds
    max_delay: float = 0.5        # backoff ceiling, seconds
    jitter: float = 0.5           # +/- fraction of the backoff randomized
    timeout: Optional[float] = None   # per-attempt wall budget (None = off)
    seed: int = 0                 # jitter stream seed

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"RetryPolicy.max_attempts must be >= 1, got "
                f"{self.max_attempts}")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError(
                f"RetryPolicy.jitter must be in [0, 1), got {self.jitter}")

    def backoff(self, op: str, attempt: int) -> float:
        raw = min(self.base_delay * (2.0 ** attempt), self.max_delay)
        # Deterministic per-(seed, op, attempt) jitter: two processes
        # replaying the same chaos schedule back off identically.
        h = zlib.crc32(f"{self.seed}:{op}:{attempt}".encode())
        unit = (h % 10_000) / 10_000.0               # [0, 1)
        return raw * (1.0 - self.jitter + 2.0 * self.jitter * unit)


class RetryBudget:
    """Run-wide caps shared by every retried operation of one driver.

    ``max_attempts`` bounds total retry attempts (first tries are free —
    only re-attempts draw down); ``max_recoveries`` bounds how many
    recovery actions (shard restores / restarts) one run may perform.
    Either cap set to ``None`` means unbounded.
    """

    def __init__(self, max_attempts: Optional[int] = None,
                 max_recoveries: Optional[int] = None):
        self.max_attempts = max_attempts
        self.max_recoveries = max_recoveries
        self.attempts_used = 0
        self.recoveries_used = 0

    def draw_attempt(self, op: str,
                     last_error: Optional[BaseException] = None) -> None:
        self.attempts_used += 1
        if self.max_attempts is not None \
                and self.attempts_used > self.max_attempts:
            # "budget:" prefix distinguishes the SHARED budget running
            # out (unrecoverable — must propagate to the degradation
            # layer) from one operation's local attempts running out
            # (recoverable — the driver falls back to restart).
            raise RecoveryExhausted("budget:attempts", op,
                                    self.attempts_used,
                                    last_error=last_error)

    def draw_recovery(self, op: str) -> None:
        self.recoveries_used += 1
        if self.max_recoveries is not None \
                and self.recoveries_used > self.max_recoveries:
            raise RecoveryExhausted("budget:recoveries", op,
                                    self.recoveries_used)

    def snapshot(self) -> dict:
        return {"attempts_used": self.attempts_used,
                "recoveries_used": self.recoveries_used,
                "max_attempts": self.max_attempts,
                "max_recoveries": self.max_recoveries}


#: Exceptions worth retrying on the checkpoint I/O path.  ``zipfile``
#: raises ``BadZipFile`` (a subclass of Exception via OSError? no —
#: ValueError) on torn npz reads; numpy re-raises them as ValueError /
#: EOFError depending on where the truncation lands; OSError covers the
#: filesystem class.  KeyError covers an npz missing an expected array
#: (half-written archive).
IO_RETRYABLE: tuple = (OSError, ValueError, EOFError, KeyError,
                      OperationTimeout)


class Retrier:
    """Callable wrapper applying one :class:`RetryPolicy` (plus an
    optional shared :class:`RetryBudget`) to recovery-path operations.

    ``on_event(dict)`` observes every retry/timeout — the resilient
    driver forwards these to its tracer/metrics, and timeout events with
    a ``shard`` feed the straggler speculation policy.
    """

    def __init__(self, policy: Optional[RetryPolicy] = None,
                 budget: Optional[RetryBudget] = None,
                 on_event: Optional[Callable[[dict], None]] = None,
                 sleep: Callable[[float], None] = time.sleep,
                 clock: Callable[[], float] = time.perf_counter):
        self.policy = policy or RetryPolicy()
        self.budget = budget
        self.on_event = on_event
        self.sleep = sleep
        self.clock = clock
        self.events: list[dict] = []
        self.timeouts: list[dict] = []

    def _emit(self, ev: dict) -> None:
        self.events.append(ev)
        if ev.get("kind") == "timeout":
            self.timeouts.append(ev)
        if self.on_event is not None:
            self.on_event(ev)

    def call(self, fn: Callable, *args, op: str = "io",
             shard: Optional[int] = None,
             retryable: Sequence[type] = IO_RETRYABLE, **kwargs):
        """Run ``fn(*args, **kwargs)`` under the policy.

        Raises :class:`RecoveryExhausted` when per-op attempts or the
        shared budget run out; re-raises non-retryable errors as-is.
        """
        last: Optional[BaseException] = None
        for attempt in range(self.policy.max_attempts):
            t0 = self.clock()
            try:
                out = fn(*args, **kwargs)
            except tuple(retryable) as e:
                last = e
            else:
                elapsed = self.clock() - t0
                if self.policy.timeout is not None \
                        and elapsed > self.policy.timeout:
                    # The attempt *finished* but blew its deadline: the
                    # result is good, but the slowness itself is signal —
                    # report it (speculation feed) and return the value.
                    self._emit({"kind": "timeout", "op": op,
                                "shard": shard, "attempt": attempt,
                                "elapsed_s": elapsed,
                                "timeout_s": self.policy.timeout})
                return out
            # retry path
            if attempt + 1 >= self.policy.max_attempts:
                break
            if self.budget is not None:
                self.budget.draw_attempt(op, last_error=last)
            delay = self.policy.backoff(op, attempt)
            self._emit({"kind": "retry", "op": op, "shard": shard,
                        "attempt": attempt, "delay_s": delay,
                        "error": type(last).__name__})
            self.sleep(delay)
        raise RecoveryExhausted("attempts", op, self.policy.max_attempts,
                                last_error=last, events=self.events[-3:])

    def drain_timeouts(self) -> list[dict]:
        """Return and clear timeout events (the speculation feed)."""
        out = list(self.timeouts)
        self.timeouts.clear()
        return out
