"""Seeded chaos-schedule generation for the resilient fixpoint driver.

Pregelix's robustness argument (PAPERS.md) is that recovery behavior
must be validated under realistic *compounding* failures, not
extrapolated from single-fault runs.  This module is the generator side
of that argument: :func:`generate_schedule` draws a randomized — but
fully seed-deterministic — :class:`FaultSchedule` mixing repeated shard
failures, correlated replica loss, failures injected while an earlier
recovery is still in flight, elastic rescales with mid-rescale
failures, and transient stragglers.  The property the chaos tests hold
over every generated schedule:

    recoverable  ⇒ final state bit-identical to the failure-free run
    unrecoverable⇒ the view layer degrades (staleness-tagged answer),
                   and never serves corrupt data

Determinism matters more than realism here: the same ``(seed, config)``
always yields the same schedule, so a failing chaos run reproduces
exactly from its seed — the CI chaos-smoke job pins a seed matrix.

Run one seeded schedule end-to-end (the CI smoke entry point)::

    python -m repro.runtime.chaos --seed 7 --events 4 --quick

``--real`` executes the SAME seeded schedule against live worker
processes (``launch/distributed.py``): a ``fail`` event SIGKILLs the
worker leasing that shard (correlated: its first ring replica's worker
too), a ``straggle`` SIGSTOPs it past the straggle threshold, a
``rescale`` permanently retires a worker — and the run must STILL
bit-match the failure-free single-process reference.  Chaos parity:
the simulated and real drivers converge to the same global key state.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional

from repro.runtime.recovery import FaultEvent, FaultSchedule


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Knobs for one randomized schedule draw.

    ``n_events`` counts *primary* events; compound follow-ons (a
    correlated replica loss rides its fail event, a during-recovery
    failure rides the recovery its predecessor started) do not consume
    a slot, so the realized schedule may carry more FaultEvents than
    ``n_events``.
    """

    seed: int = 0
    num_shards: int = 4           # shard count the run starts with
    max_stratum: int = 8          # events land on strata [1, max_stratum)
    n_events: int = 3
    p_correlated: float = 0.25    # fail also wipes the first ring replica
    p_during_recovery: float = 0.25   # fail strikes mid-recovery
    p_rescale: float = 0.15
    p_straggle: float = 0.15
    p_fail_during_rescale: float = 0.5  # given a rescale, add a mid-
    #                                     migration failure under the
    #                                     new snapshot
    min_shards: int = 2
    max_shards: int = 8
    strategy: str = "incremental"     # "incremental" | "restart"

    def __post_init__(self):
        if self.n_events < 1:
            raise ValueError(
                f"ChaosConfig.n_events must be >= 1, got {self.n_events!r}")
        if self.max_stratum < 2:
            raise ValueError(
                f"ChaosConfig.max_stratum must be >= 2, got "
                f"{self.max_stratum!r}")
        if self.min_shards < 1 or self.max_shards < self.min_shards:
            raise ValueError(
                f"ChaosConfig needs 1 <= min_shards <= max_shards, got "
                f"min_shards={self.min_shards!r}, "
                f"max_shards={self.max_shards!r}")


def generate_schedule(cfg: ChaosConfig) -> FaultSchedule:
    """Draw one deterministic multi-event schedule from ``cfg``.

    The draw tracks the shard count through rescales so every event's
    ``shard`` is valid under the snapshot it will fire under, and emits
    during-recovery / during-rescale follow-ons anchored to the event
    that makes them fireable (same stratum, later in list order — the
    FaultSchedule contract).
    """
    rng = random.Random(cfg.seed)
    ats = sorted(rng.randrange(1, cfg.max_stratum)
                 for _ in range(cfg.n_events))
    events: list[FaultEvent] = []
    shards = cfg.num_shards
    for at in ats:
        r = rng.random()
        if r < cfg.p_rescale:
            choices = [k for k in range(cfg.min_shards, cfg.max_shards + 1)
                       if k != shards]
            if choices:
                shards = rng.choice(choices)
                events.append(FaultEvent(kind="rescale", at=at,
                                         new_num_shards=shards))
                if rng.random() < cfg.p_fail_during_rescale:
                    # Mid-migration failure: fires inside _do_rescale,
                    # under the NEW snapshot, against the barely-landed
                    # migrated chain.
                    events.append(FaultEvent(
                        kind="fail", at=at, shard=rng.randrange(shards),
                        during="rescale"))
                continue
        if r < cfg.p_rescale + cfg.p_straggle:
            events.append(FaultEvent(
                kind="straggle", at=at, shard=rng.randrange(shards),
                slowdown=round(2.0 + 3.0 * rng.random(), 3)))
            continue
        shard = rng.randrange(shards)
        correlated = rng.random() < cfg.p_correlated
        events.append(FaultEvent(kind="fail", at=at, shard=shard,
                                 correlated=correlated))
        if cfg.strategy == "incremental" \
                and rng.random() < cfg.p_during_recovery:
            # Strikes while the recovery the previous event started is
            # in flight — recovery must be re-entrant.
            events.append(FaultEvent(
                kind="fail", at=at, shard=rng.randrange(shards),
                during="recovery"))
    return FaultSchedule(events=tuple(events), strategy=cfg.strategy)


def acceptance_schedule(num_shards: int = 4,
                        strategy: str = "incremental") -> FaultSchedule:
    """The ISSUE's acceptance scenario, pinned: >= 3 faults including
    one correlated replica loss and one failure-during-recovery."""
    return FaultSchedule(events=(
        FaultEvent(kind="fail", at=1, shard=1 % num_shards),
        FaultEvent(kind="fail", at=2, shard=2 % num_shards,
                   correlated=True),
        FaultEvent(kind="fail", at=2, shard=3 % num_shards,
                   during="recovery"),
    ), strategy=strategy)


# ---------------------------------------------------------------------------
# Real-mode executor: the seeded schedule delivered as actual signals.
# ---------------------------------------------------------------------------

class RealChaosInjector:
    """Executes a :class:`FaultSchedule` against live worker processes.

    Installed as the distributed driver's ``chaos_hook``; at every
    punctuation barrier it fires all events whose stratum is due:

      * ``fail``     → SIGKILL the worker leasing the shard (correlated:
        also the worker leasing the shard's first ring replica) — the
        driver must DETECT the loss via the lease table, not be told;
      * ``straggle`` → SIGSTOP the owner past the straggle threshold
        (auto-SIGCONT before its lease expires): late heartbeats, a
        missed ack, a straggle signal — never a death;
      * ``rescale``  → permanently retire one surviving worker with the
        event's target shard count; the driver's elastic rescale
        absorbs it.

    ``during='recovery'/'rescale'`` windows are a simulation-only
    concept (real failures cannot be injected INSIDE the coordinator's
    handler from the outside); those events fire as ordinary barrier
    kills at their stratum — same-barrier multiples still exercise the
    multi-entry recovery queue.  Every event fires at most once;
    ``fired``/``skipped`` keep the accounting for the summary.
    """

    def __init__(self, schedule: FaultSchedule, cluster):
        self.pending = list(schedule.events)
        self.cluster = cluster
        self.fired: list = []
        self.skipped: list = []

    def _owner(self, shard: int):
        try:
            return self.cluster.worker_of(shard)
        except KeyError:
            return None

    def _alive_workers(self) -> list:
        return [w for w, p in self.cluster.procs.items()
                if p.alive() and w not in self.cluster.retired]

    def __call__(self, driver) -> None:
        while self.pending and self.pending[0].at <= driver.stratum:
            ev = self.pending.pop(0)
            record = {"kind": ev.kind, "at": ev.at, "shard": ev.shard,
                      "stratum": driver.stratum}
            if ev.kind == "fail":
                targets = {self._owner(ev.shard)}
                if ev.correlated:
                    reps = driver.snapshot.replicas_of(ev.shard)
                    if reps:
                        targets.add(self._owner(reps[0]))
                targets.discard(None)
                if not targets:
                    self.skipped.append(record)
                    continue
                for w in sorted(targets):
                    self.cluster.kill(w)
                record["workers"] = sorted(targets)
            elif ev.kind == "straggle":
                w = self._owner(ev.shard)
                if w is None:
                    self.skipped.append(record)
                    continue
                cfg = self.cluster.config
                pause_s = cfg.straggle_after + 0.3 * (
                    cfg.lease_ttl - cfg.straggle_after)
                self.cluster.pause(w, pause_s)
                record["workers"] = [w]
                record["pause_s"] = round(pause_s, 3)
            else:                       # rescale → retire one worker
                alive = self._alive_workers()
                if len(alive) < 2:      # never retire the last worker
                    self.skipped.append(record)
                    continue
                w = alive[-1]
                self.cluster.retire(w, new_num_shards=ev.new_num_shards)
                record["workers"] = [w]
                record["to_shards"] = ev.new_num_shards
            self.fired.append(record)


# ---------------------------------------------------------------------------
# CLI: one seeded schedule end-to-end vs the failure-free run — the CI
# chaos-smoke entry point.  Engine imports are local to main():
# repro.runtime.__init__ imports this module, a top-level engine import
# would cycle.
# ---------------------------------------------------------------------------

def main(argv: Optional[list] = None) -> int:
    import argparse
    import json
    import shutil
    import tempfile
    import time

    parser = argparse.ArgumentParser(
        description="Run one seeded chaos schedule against the real "
                    "engine and bit-compare with the failure-free run.")
    parser.add_argument("--seed", type=int, default=0)
    parser.add_argument("--events", type=int, default=3)
    parser.add_argument("--shards", type=int, default=4)
    parser.add_argument("--max-stratum", type=int, default=6)
    parser.add_argument("--strategy", default="incremental",
                        choices=("incremental", "restart"))
    parser.add_argument("--acceptance", action="store_true",
                        help="run the pinned acceptance schedule instead "
                             "of a seeded draw")
    parser.add_argument("--quick", action="store_true")
    parser.add_argument("--nodes", type=int, default=None,
                        help="override the dataset node count (tiny "
                             "graphs for smoke tests)")
    parser.add_argument("--real", action="store_true",
                        help="execute the schedule as REAL signals "
                             "(SIGKILL/SIGSTOP/retire) against live "
                             "worker processes")
    parser.add_argument("--workers", type=int, default=None,
                        help="worker process count in --real mode "
                             "(default: one per shard)")
    parser.add_argument("--worker-jax", default="off",
                        choices=("off", "local"),
                        help="per-worker jax runtime in --real mode")
    parser.add_argument("--detect", default="lease",
                        choices=("lease", "poll"),
                        help="death detection in --real mode: missed "
                             "lease deadline only, or also Popen.poll")
    parser.add_argument("--lease-ttl", type=float, default=1.2)
    parser.add_argument("--hb-interval", type=float, default=0.05)
    parser.add_argument("--ack-timeout", type=float, default=0.8)
    parser.add_argument("--trace-out", default=None,
                        help="directory for Chrome trace + metrics JSON "
                             "(per-worker timeline rows)")
    args = parser.parse_args(argv)

    from repro.algorithms import sssp
    from repro.core.engine import ShardedExecutor
    from repro.core.partition import PartitionSnapshot
    from repro.data.graphs import DATASETS, make_powerlaw_graph, shard_csr

    S = args.shards
    if args.acceptance:
        schedule = acceptance_schedule(num_shards=S,
                                       strategy=args.strategy)
    else:
        schedule = generate_schedule(ChaosConfig(
            seed=args.seed, num_shards=S, n_events=args.events,
            max_stratum=args.max_stratum, strategy=args.strategy,
            min_shards=2, max_shards=max(S, 4)))

    dataset = "dbpedia-small" if args.quick else "dbpedia"
    n, avg, alpha = DATASETS[dataset]
    if args.nodes is not None:
        n = args.nodes
    indptr, indices = make_powerlaw_graph(n, avg, alpha, 0)
    snap = PartitionSnapshot(n_keys=n, num_shards=S)
    cap = max(65536, 4 * n)

    def remake(new_snap):
        a = sssp.make_algorithm(new_snap,
                                src_capacity=new_snap.block_size,
                                edge_capacity=cap)
        e = ShardedExecutor(snapshot=new_snap, seg_capacity=cap,
                            edge_capacity=cap,
                            src_capacity=new_snap.block_size,
                            ladder_tiers=4, route_strategy="auto")
        # The immutable graph is re-sharded for the new snapshot — a
        # rescale changes every leading shard axis, not just the state.
        return e, a, shard_csr(indptr, indices, new_snap.num_shards)

    g = shard_csr(indptr, indices, S)

    ex, algo, _ = remake(snap)
    state0 = sssp.initial_state(snap, 0)
    ref = ex.run(algo, state0, 1, g, 80)

    tmp = tempfile.mkdtemp(prefix="chaos_")
    try:
        import jax.numpy as jnp
        import numpy as np

        from repro.core.partition import unshard_dense_state

        tracer = metrics_reg = None
        if args.trace_out:
            from repro.obs.metrics import MetricsRegistry
            from repro.obs.trace import Tracer
            tracer, metrics_reg = Tracer(), MetricsRegistry()
        injector = cluster = None
        t0 = time.perf_counter()
        if args.real:
            from repro.launch.distributed import (Cluster,
                                                  DistributedResilientDriver)
            from repro.runtime.health import HealthConfig
            cfg = HealthConfig(lease_ttl=args.lease_ttl,
                               straggle_after=min(0.35, args.lease_ttl / 3),
                               heartbeat_interval=args.hb_interval,
                               ack_timeout=args.ack_timeout)
            cluster = Cluster(f"{tmp}/cluster", args.workers or S,
                              num_shards=S, config=cfg,
                              jax_mode=args.worker_jax,
                              detect=args.detect, tracer=tracer,
                              metrics=metrics_reg)
            cluster.start()
            injector = RealChaosInjector(schedule, cluster)
            driver = DistributedResilientDriver(
                ex, algo, state0, 1, g, 80, ckpt_root=f"{tmp}/chaos",
                cluster=cluster, strategy=schedule.strategy,
                remake=remake, chaos_hook=injector, tracer=tracer,
                metrics=metrics_reg)
            res = driver.run()
            cluster.shutdown()
        else:
            res = ex.run_resilient(algo, state0, 1, g, 80,
                                   ckpt_root=f"{tmp}/chaos",
                                   fault_plan=schedule, remake=remake,
                                   tracer=tracer, metrics=metrics_reg)
        wall = time.perf_counter() - t0
        # Compare in GLOBAL key space: a rescale changes leaf shapes but
        # never values — unshard both sides and demand bit equality.
        ref_flat = np.asarray(unshard_dense_state(
            snap, jnp.stack(ref.state, -1)))
        got_flat = np.asarray(unshard_dense_state(
            snap.resnapshot(res.metrics["final_num_shards"]),
            jnp.stack(res.result.state, -1)))
        identical = bool(np.array_equal(ref_flat, got_flat))
        summary = {
            "seed": args.seed,
            "mode": "real" if args.real else "simulated",
            "strategy": schedule.strategy,
            "events": [dataclasses.asdict(e) for e in schedule.events],
            "faults": schedule.fail_count,
            "recoveries": res.metrics["recoveries"],
            "restarts": res.metrics["restarts"],
            "strata_executed": res.metrics["strata_executed"],
            "total_work_units": res.metrics["total_work_units"],
            "wall_s": round(wall, 3),
            "identical": bool(identical),
        }
        if args.real:
            summary["workers"] = res.metrics["workers"]
            summary["detect"] = args.detect
            summary["signals_fired"] = injector.fired
            summary["signals_skipped"] = injector.skipped
            summary["detections"] = res.metrics["worker_detections"]
            summary["ack_timeouts"] = res.metrics["ack_timeouts"]
        if args.trace_out:
            import os

            from repro.obs.export import write_chrome_trace, write_metrics
            os.makedirs(args.trace_out, exist_ok=True)
            mode = "real" if args.real else "sim"
            write_chrome_trace(
                tracer, os.path.join(args.trace_out,
                                     f"chaos_{mode}_{args.seed}.trace.json"))
            write_metrics(
                metrics_reg, os.path.join(
                    args.trace_out, f"chaos_{mode}_{args.seed}.metrics.json"))
        print(json.dumps(summary, indent=2))
        return 0 if identical else 1
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    raise SystemExit(main())
