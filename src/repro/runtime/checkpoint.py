"""Checkpointing: full snapshots + REX incremental delta checkpoints.

Paper §4.3: MapReduce checkpoints *everything* (expensive); pipelined DBs
checkpoint *nothing* (no forward-progress guarantee).  REX's hybrid keeps
periodic full checkpoints and, per stratum, replicates only the **mutable
Δᵢ set** — so recovery restarts from the last completed stratum instead of
from scratch, and the per-stratum overhead shrinks as the computation
converges (|Δᵢ| ↓).

This module implements both sides generically over PyTrees:

  * ``save_full`` / ``load_full``        — atomic full snapshots with a
    replication chain (shard s's files are copied to replicas
    (s+1..s+R−1) mod S — the paper's DHT replication, factor 3).
  * ``save_delta`` / ``replay_deltas``   — per-stratum Δ checkpoints:
    (stratum, DeltaBuffer) pairs for analytics; (step, sparse param diff)
    for training (only components that changed ≥ τ — the training-side
    analogue, reusing the delta-compression machinery).

Checkpoints are plain ``.npz`` files under a directory tree; on a real
cluster each worker writes its shard to local disk and the replication
chain copies cross-host (simulated here with directories per "node").
Writes are atomic (tmp + rename) so a crash mid-write never corrupts the
restore point.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _tree_like(tree, arrays: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        leaves.append(jnp.asarray(arrays[key]))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _atomic_savez(path: str, **arrays):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    # suffix must end in .npz or np.savez appends it and the rename
    # would move an empty file.
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".npz")
    os.close(fd)
    try:
        np.savez(tmp, **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


class CheckpointManager:
    """Directory layout:  <root>/node<k>/{full_<step>.npz, delta_<step>.npz,
    MANIFEST.json}.  ``replication`` copies every write to the next R−1
    node directories (the paper's replica chain)."""

    def __init__(self, root: str, num_nodes: int = 1, replication: int = 3,
                 keep: int = 2):
        self.root = root
        self.num_nodes = num_nodes
        self.replication = min(replication, num_nodes)
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    def _node_dir(self, node: int) -> str:
        return os.path.join(self.root, f"node{node}")

    def _replicas(self, node: int):
        return [(node + r) % self.num_nodes
                for r in range(self.replication)]

    # ---- full checkpoints ------------------------------------------------
    def save_full(self, node: int, step: int, tree) -> None:
        arrays = _flatten_with_paths(tree)
        for tgt in self._replicas(node):
            path = os.path.join(self._node_dir(tgt),
                                f"full_{step:08d}_of{node}.npz")
            _atomic_savez(path, **arrays)
        self._write_manifest(node, step, kind="full")
        self._gc(node)

    def load_full(self, node: int, like, step: Optional[int] = None,
                  from_replica: bool = False, exclude_self: bool = False):
        """Restore node's latest (or ``step``) full snapshot; with
        ``from_replica`` read it from the replica chain (the node's own
        disk is presumed lost — paper recovery path).  ``exclude_self``
        additionally skips the node's own directory even if it survives —
        straggler speculation reads ONLY replicas, proving the re-issued
        work never needs the slow node's disk."""
        sources = self._replicas(node) if from_replica else [node]
        if exclude_self:
            sources = [s for s in sources if s != node]
        for src in sources:
            d = self._node_dir(src)
            if not os.path.isdir(d):
                continue
            cands = sorted(f for f in os.listdir(d)
                           if f.startswith("full_")
                           and f.endswith(f"_of{node}.npz"))
            if step is not None:
                cands = [f for f in cands if f"full_{step:08d}" in f]
            if cands:
                data = np.load(os.path.join(d, cands[-1]))
                got_step = int(cands[-1].split("_")[1])
                return _tree_like(like, dict(data)), got_step
        raise FileNotFoundError(
            f"no full checkpoint for node {node} (replicas searched: "
            f"{sources})")

    # ---- incremental delta checkpoints ------------------------------------
    def save_delta(self, node: int, step: int, keys, payload,
                   meta: Optional[dict] = None) -> int:
        """Replicate one stratum's Δ set (indices + payloads only — the
        paper's incremental checkpoint).  Returns bytes written per
        replica."""
        keys = np.asarray(keys)
        payload = np.asarray(payload)
        for tgt in self._replicas(node):
            path = os.path.join(self._node_dir(tgt),
                                f"delta_{step:08d}_of{node}.npz")
            _atomic_savez(path, keys=keys, payload=payload,
                          meta=np.frombuffer(
                              json.dumps(meta or {}).encode(), np.uint8))
        self._write_manifest(node, step, kind="delta")
        return int(keys.nbytes + payload.nbytes)

    def replay_deltas(self, node: int, since_step: int,
                      from_replica: bool = False, with_meta: bool = False,
                      exclude_self: bool = False,
                      merge_sources: bool = False):
        """Yield (step, keys, payload) for every delta checkpoint after
        ``since_step``, in order — recovery replays these onto the
        restored full snapshot to reach the last completed stratum.
        With ``with_meta`` each item gains the decoded meta dict;
        ``exclude_self`` reads only true replicas (see ``load_full``).

        By default the FIRST source directory holding any matching entry
        wins (single-writer history).  ``merge_sources`` instead unions
        entries across all sources by step — required once a node's disk
        has been wiped and re-created mid-history: its own directory then
        holds only post-recovery entries while the older strata live on
        the replicas, and neither side alone is complete.  (Replicated
        writes are byte-identical per step, so the union is unambiguous.)
        """
        sources = self._replicas(node) if from_replica else [node]
        if exclude_self:
            sources = [s for s in sources if s != node]
        found: dict[int, str] = {}
        for src in sources:
            d = self._node_dir(src)
            if not os.path.isdir(d):
                continue
            cands = sorted(f for f in os.listdir(d)
                           if f.startswith("delta_")
                           and f.endswith(f"_of{node}.npz"))
            steps = [(int(f.split("_")[1]), f) for f in cands]
            steps = [(s, f) for s, f in steps if s > since_step]
            for s, f in steps:
                found.setdefault(s, os.path.join(d, f))
            if found and not merge_sources:
                break
        for s in sorted(found):
            data = np.load(found[s])
            if with_meta:
                meta = json.loads(bytes(data["meta"]).decode())
                yield s, data["keys"], data["payload"], meta
            else:
                yield s, data["keys"], data["payload"]

    # ---- bookkeeping -----------------------------------------------------
    def _write_manifest(self, node: int, step: int, kind: str):
        path = os.path.join(self._node_dir(node), "MANIFEST.json")
        manifest = {"latest_step": step, "kind": kind}
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(manifest, f)

    def _gc(self, node: int):
        """Keep the last ``keep`` full checkpoints (+ their deltas)."""
        for tgt in self._replicas(node):
            d = self._node_dir(tgt)
            if not os.path.isdir(d):
                continue
            fulls = sorted(f for f in os.listdir(d)
                           if f.startswith("full_")
                           and f.endswith(f"_of{node}.npz"))
            for f in fulls[:-self.keep]:
                os.unlink(os.path.join(d, f))
            if fulls:
                oldest_kept = int(fulls[-self.keep:][0].split("_")[1])
                for f in os.listdir(d):
                    if (f.startswith("delta_")
                            and f.endswith(f"_of{node}.npz")
                            and int(f.split("_")[1]) < oldest_kept):
                        os.unlink(os.path.join(d, f))

    def wipe_node(self, node: int):
        """Simulate total disk loss of one node (failure injection)."""
        d = self._node_dir(node)
        if os.path.isdir(d):
            shutil.rmtree(d)
