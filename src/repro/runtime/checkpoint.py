"""Checkpointing: full snapshots + REX incremental delta checkpoints.

Paper §4.3: MapReduce checkpoints *everything* (expensive); pipelined DBs
checkpoint *nothing* (no forward-progress guarantee).  REX's hybrid keeps
periodic full checkpoints and, per stratum, replicates only the **mutable
Δᵢ set** — so recovery restarts from the last completed stratum instead of
from scratch, and the per-stratum overhead shrinks as the computation
converges (|Δᵢ| ↓).

This module implements both sides generically over PyTrees:

  * ``save_full`` / ``load_full``        — atomic full snapshots with a
    replication chain (shard s's files are copied to replicas
    (s+1..s+R−1) mod S — the paper's DHT replication, factor 3).
  * ``save_delta`` / ``replay_deltas``   — per-stratum Δ checkpoints:
    (stratum, DeltaBuffer) pairs for analytics; (step, sparse param diff)
    for training (only components that changed ≥ τ — the training-side
    analogue, reusing the delta-compression machinery).

Checkpoints are plain ``.npz`` files under a directory tree; on a real
cluster each worker writes its shard to local disk and the replication
chain copies cross-host (simulated here with directories per "node").

Integrity contract (chaos-hardened):

  * Writes are atomic and durable: tmp file + fsync + ``os.replace`` +
    directory fsync, so a crash mid-write leaves the previous restore
    point intact and never a torn file at the final path.
  * Every checkpoint embeds a sha256 over its array contents
    (``__sum__``); reads verify it.  A torn or bit-corrupted file raises
    :class:`CheckpointCorruption`, is moved to a ``quarantine/``
    subdirectory (never silently deleted — it is forensic evidence),
    and the reader falls back to the next replica holding the same step.
  * Reads can be wrapped in a ``runtime.retry.Retrier`` (transient-error
    retry with seeded backoff); corruption is NOT retried — the same
    bytes would fail again — it falls through to the replica chain.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp


class CheckpointCorruption(RuntimeError):
    """A checkpoint file failed integrity verification (torn write,
    truncated archive, or bit corruption)."""

    def __init__(self, path: str, reason: str):
        super().__init__(f"corrupt checkpoint {path}: {reason}")
        self.path = path
        self.reason = reason


def _digest(arrays: dict) -> np.ndarray:
    """sha256 over array contents + dtypes + shapes, name-sorted —
    stored inside the npz so the checkpoint is self-verifying."""
    h = hashlib.sha256()
    for key in sorted(arrays):
        arr = np.ascontiguousarray(arrays[key])
        h.update(key.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return np.frombuffer(h.digest(), np.uint8)


def _read_npz(path: str) -> dict:
    """Load + verify one checkpoint; raises CheckpointCorruption on a
    torn/truncated/bit-flipped file.  Files written before checksums
    existed (no ``__sum__``) load unverified."""
    try:
        with np.load(path) as data:
            arrays = {k: np.array(data[k]) for k in data.files}
    except OSError:
        raise          # missing file / transient FS error — retryable,
        #                not corruption (the caller's retrier handles it)
    except Exception as e:       # torn zip, truncated array, bad pickle
        raise CheckpointCorruption(path, f"unreadable: {e!r}") from e
    expected = arrays.pop("__sum__", None)
    if expected is not None \
            and not np.array_equal(_digest(arrays), expected):
        raise CheckpointCorruption(path, "checksum mismatch")
    return arrays


def _quarantine(path: str) -> str:
    """Move a corrupt file aside (same filesystem, atomic) so retries
    and replicas never re-read it; returns the quarantine path."""
    qdir = os.path.join(os.path.dirname(path), "quarantine")
    os.makedirs(qdir, exist_ok=True)
    dst = os.path.join(qdir, os.path.basename(path))
    try:
        os.replace(path, dst)
    except OSError:
        pass                      # already gone (concurrent wipe) — fine
    return dst


def _fsync_dir(dirname: str) -> None:
    fd = os.open(dirname, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_json(path: str, payload: dict) -> None:
    """Durable atomic JSON write (tmp + fsync + replace + dir fsync) —
    manifests must never be readable half-written."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".json")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(os.path.dirname(path))
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _tree_like(tree, arrays: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        leaves.append(jnp.asarray(arrays[key]))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _atomic_savez(path: str, **arrays):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    # suffix must end in .npz or np.savez appends it and the rename
    # would move an empty file.
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".npz")
    os.close(fd)
    try:
        arrays = {k: np.asarray(v) for k, v in arrays.items()}
        np.savez(tmp, __sum__=_digest(arrays), **arrays)
        # fsync file THEN replace THEN fsync dir: after a crash the final
        # path holds either the old complete file or the new complete
        # file — never torn bytes.
        with open(tmp, "rb") as f:
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(os.path.dirname(path))
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


class CheckpointManager:
    """Directory layout:  <root>/node<k>/{full_<step>.npz, delta_<step>.npz,
    MANIFEST.json}.  ``replication`` copies every write to the next R−1
    node directories (the paper's replica chain)."""

    def __init__(self, root: str, num_nodes: int = 1, replication: int = 3,
                 keep: int = 2, retrier=None):
        self.root = root
        self.num_nodes = num_nodes
        self.replication = min(replication, num_nodes)
        self.keep = keep
        # Optional runtime.retry.Retrier: transient read errors are
        # retried with seeded backoff; CheckpointCorruption is never
        # retried (deterministic) — it quarantines and falls through to
        # the next replica instead.
        self.retrier = retrier
        self.quarantined: list[str] = []
        os.makedirs(root, exist_ok=True)

    def _load(self, path: str) -> dict:
        """Verified read of one checkpoint file, through the retrier
        when one is attached (transient-error retry only)."""
        if self.retrier is None:
            return _read_npz(path)
        return self.retrier.call(
            _read_npz, path, op=f"ckpt_read:{os.path.basename(path)}",
            retryable=(OSError,))

    def _load_fallback(self, paths: list[str], what: str) -> dict:
        """Read the first verifiable copy among replicas of ONE logical
        checkpoint; corrupt copies are quarantined and skipped.  Raises
        CheckpointCorruption only when every copy is bad — a torn write
        must never silently drop a stratum from the replay."""
        last: Optional[Exception] = None
        for path in paths:
            try:
                return self._load(path)
            except FileNotFoundError as e:
                last = e          # replica vanished (wipe race) — skip
            except CheckpointCorruption as e:
                self.quarantined.append(_quarantine(path))
                last = e
        raise CheckpointCorruption(
            what, f"all {len(paths)} replica cop(ies) corrupt; "
                  f"last: {last}")

    def _node_dir(self, node: int) -> str:
        return os.path.join(self.root, f"node{node}")

    def _replicas(self, node: int):
        return [(node + r) % self.num_nodes
                for r in range(self.replication)]

    # ---- full checkpoints ------------------------------------------------
    def save_full(self, node: int, step: int, tree) -> None:
        arrays = _flatten_with_paths(tree)
        for tgt in self._replicas(node):
            path = os.path.join(self._node_dir(tgt),
                                f"full_{step:08d}_of{node}.npz")
            _atomic_savez(path, **arrays)
        self._write_manifest(node, step, kind="full")
        self._gc(node)

    def load_full(self, node: int, like, step: Optional[int] = None,
                  from_replica: bool = False, exclude_self: bool = False):
        """Restore node's latest (or ``step``) full snapshot; with
        ``from_replica`` read it from the replica chain (the node's own
        disk is presumed lost — paper recovery path).  ``exclude_self``
        additionally skips the node's own directory even if it survives —
        straggler speculation reads ONLY replicas, proving the re-issued
        work never needs the slow node's disk."""
        sources = self._replicas(node) if from_replica else [node]
        if exclude_self:
            sources = [s for s in sources if s != node]
        # Collect every copy of every candidate step across sources, so
        # a corrupt copy on one replica falls back to the same step on
        # another, and an entirely-corrupt step falls back to the next
        # OLDER step still on disk.
        by_step: dict[int, list[str]] = {}
        for src in sources:
            d = self._node_dir(src)
            if not os.path.isdir(d):
                continue
            for f in os.listdir(d):
                if not (f.startswith("full_")
                        and f.endswith(f"_of{node}.npz")):
                    continue
                s = int(f.split("_")[1])
                if step is not None and s != step:
                    continue
                by_step.setdefault(s, []).append(os.path.join(d, f))
        last: Optional[Exception] = None
        for s in sorted(by_step, reverse=True):
            try:
                arrays = self._load_fallback(
                    by_step[s], f"full step {s} of node {node}")
            except CheckpointCorruption as e:
                last = e                  # fall back to the older step
                continue
            arrays.pop("__sum__", None)
            return _tree_like(like, arrays), s
        if last is not None:
            raise CheckpointCorruption(
                f"node {node}", f"every full checkpoint corrupt "
                                f"(steps {sorted(by_step)}): {last}")
        raise FileNotFoundError(
            f"no full checkpoint for node {node} (replicas searched: "
            f"{sources})")

    # ---- incremental delta checkpoints ------------------------------------
    def save_delta(self, node: int, step: int, keys, payload,
                   meta: Optional[dict] = None) -> int:
        """Replicate one stratum's Δ set (indices + payloads only — the
        paper's incremental checkpoint).  Returns bytes written per
        replica."""
        keys = np.asarray(keys)
        payload = np.asarray(payload)
        for tgt in self._replicas(node):
            path = os.path.join(self._node_dir(tgt),
                                f"delta_{step:08d}_of{node}.npz")
            _atomic_savez(path, keys=keys, payload=payload,
                          meta=np.frombuffer(
                              json.dumps(meta or {}).encode(), np.uint8))
        self._write_manifest(node, step, kind="delta")
        return int(keys.nbytes + payload.nbytes)

    def replay_deltas(self, node: int, since_step: int,
                      from_replica: bool = False, with_meta: bool = False,
                      exclude_self: bool = False,
                      merge_sources: bool = False):
        """Yield (step, keys, payload) for every delta checkpoint after
        ``since_step``, in order — recovery replays these onto the
        restored full snapshot to reach the last completed stratum.
        With ``with_meta`` each item gains the decoded meta dict;
        ``exclude_self`` reads only true replicas (see ``load_full``).

        By default the FIRST source directory holding any matching entry
        wins (single-writer history).  ``merge_sources`` instead unions
        entries across all sources by step — required once a node's disk
        has been wiped and re-created mid-history: its own directory then
        holds only post-recovery entries while the older strata live on
        the replicas, and neither side alone is complete.  (Replicated
        writes are byte-identical per step, so the union is unambiguous.)
        """
        sources = self._replicas(node) if from_replica else [node]
        if exclude_self:
            sources = [s for s in sources if s != node]
        # Every source's copy of each step is kept as a fallback: a
        # torn/corrupt delta on one replica reads from the next replica
        # instead of silently dropping the stratum (which would corrupt
        # the restored shard).
        found: dict[int, list[str]] = {}
        primary_sources: Optional[set] = None
        for src in sources:
            d = self._node_dir(src)
            if not os.path.isdir(d):
                continue
            cands = sorted(f for f in os.listdir(d)
                           if f.startswith("delta_")
                           and f.endswith(f"_of{node}.npz"))
            steps = [(int(f.split("_")[1]), f) for f in cands]
            steps = [(s, f) for s, f in steps if s > since_step]
            if steps and not merge_sources and primary_sources is None:
                # single-writer history: the FIRST source holding any
                # matching entry wins, but later sources still provide
                # per-step fallback copies for corruption recovery.
                primary_sources = {s for s, _ in steps}
            for s, f in steps:
                if not merge_sources and primary_sources is not None \
                        and s not in primary_sources:
                    continue
                found.setdefault(s, []).append(os.path.join(d, f))
        for s in sorted(found):
            data = self._load_fallback(
                found[s], f"delta step {s} of node {node}")
            if with_meta:
                meta = json.loads(bytes(data["meta"]).decode())
                yield s, data["keys"], data["payload"], meta
            else:
                yield s, data["keys"], data["payload"]

    # ---- bookkeeping -----------------------------------------------------
    def _write_manifest(self, node: int, step: int, kind: str):
        path = os.path.join(self._node_dir(node), "MANIFEST.json")
        manifest = {"latest_step": step, "kind": kind}
        atomic_write_json(path, manifest)

    def _gc(self, node: int):
        """Keep the last ``keep`` full checkpoints (+ their deltas)."""
        for tgt in self._replicas(node):
            d = self._node_dir(tgt)
            if not os.path.isdir(d):
                continue
            fulls = sorted(f for f in os.listdir(d)
                           if f.startswith("full_")
                           and f.endswith(f"_of{node}.npz"))
            for f in fulls[:-self.keep]:
                os.unlink(os.path.join(d, f))
            if fulls:
                oldest_kept = int(fulls[-self.keep:][0].split("_")[1])
                for f in os.listdir(d):
                    if (f.startswith("delta_")
                            and f.endswith(f"_of{node}.npz")
                            and int(f.split("_")[1]) < oldest_kept):
                        os.unlink(os.path.join(d, f))

    def wipe_node(self, node: int):
        """Simulate total disk loss of one node (failure injection)."""
        d = self._node_dir(node)
        if os.path.isdir(d):
            shutil.rmtree(d)
