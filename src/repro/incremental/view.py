"""Materialized views and the ViewManager session layer.

A :class:`MaterializedView` keeps the converged ``FixpointResult`` state of
one standing query resident, absorbs sealed mutation batches through its
algorithm's repair rule, and re-enters the sharded fixpoint *warm*.  The
repair-vs-recompute decision is the paper's delta/dense duality lifted to
the update-to-update level: when the rule's estimated repair volume
(touched keys) exceeds ``fallback_threshold × key_count``, the view cold
recomputes instead — same answer, different cost model.

:class:`ViewManager` owns N concurrent views, routes mutation batches,
exposes ``refresh()``/``query()`` with result caching keyed by view
version, and (optionally) journals every batch durably through
``runtime/checkpoint.py`` so a restarted process resumes views from the
last base snapshot plus the replayed mutation journal.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.incremental.mutations import Mutation, MutationBatch, MutationLog
from repro.incremental.rules import get_rule
from repro.incremental.stores import GraphStore, PointStore
from repro.runtime.retry import RecoveryExhausted


@dataclasses.dataclass(frozen=True)
class RefreshReport:
    """What one refresh did: which path ran and what it cost."""

    view: str
    version: int
    mode: str                 # "cold" | "repair" | "noop" | "degraded"
    mutations: int
    touched_keys: int
    strata: int
    rehash_bytes: float
    wall_s: float


@dataclasses.dataclass(frozen=True)
class QueryAnswer:
    """A query result with explicit staleness metadata.

    ``version`` is the converged state actually served; when the view is
    degraded (a refresh exhausted its recovery budget) that lags
    ``latest_version`` — the base data's version including every sealed
    batch the served state does not yet reflect.  ``stale_batches`` is
    the gap in batches; ``reason`` carries the exhaustion kind (e.g.
    ``"budget:recoveries"``).  A fresh answer has ``degraded=False``,
    ``stale_batches=0``, ``reason=None``.
    """

    value: np.ndarray
    view: str
    version: int
    latest_version: int
    degraded: bool = False
    stale_batches: int = 0
    reason: Optional[str] = None


class MaterializedView:
    """One standing query: store + converged state + repair rule."""

    def __init__(self, name: str, algorithm: str,
                 store: GraphStore | PointStore,
                 params: Optional[dict] = None,
                 fallback_threshold: float = 0.15,
                 _restored: Optional[tuple] = None,
                 tracer=None, metrics=None):
        self.name = name
        self.algorithm = algorithm
        self.store = store
        self.params = dict(params or {})
        self.fallback_threshold = float(fallback_threshold)
        self.rule = get_rule(algorithm)
        self.log = MutationLog()
        self.history: list[RefreshReport] = []
        self.last_batch: Optional[MutationBatch] = None
        self._cache: Optional[tuple[int, np.ndarray]] = None
        # Observability (optional): refresh spans land on the tracer's
        # "views" row, repair/cold latency and mutation counts in the
        # registry.  Both default to None — no overhead.
        self.tracer = tracer
        self.metrics = metrics
        # Executor-fault injection for the next refresh (consumed by the
        # rule's resilient resume when params carry a "resilient_root").
        # ``fault_plan`` accepts a FaultPlan or a FaultSchedule;
        # ``retry_policy``/``retry_budget`` bound the recovery work one
        # refresh may spend before the view DEGRADES: it keeps serving
        # the last converged state (staleness-tagged) instead of raising.
        self.fault_plan = None
        self.retry_policy = None
        self.retry_budget = None
        self.last_recovery: Optional[dict] = None
        # Degradation state: metadata of the refresh that exhausted its
        # budget, count of sealed batches the served state lags behind,
        # and the catch-up flag forcing the next refresh down the cold
        # path (a degraded refresh's repair plan is lost — only a cold
        # recompute from the mutated store is guaranteed correct).
        self.degraded: Optional[dict] = None
        self._stale_batches = 0
        self._needs_cold = False

        self.immutable = store.build_sharded()
        self.rule.bind(self)
        if _restored is None:
            t0 = time.perf_counter()
            self.version = 0
            self.state, res = self.rule.cold(self)
            self.last_result = res
            iters = int(res.stats.iterations)
            self._record(RefreshReport(
                view=name, version=0, mode="cold", mutations=0,
                touched_keys=self.key_count, strata=iters,
                rehash_bytes=float(np.sum(
                    np.asarray(res.stats.rehash_bytes)[:iters])),
                wall_s=time.perf_counter() - t0))
        else:
            self.state, self.version = _restored
            self.last_result = None

    @property
    def key_count(self) -> int:
        """Size of the view's key space (fallback-policy denominator)."""
        return self.store.n if isinstance(self.store, GraphStore) \
            else self.store.capacity

    def _record(self, report: RefreshReport) -> RefreshReport:
        """Append to history and mirror the report into the tracer
        timeline ("views" row) and the metrics registry."""
        self.history.append(report)
        if self.tracer is not None:
            self.tracer._append({
                "name": f"{report.view}.{report.mode}", "ph": "X",
                "ts": self.tracer._now() - report.wall_s,
                "dur": report.wall_s, "tid": "views",
                "args": {"view": report.view, "mode": report.mode,
                         "version": report.version,
                         "mutations": report.mutations,
                         "touched_keys": report.touched_keys,
                         "strata": report.strata,
                         "rehash_bytes": report.rehash_bytes}})
        if self.metrics is not None:
            m = self.metrics
            m.counter(f"view.{report.mode}s").inc()
            m.counter("view.mutations_applied").inc(report.mutations)
            if report.mode != "noop":
                m.histogram("view.refresh_seconds").observe(report.wall_s)
                m.histogram("view.touched_keys").observe(
                    max(report.touched_keys, 0))
            if report.mode == "repair":
                # The headline number: end-to-end repair-pipeline latency
                # (seal + store apply + plan + warm fixpoint).
                m.histogram("view.repair_seconds").observe(report.wall_s)
        return report

    # ------------------------------------------------------------------
    def apply(self, *mutations: Mutation) -> int:
        """Queue mutations for the next refresh; returns first seq id."""
        return self.log.append(*mutations)

    def refresh(self, force: Optional[str] = None,
                on_sealed: Optional[callable] = None) -> RefreshReport:
        """Seal pending mutations and bring the view up to date.

        ``force``: None (policy decides), "repair", or "cold".
        ``on_sealed(batch, mode)`` fires after the batch is sealed and the
        refresh path is DECIDED but before the fixpoint runs — the
        ViewManager journals the batch there, so a crash (or executor
        failure) mid-repair loses no durably-accepted mutations: restore
        replays the journaled batch through the same decided path.
        """
        if force not in (None, "repair", "cold"):
            raise ValueError(force)
        t0 = time.perf_counter()
        if self.log.pending_count == 0:
            if self._needs_cold:
                # Degraded with no new mutations: a refresh is the
                # operator's catch-up request — cold recompute from the
                # (already-mutated) store restores freshness.
                return self._catch_up(t0)
            return self._record(RefreshReport(
                view=self.name, version=self.version, mode="noop",
                mutations=0, touched_keys=0, strata=0, rehash_bytes=0.0,
                wall_s=time.perf_counter() - t0))

        # Degraded batches were sealed (and applied to the store) past
        # ``version`` without being served — number monotonically after
        # them so journal steps never collide.
        batch = self.log.seal(self.version + 1 + self._stale_batches)
        self.last_batch = batch
        try:
            effect = self.store.apply_batch(batch.mutations)
        except Exception:
            # Stores apply atomically, so nothing took effect: put the
            # batch back so the caller can drop the bad mutation and
            # retry without losing the good ones.
            self.log.unseal(batch)
            self.last_batch = None
            raise
        old_cap = getattr(self.store, "nnz_capacity", None)
        self.immutable = self.store.build_sharded()
        if old_cap is not None and self.store.nnz_capacity != old_cap:
            self.rule.rebind(self)      # capacity grew: one re-trace

        plan = None
        # A degraded view's lost repair plans make "cold" the only
        # correct catch-up: the store already holds every sealed batch.
        mode = "cold" if (force == "cold" or self._needs_cold) \
            else "repair"
        if mode == "repair":
            plan = self.rule.repair(self, effect, self.state)
            if (force != "repair"
                    and plan.touched_keys
                    > self.fallback_threshold * self.key_count):
                mode = "cold"
        if on_sealed is not None:
            on_sealed(batch, mode)
        try:
            if mode == "cold":
                self.state, res = self.rule.cold(self)
            elif plan.touched_keys == 0:
                # The batch left every derived value intact (e.g. a no-op
                # reweight): skip the fixpoint entirely, zero strata.
                from repro.core.fixpoint import FixpointResult, empty_stats
                self.state = plan.state
                res = FixpointResult(state=plan.state, stats=empty_stats(1))
            else:
                self.state, res = self.rule.resume(self, plan.state)
        except RecoveryExhausted as e:
            # Graceful degradation: the recovery budget ran out before
            # the refresh could converge.  ``self.state`` is untouched
            # (assignment happens only on success), so the view keeps
            # serving the LAST CONVERGED answer — now stale by this
            # batch — instead of raising to the caller.
            return self._degrade(batch, mode, e, t0)

        self.version = batch.version
        self._cache = None
        self.last_result = res
        self.last_plan = plan
        if self.degraded is not None:
            self._mark_recovered()
        iters = int(res.stats.iterations)
        return self._record(RefreshReport(
            view=self.name, version=self.version, mode=mode,
            mutations=len(batch),
            touched_keys=(plan.touched_keys if plan is not None
                          else self.key_count),
            strata=iters,
            rehash_bytes=float(np.sum(
                np.asarray(res.stats.rehash_bytes)[:iters])),
            wall_s=time.perf_counter() - t0))

    # ---- degradation -----------------------------------------------------
    def _degrade(self, batch: MutationBatch, mode: str,
                 err: RecoveryExhausted, t0: float) -> RefreshReport:
        self._stale_batches += 1
        self._needs_cold = True
        self.degraded = {
            "reason": err.kind, "detail": str(err),
            "served_version": self.version,
            "missed_version": batch.version,
            "stale_batches": self._stale_batches,
        }
        if self.tracer is not None:
            self.tracer.instant("view_degraded", tid="views",
                                view=self.name, reason=err.kind,
                                served_version=self.version,
                                stale_batches=self._stale_batches)
        if self.metrics is not None:
            self.metrics.counter("view.degradations").inc()
            self.metrics.gauge(f"view.staleness.{self.name}").set(
                self._stale_batches)
        return self._record(RefreshReport(
            view=self.name, version=self.version, mode="degraded",
            mutations=len(batch), touched_keys=0, strata=0,
            rehash_bytes=0.0, wall_s=time.perf_counter() - t0))

    def _mark_recovered(self) -> None:
        """A refresh converged after degradation: freshness restored."""
        self.degraded = None
        self._stale_batches = 0
        self._needs_cold = False
        if self.tracer is not None:
            self.tracer.instant("view_recovered", tid="views",
                                view=self.name, version=self.version)
        if self.metrics is not None:
            self.metrics.gauge(f"view.staleness.{self.name}").set(0)

    def _catch_up(self, t0: float) -> RefreshReport:
        """Cold recompute with no new batch: absorb the degraded-era
        batches already sitting in the store."""
        self.state, res = self.rule.cold(self)
        self.version += self._stale_batches
        self._cache = None
        self.last_result = res
        self._mark_recovered()
        iters = int(res.stats.iterations)
        return self._record(RefreshReport(
            view=self.name, version=self.version, mode="cold",
            mutations=0, touched_keys=self.key_count, strata=iters,
            rehash_bytes=float(np.sum(
                np.asarray(res.stats.rehash_bytes)[:iters])),
            wall_s=time.perf_counter() - t0))

    def query(self) -> np.ndarray:
        """Current result, cached per view version."""
        if self._cache is None or self._cache[0] != self.version:
            self._cache = (self.version,
                           self.rule.extract(self, self.state))
        return self._cache[1]

    def answer(self) -> QueryAnswer:
        """:meth:`query` plus explicit staleness metadata — the serving
        contract under degradation: never raise, never serve corrupt
        data, always say how stale the answer is."""
        return QueryAnswer(
            value=self.query(), view=self.name, version=self.version,
            latest_version=self.version + self._stale_batches,
            degraded=self.degraded is not None,
            stale_batches=self._stale_batches,
            reason=(self.degraded or {}).get("reason"))


class ViewManager:
    """Session layer over N concurrent materialized views."""

    def __init__(self, journal_root: Optional[str] = None,
                 fallback_threshold: float = 0.15,
                 tracer=None, metrics=None):
        self.views: dict[str, MaterializedView] = {}
        self.fallback_threshold = fallback_threshold
        # Shared observability sinks for every view created here; the
        # manager also tracks per-view journal depth (sealed batches
        # since the last base snapshot — the replay a restore would do).
        self.tracer = tracer
        self.metrics = metrics
        self.journal_depth: dict[str, int] = {}
        if journal_root is not None:
            from repro.incremental.journal import ViewJournal
            self.journal = ViewJournal(journal_root)
        else:
            self.journal = None

    def _set_depth(self, name: str, depth: int) -> None:
        self.journal_depth[name] = depth
        if self.metrics is not None:
            self.metrics.gauge(f"view.journal_depth.{name}").set(depth)

    # ---- creation --------------------------------------------------------
    def create_view(self, name: str, algorithm: str,
                    store: GraphStore | PointStore,
                    fallback_threshold: Optional[float] = None,
                    **params) -> MaterializedView:
        if name in self.views:
            raise KeyError(f"view {name!r} already exists")
        view = MaterializedView(
            name, algorithm, store, params=params,
            fallback_threshold=(self.fallback_threshold
                                if fallback_threshold is None
                                else fallback_threshold),
            tracer=self.tracer, metrics=self.metrics)
        self.views[name] = view
        self._set_depth(name, 0)
        if self.journal is not None:
            self.journal.register_view(view)
            self.journal.save_base(view)
        return view

    def create_graph_view(self, name: str, algorithm: str,
                          indptr: np.ndarray, indices: np.ndarray, n: int,
                          num_shards: int = 4, **kw) -> MaterializedView:
        store = GraphStore(indptr, indices, n, num_shards)
        return self.create_view(name, algorithm, store, **kw)

    def create_kmeans_view(self, name: str, points: np.ndarray, k: int,
                           num_shards: int = 4,
                           capacity: Optional[int] = None,
                           **kw) -> MaterializedView:
        store = PointStore(points, num_shards, capacity)
        return self.create_view(name, algorithm="kmeans", store=store,
                                k=k, **kw)

    # ---- routing ---------------------------------------------------------
    def __getitem__(self, name: str) -> MaterializedView:
        return self.views[name]

    def mutate(self, name: str, *mutations: Mutation) -> int:
        return self.views[name].apply(*mutations)

    def refresh(self, name: Optional[str] = None,
                force: Optional[str] = None) -> dict[str, RefreshReport]:
        """Refresh one view (or all); journals sealed batches durably.

        Batches are journaled BEFORE their fixpoint runs (via the view's
        ``on_sealed`` hook), so a crash or executor failure mid-repair
        never loses an accepted batch — ``restore`` replays it through
        the journaled path."""
        names = [name] if name is not None else list(self.views)
        reports = {}
        for nm in names:
            view = self.views[nm]

            def on_sealed(batch, mode, _view=view, _nm=nm):
                # Every sealed batch deepens the journal replay a restore
                # would perform — tracked whether or not a durable journal
                # is attached (the gauge is the replay-depth signal).
                self._set_depth(_nm, self.journal_depth.get(_nm, 0) + 1)
                if self.journal is not None:
                    self.journal.log_batch(_view, batch, mode=mode)

            reports[nm] = view.refresh(force=force, on_sealed=on_sealed)
        return reports

    def query(self, name: str, detail: bool = False):
        """Serve the view's answer; NEVER raises for a degraded view —
        the last converged snapshot is served instead.  With
        ``detail=True`` returns a :class:`QueryAnswer` carrying the
        staleness metadata (version served vs latest, batches behind,
        degradation reason); the default returns the bare array for
        backward compatibility."""
        view = self.views[name]
        return view.answer() if detail else view.query()

    def drop(self, name: str) -> None:
        del self.views[name]
        if self.journal is not None:
            self.journal.forget(name)    # else restore() resurrects it

    def checkpoint(self, name: Optional[str] = None) -> None:
        """Write fresh base snapshots, truncating each view's replay."""
        if self.journal is None:
            raise RuntimeError("manager has no journal attached")
        for nm in ([name] if name is not None else list(self.views)):
            self.journal.save_base(self.views[nm])
            self._set_depth(nm, 0)     # fresh base truncates the replay

    # ---- recovery --------------------------------------------------------
    @classmethod
    def restore(cls, journal_root: str) -> "ViewManager":
        """Rebuild every journaled view: base snapshot + replayed batches."""
        from repro.incremental.journal import ViewJournal
        mgr = cls(journal_root=None)
        journal = ViewJournal(journal_root)
        for name in journal.view_names():
            view, batches = journal.load_view(name)
            for batch, mode in batches:
                view.apply(*batch.mutations)
                view.refresh(force=mode)   # replay the journaled path
            mgr.views[name] = view
        mgr.journal = journal          # re-attach AFTER replay so the
        return mgr                     # replayed batches aren't re-logged
