"""Base-data mutations and the versioned mutation log.

The incremental subsystem treats a change to the *immutable* set (the
paper's terminology for base data) as just another delta: an edge insert
is a ``+()`` tuple, a delete a ``−()``, a reweight a ``→(t')``, and the
per-algorithm repair they induce on converged state is a ``δ(E)``
adjustment (see ``incremental/rules/``).  This module defines the host-side
mutation records and the :class:`MutationLog` that batches them between
view refreshes.

Every mutation gets a monotonically increasing sequence number; a refresh
*seals* the pending mutations into a :class:`MutationBatch` stamped with
the view version it produces.  Sealed batches are what the durable journal
(``incremental/journal.py``) persists and what recovery replays.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.delta import ANN_DELETE, ANN_INSERT, ANN_REPLACE

# Journal encoding ids (payload column 0 of the encoded batch).
KIND_EDGE_INSERT = 0
KIND_EDGE_DELETE = 1
KIND_EDGE_REWEIGHT = 2
KIND_POINT_INSERT = 3
KIND_POINT_REMOVE = 4


@dataclasses.dataclass(frozen=True)
class EdgeInsert:
    """+() on the edge relation: add one (u, v) occurrence (multi-edges
    are meaningful — PageRank mass follows multiplicity)."""

    u: int
    v: int
    kind = KIND_EDGE_INSERT
    ann = ANN_INSERT


@dataclasses.dataclass(frozen=True)
class EdgeDelete:
    """−() on the edge relation: remove one (u, v) occurrence."""

    u: int
    v: int
    kind = KIND_EDGE_DELETE
    ann = ANN_DELETE


@dataclasses.dataclass(frozen=True)
class EdgeReweight:
    """→(t') on the edge relation: set the multiplicity of (u, v).

    The engine's graphs are unweighted; integer multiplicity is the weight
    analogue (PageRank mass is proportional to it).  Lowered to the
    insert/delete difference by the store.
    """

    u: int
    v: int
    multiplicity: int
    kind = KIND_EDGE_REWEIGHT
    ann = ANN_REPLACE


@dataclasses.dataclass(frozen=True)
class PointInsert:
    """+() on the point relation (k-means).  The store assigns the lowest
    free slot deterministically so journal replay is reproducible."""

    x: float
    y: float
    kind = KIND_POINT_INSERT
    ann = ANN_INSERT


@dataclasses.dataclass(frozen=True)
class PointRemove:
    """−() on the point relation: free one occupied slot."""

    slot: int
    kind = KIND_POINT_REMOVE
    ann = ANN_DELETE


Mutation = EdgeInsert | EdgeDelete | EdgeReweight | PointInsert | PointRemove


@dataclasses.dataclass(frozen=True)
class MutationBatch:
    """A sealed group of mutations producing view version ``version``."""

    version: int
    first_seq: int
    mutations: tuple[Mutation, ...]

    def __len__(self) -> int:
        return len(self.mutations)


class MutationLog:
    """Append-only mutation buffer with versioned sealing.

    ``append`` stamps sequence numbers; ``seal`` drains the pending buffer
    into a :class:`MutationBatch` for the given target version.  The log
    keeps sealed batches (bounded by ``history``) so the journal and
    debugging tools can inspect what produced each version.
    """

    def __init__(self, history: int = 64):
        self._pending: list[Mutation] = []
        self._seq = 0
        self._history = history
        self.batches: list[MutationBatch] = []

    @property
    def pending_count(self) -> int:
        return len(self._pending)

    @property
    def next_seq(self) -> int:
        return self._seq

    def append(self, *mutations: Mutation) -> int:
        """Append mutations; returns the sequence number of the first."""
        first = self._seq
        self._pending.extend(mutations)
        self._seq += len(mutations)
        return first

    def seal(self, version: int) -> MutationBatch:
        batch = MutationBatch(
            version=version,
            first_seq=self._seq - len(self._pending),
            mutations=tuple(self._pending))
        self._pending = []
        self.batches.append(batch)
        if len(self.batches) > self._history:
            self.batches = self.batches[-self._history:]
        return batch

    def unseal(self, batch: MutationBatch) -> None:
        """Undo a just-sealed batch (refresh failed before taking effect):
        its mutations go back to the front of the pending buffer."""
        if self.batches and self.batches[-1] is batch:
            self.batches.pop()
        self._pending = list(batch.mutations) + self._pending


# ---------------------------------------------------------------------------
# Journal encoding: one mutation -> one (key, payload[4]) row, reusing the
# delta-checkpoint wire shape of runtime/checkpoint.py (keys + payloads).
# ---------------------------------------------------------------------------

def encode_batch(batch: MutationBatch) -> tuple[np.ndarray, np.ndarray]:
    """Encode a batch as (keys=int64 seq ids, payload=f64[n, 4]) arrays.

    Payload rows are ``[kind, a, b, c]``; float64 carries vertex ids and
    point coordinates exactly.
    """
    n = len(batch.mutations)
    keys = batch.first_seq + np.arange(n, dtype=np.int64)
    payload = np.zeros((n, 4), np.float64)
    for i, m in enumerate(batch.mutations):
        if isinstance(m, EdgeInsert):
            payload[i] = [KIND_EDGE_INSERT, m.u, m.v, 0.0]
        elif isinstance(m, EdgeDelete):
            payload[i] = [KIND_EDGE_DELETE, m.u, m.v, 0.0]
        elif isinstance(m, EdgeReweight):
            payload[i] = [KIND_EDGE_REWEIGHT, m.u, m.v, m.multiplicity]
        elif isinstance(m, PointInsert):
            payload[i] = [KIND_POINT_INSERT, m.x, m.y, 0.0]
        elif isinstance(m, PointRemove):
            payload[i] = [KIND_POINT_REMOVE, m.slot, 0.0, 0.0]
        else:  # pragma: no cover - exhaustive over Mutation
            raise TypeError(type(m))
    return keys, payload


def decode_batch(version: int, keys: np.ndarray, payload: np.ndarray
                 ) -> MutationBatch:
    """Inverse of :func:`encode_batch`."""
    muts: list[Mutation] = []
    for row in np.asarray(payload, np.float64):
        kind = int(row[0])
        if kind == KIND_EDGE_INSERT:
            muts.append(EdgeInsert(int(row[1]), int(row[2])))
        elif kind == KIND_EDGE_DELETE:
            muts.append(EdgeDelete(int(row[1]), int(row[2])))
        elif kind == KIND_EDGE_REWEIGHT:
            muts.append(EdgeReweight(int(row[1]), int(row[2]), int(row[3])))
        elif kind == KIND_POINT_INSERT:
            muts.append(PointInsert(float(row[1]), float(row[2])))
        elif kind == KIND_POINT_REMOVE:
            muts.append(PointRemove(int(row[1])))
        else:
            raise ValueError(f"unknown mutation kind {kind}")
    first = int(keys[0]) if len(keys) else 0
    return MutationBatch(version=version, first_seq=first,
                         mutations=tuple(muts))
