"""Versioned base-data stores for materialized views.

A store owns the host-side authoritative copy of a view's *immutable* set
(the paper's base data) and absorbs sealed mutation batches, reporting to
the repair rules exactly what changed (:class:`GraphBatchEffect` /
:class:`PointBatchEffect`).  Device-side arrays are rebuilt with **pinned
capacities** so that every refresh reuses the already-traced fixpoint —
static shapes are what keep the warm path warm.

``GraphStore`` keeps the edge relation as a multiset (parallel src/dst
arrays plus a sorted-code index for O(log E) membership); ``PointStore``
keeps a fixed-capacity slot array with a validity mask (dead slots are
masked out of the k-means strata, never reshaped away).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

import jax.numpy as jnp

from repro.data.graphs import CSRGraph, edges_to_csr, shard_csr
from repro.incremental.mutations import (EdgeDelete, EdgeInsert, EdgeReweight,
                                         Mutation, PointInsert, PointRemove)


@dataclasses.dataclass(frozen=True)
class GraphBatchEffect:
    """What one sealed batch did to the edge relation.

    ``changed_src`` lists every source whose out-edge set changed, with its
    pre/post out-degree (multiplicity-counted) aligned by position.
    ``old_edges`` / ``new_edges`` are the FULL (src, dst) edge lists of the
    changed sources before/after the batch — exactly what the PageRank
    rank-redistribution rule needs.  ``inserted`` / ``deleted`` are the raw
    per-occurrence edge arrays for the monotone/closure rules.
    """

    inserted: tuple[np.ndarray, np.ndarray]
    deleted: tuple[np.ndarray, np.ndarray]
    changed_src: np.ndarray
    old_deg: np.ndarray
    new_deg: np.ndarray
    old_edges: tuple[np.ndarray, np.ndarray]
    new_edges: tuple[np.ndarray, np.ndarray]

    @property
    def size(self) -> int:
        return len(self.inserted[0]) + len(self.deleted[0])


@dataclasses.dataclass(frozen=True)
class PointBatchEffect:
    """Slot-level effect of a point batch: arrays aligned per occurrence."""

    inserted_slots: np.ndarray
    inserted_points: np.ndarray     # f32[n_ins, 2]
    removed_slots: np.ndarray
    removed_points: np.ndarray      # f32[n_rem, 2]

    @property
    def size(self) -> int:
        return len(self.inserted_slots) + len(self.removed_slots)


class GraphStore:
    """Mutable edge multiset over a fixed vertex set [0, n).

    The sharded CSR is rebuilt per refresh with a pinned per-shard
    ``nnz_capacity`` (initial max shard load × ``headroom``); if a batch
    overflows the pin, capacity doubles and the view re-traces once —
    growth is amortized, shrink never re-traces.
    """

    def __init__(self, indptr: np.ndarray, indices: np.ndarray, n: int,
                 num_shards: int, headroom: float = 2.0):
        from repro.data.graphs import csr_to_edges
        src, dst = csr_to_edges(np.asarray(indptr), np.asarray(indices))
        self.n = int(n)
        self.num_shards = int(num_shards)
        self._src = src.astype(np.int64)
        self._dst = dst.astype(np.int64)
        self._reindex()
        self.nnz_capacity = max(int(self._max_shard_nnz() * headroom), 1)

    # ---- construction helpers -------------------------------------------
    @classmethod
    def from_edges(cls, src: np.ndarray, dst: np.ndarray, n: int,
                   num_shards: int, headroom: float = 2.0) -> "GraphStore":
        indptr, indices = edges_to_csr(np.asarray(src), np.asarray(dst), n)
        return cls(indptr, indices, n, num_shards, headroom)

    def _reindex(self):
        self._codes = self._src * self.n + self._dst
        self._order = np.argsort(self._codes, kind="stable")
        self._sorted_codes = self._codes[self._order]

    def _max_shard_nnz(self) -> int:
        block = -(-self.n // self.num_shards)
        shard_of_src = self._src // block
        counts = np.bincount(shard_of_src, minlength=self.num_shards)
        return int(counts.max()) if len(counts) else 0

    # ---- queries ---------------------------------------------------------
    @property
    def n_edges(self) -> int:
        return len(self._src)

    def edges(self) -> tuple[np.ndarray, np.ndarray]:
        """Current (src, dst) arrays — shared, do not mutate."""
        return self._src, self._dst

    def multiplicity(self, u: int, v: int) -> int:
        c = u * self.n + v
        lo = np.searchsorted(self._sorted_codes, c, "left")
        hi = np.searchsorted(self._sorted_codes, c, "right")
        return int(hi - lo)

    def out_degree_of(self, sources: np.ndarray) -> np.ndarray:
        sources = np.asarray(sources, np.int64)
        lo = np.searchsorted(self._sorted_codes, sources * self.n, "left")
        hi = np.searchsorted(self._sorted_codes, (sources + 1) * self.n,
                             "left")
        return (hi - lo).astype(np.int64)

    def edges_of(self, sources: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """All (src, dst) occurrences whose source is in ``sources``."""
        sources = np.asarray(sources, np.int64)
        lo = np.searchsorted(self._sorted_codes, sources * self.n, "left")
        hi = np.searchsorted(self._sorted_codes, (sources + 1) * self.n,
                             "left")
        pos = np.concatenate([self._order[a:b] for a, b in zip(lo, hi)]) \
            if len(sources) else np.zeros(0, np.int64)
        return self._src[pos], self._dst[pos]

    # ---- mutation --------------------------------------------------------
    def apply_batch(self, mutations: Sequence[Mutation]) -> GraphBatchEffect:
        # Walk the batch in order, accumulating each edge's multiplicity
        # delta relative to the base store; sequential validity (a delete
        # may consume an insert earlier in the same batch, never a later
        # one) falls out of the running count.  The NET delta is what the
        # store applies and what the repair rules see.
        net: dict[int, int] = {}
        for m in mutations:
            if isinstance(m, (EdgeInsert, EdgeDelete, EdgeReweight)):
                self._check_vertex(m.u, m.v)
                code = m.u * self.n + m.v
            else:
                raise TypeError(
                    f"GraphStore cannot apply {type(m).__name__}")
            if isinstance(m, EdgeInsert):
                net[code] = net.get(code, 0) + 1
            elif isinstance(m, EdgeDelete):
                if self.multiplicity(m.u, m.v) + net.get(code, 0) <= 0:
                    raise KeyError(
                        f"delete of edge ({m.u}, {m.v}): no occurrence "
                        f"present at this point in the batch")
                net[code] = net.get(code, 0) - 1
            else:
                if m.multiplicity < 0:
                    raise ValueError("multiplicity must be >= 0")
                cur = self.multiplicity(m.u, m.v) + net.get(code, 0)
                net[code] = net.get(code, 0) + (m.multiplicity - cur)

        ins_codes = np.sort(np.repeat(
            np.asarray([c for c, d in net.items() if d > 0], np.int64),
            [d for d in net.values() if d > 0]))
        del_codes = np.sort(np.repeat(
            np.asarray([c for c, d in net.items() if d < 0], np.int64),
            [-d for d in net.values() if d < 0]))
        ins = (ins_codes // self.n, ins_codes % self.n)
        dele = (del_codes // self.n, del_codes % self.n)
        changed = np.unique(np.concatenate([ins[0], dele[0]]))
        old_deg = self.out_degree_of(changed)
        old_edges = self.edges_of(changed)

        # Locate one stored occurrence per delete (grouped by code so that
        # duplicate deletes of the same edge consume successive slots).
        if len(dele[0]):
            codes = dele[0] * self.n + dele[1]
            uniq, counts = np.unique(codes, return_counts=True)
            drop: list[np.ndarray] = []
            for c, m in zip(uniq, counts):
                lo = np.searchsorted(self._sorted_codes, c, "left")
                hi = np.searchsorted(self._sorted_codes, c, "right")
                if hi - lo < m:
                    u, v = divmod(int(c), self.n)
                    raise KeyError(
                        f"delete of edge ({u}, {v}) x{m}: only {hi - lo} "
                        f"occurrence(s) present")
                drop.append(self._order[lo:lo + m])
            keep = np.ones(len(self._src), bool)
            keep[np.concatenate(drop)] = False
            self._src = self._src[keep]
            self._dst = self._dst[keep]
        if len(ins[0]):
            self._src = np.concatenate([self._src, ins[0]])
            self._dst = np.concatenate([self._dst, ins[1]])
        self._reindex()

        return GraphBatchEffect(
            inserted=ins, deleted=dele, changed_src=changed,
            old_deg=old_deg, new_deg=self.out_degree_of(changed),
            old_edges=old_edges, new_edges=self.edges_of(changed))

    def _check_vertex(self, *vs: int):
        for v in vs:
            if not (0 <= v < self.n):
                raise IndexError(f"vertex {v} outside [0, {self.n})")

    # ---- device view -----------------------------------------------------
    def build_sharded(self) -> CSRGraph:
        """Sharded CSR with the pinned capacity; doubles the pin (forcing
        one re-trace in the caller) when a growth batch overflows it."""
        indptr, indices = edges_to_csr(self._src, self._dst, self.n)
        while True:
            try:
                return shard_csr(indptr, indices, self.num_shards,
                                 nnz_capacity=self.nnz_capacity)
            except ValueError:
                self.nnz_capacity *= 2

    # ---- journal snapshot ------------------------------------------------
    def to_arrays(self) -> dict:
        return {"src": self._src, "dst": self._dst,
                "n": np.asarray(self.n), "num_shards":
                np.asarray(self.num_shards),
                "nnz_capacity": np.asarray(self.nnz_capacity)}

    @classmethod
    def from_arrays(cls, arrays: dict) -> "GraphStore":
        store = cls.from_edges(np.asarray(arrays["src"]),
                               np.asarray(arrays["dst"]),
                               int(arrays["n"]), int(arrays["num_shards"]))
        store.nnz_capacity = int(arrays["nnz_capacity"])
        return store


class PointStore:
    """Fixed-capacity 2-D point set with a validity mask (k-means views).

    ``capacity`` is padded to ``num_shards`` equal blocks; slot ids are
    global indices into the flattened [capacity] array.  Inserts take the
    lowest free slot (deterministic for journal replay).
    """

    def __init__(self, points: np.ndarray, num_shards: int,
                 capacity: int | None = None):
        points = np.asarray(points, np.float32).reshape(-1, 2)
        n = len(points)
        if capacity is None:
            capacity = 2 * n
        block = -(-capacity // num_shards)
        self.capacity = block * num_shards
        self.block = block
        self.num_shards = int(num_shards)
        self._points = np.zeros((self.capacity, 2), np.float32)
        self._points[:n] = points
        self._valid = np.zeros(self.capacity, bool)
        self._valid[:n] = True

    @property
    def n_points(self) -> int:
        return int(self._valid.sum())

    def point(self, slot: int) -> np.ndarray:
        return self._points[slot]

    def is_valid(self, slot: int) -> bool:
        return bool(self._valid[slot])

    def apply_batch(self, mutations: Sequence[Mutation]) -> PointBatchEffect:
        # Stage on copies, commit at the end: a mid-batch error (bad slot,
        # store full) must leave the store untouched so the caller can
        # drop or fix the batch without losing atomicity.
        points = self._points.copy()
        valid = self._valid.copy()
        ins_slots: list[int] = []
        ins_pts: list[tuple[float, float]] = []
        rem_slots: list[int] = []
        rem_pts: list[np.ndarray] = []
        live_in_batch: dict[int, int] = {}   # slot -> index into ins_slots
        for m in mutations:
            if isinstance(m, PointInsert):
                free = np.flatnonzero(~valid)
                if not len(free):
                    raise OverflowError("PointStore is full")
                slot = int(free[0])
                points[slot] = (m.x, m.y)
                valid[slot] = True
                live_in_batch[slot] = len(ins_slots)
                ins_slots.append(slot)
                ins_pts.append((m.x, m.y))
            elif isinstance(m, PointRemove):
                if not (0 <= m.slot < self.capacity) or not valid[m.slot]:
                    raise KeyError(f"slot {m.slot} is not occupied")
                valid[m.slot] = False
                if m.slot in live_in_batch:
                    # Inserted earlier in this batch: the point never
                    # crosses a refresh boundary — cancel the pair so the
                    # repair rule never retracts a not-yet-granted slot.
                    i = live_in_batch.pop(m.slot)
                    ins_slots[i] = -1
                else:
                    rem_slots.append(m.slot)
                    rem_pts.append(points[m.slot].copy())
            else:
                raise TypeError(
                    f"PointStore cannot apply {type(m).__name__}")
        self._points = points
        self._valid = valid
        keep = [i for i, s in enumerate(ins_slots) if s >= 0]
        return PointBatchEffect(
            inserted_slots=np.asarray([ins_slots[i] for i in keep],
                                      np.int64),
            inserted_points=np.asarray([ins_pts[i] for i in keep],
                                       np.float32).reshape(-1, 2),
            removed_slots=np.asarray(rem_slots, np.int64),
            removed_points=np.asarray(rem_pts, np.float32).reshape(-1, 2))

    # ---- device view -----------------------------------------------------
    def build_sharded(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        """(points f32[S, block, 2], valid bool[S, block]) — static shapes."""
        pts = jnp.asarray(
            self._points.reshape(self.num_shards, self.block, 2))
        valid = jnp.asarray(self._valid.reshape(self.num_shards, self.block))
        return pts, valid

    # ---- journal snapshot ------------------------------------------------
    def to_arrays(self) -> dict:
        return {"points": self._points, "valid": self._valid,
                "num_shards": np.asarray(self.num_shards),
                "capacity": np.asarray(self.capacity)}

    @classmethod
    def from_arrays(cls, arrays: dict) -> "PointStore":
        store = cls.__new__(cls)
        # copy: checkpoint-loaded arrays may be read-only views
        store._points = np.array(arrays["points"], np.float32)
        store._valid = np.array(arrays["valid"], bool)
        store.num_shards = int(arrays["num_shards"])
        store.capacity = int(arrays["capacity"])
        store.block = store.capacity // store.num_shards
        return store
