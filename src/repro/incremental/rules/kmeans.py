"""Incremental k-means: centroid nudge on point insert/remove.

The converged KMState keeps exactly the paper's KMAgg aggregates —
per-centroid (Σx, Σy, n).  A point mutation is therefore a literal KMAgg
delta: removing point p assigned to centroid c retracts ``(c, −x, −y, −1)``;
inserting p grants ``(c*, +x, +y, +1)`` to its nearest current centroid.
Folding the nudge keeps the sums/counts invariant exact, and the warm
resume's first stratum re-checks every valid point against the nudged
centroids, so assignments re-settle in the (usually tiny) neighbourhood of
the change.  Unlike the graph rules there is no unique fixpoint — Lloyd
converges to a local optimum — so the warm view tracks the *standing
query* semantics: the clustering evolves continuously instead of being
re-seeded per batch.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.algorithms import kmeans
from repro.algorithms.kmeans import KMState
from repro.core.delta import ANN_ADJUST
from repro.incremental.rules.base import (IncrementalRule, RepairPlan,
                                          make_seed, register)


@register("kmeans")
class KMeansRule(IncrementalRule):

    def bind(self, view) -> None:
        self.k = int(view.params.get("k", 8))
        self.mode = view.params.get("mode", "delta")
        self.max_iters = int(view.params.get("max_iters", 60))
        self.seed = int(view.params.get("seed", 0))
        self._cold_fn = jax.jit(
            lambda pts, init, valid: kmeans.run(
                pts, init, self.mode, self.max_iters, valid))
        self._resume_fn = jax.jit(
            lambda pts, st, valid: kmeans.resume(
                pts, st, self.max_iters, self.mode, valid))

    def _init_centroids(self, view) -> np.ndarray:
        """KMSampleAgg: sample k valid points (deterministic per view)."""
        arrays = view.store.to_arrays()
        pts = np.asarray(arrays["points"], np.float32)
        valid = np.flatnonzero(np.asarray(arrays["valid"]))
        rng = np.random.default_rng(self.seed)
        pick = rng.choice(valid, size=self.k, replace=len(valid) < self.k)
        return pts[pick]

    def cold(self, view):
        pts, valid = view.immutable
        _, res = self._cold_fn(pts, self._init_centroids(view), valid)
        return res.state, res

    def resume(self, view, state: KMState):
        pts, valid = view.immutable
        _, res = self._resume_fn(pts, state, valid)
        return res.state, res

    def repair(self, view, effect, state: KMState) -> RepairPlan:
        assign = np.asarray(state.assign).reshape(-1).copy()
        sums = np.asarray(state.sums, np.float64).copy()
        counts = np.asarray(state.counts, np.float64).copy()
        adj = np.zeros((self.k, 3), np.float64)

        for slot, p in zip(effect.removed_slots, effect.removed_points):
            c = int(assign[slot])
            adj[c] -= (p[0], p[1], 1.0)
        cents = sums / np.maximum(counts, 1.0)[:, None]
        for slot, p in zip(effect.inserted_slots, effect.inserted_points):
            c = int(np.argmin(((cents - p) ** 2).sum(axis=1)))
            assign[slot] = c
            adj[c] += (p[0], p[1], 1.0)

        sums += adj[:, :2]
        counts += adj[:, 2]
        nudged = np.flatnonzero(np.abs(adj).sum(axis=1))
        seed = make_seed(nudged, adj[nudged], ANN_ADJUST)
        S, B = state.assign.shape
        import jax.numpy as jnp
        new_state = KMState(
            assign=jnp.asarray(assign.reshape(S, B)),
            sums=jnp.asarray(sums.astype(np.float32)),
            counts=jnp.asarray(counts.astype(np.float32)))
        return RepairPlan(state=new_state, touched_keys=effect.size,
                          seeds={"centroid_nudge": seed})

    def extract(self, view, state: KMState) -> np.ndarray:
        return np.asarray(kmeans.centroids_of(state), np.float32)

    def state_template(self, view):
        import jax.numpy as jnp
        S, B = view.store.num_shards, view.store.block
        return KMState(assign=jnp.zeros((S, B), jnp.int32),
                       sums=jnp.zeros((self.k, 2), jnp.float32),
                       counts=jnp.zeros((self.k,), jnp.float32))
