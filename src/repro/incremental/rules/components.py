"""Incremental connected components: merge fast-path, split re-derivation.

Min-label propagation converges to ``label(v) = min id over {v} ∪
ancestors(v)``.  An edge insert (u, v) can only *merge*: the seed is a
→(t') replacement ``label(v) ← min(label(v), label(u))`` and the warm
resume floods the smaller label forward — exactly the paper's monotone
Δ-set restart.

An edge delete can *split* a component (or orphan a label that flowed
through the deleted edge).  Reusing the SSSP closure machinery with the
tightness test ``label(child) == label(parent)`` (label could have flowed
through) and excluding self-labelled vertices (their own id needs no
derivation), the rule resets the affected closure to self-labels (−() on
the derived tuples) and re-emits the rim's still-valid labels; the resumed
fixpoint re-floods minimum labels only through the damaged region.
"""
from __future__ import annotations

import numpy as np

from repro.algorithms import connected_components as cc
from repro.algorithms.connected_components import CCState
from repro.core.delta import ANN_ADJUST, ANN_DELETE, ANN_REPLACE
from repro.incremental.rules.base import (GraphRuleBase, RepairPlan,
                                          make_seed, register)
from repro.incremental.rules.sssp import affected_closure, boundary_sources


@register("connected_components")
class ConnectedComponentsRule(GraphRuleBase):

    def make_algo(self, view, src_capacity, edge_capacity):
        return cc.make_algorithm(self.snapshot, src_capacity,
                                 edge_capacity)

    def cold_impl(self, graph):
        state0 = cc.initial_state(self.snapshot)
        return self.executor.run(self.algo, state0,
                                 self.snapshot.padded_keys, graph,
                                 self.max_iters, mode=self.mode)

    def repair(self, view, effect, state: CCState) -> RepairPlan:
        label = self.flat64(state.label)
        sent = self.flat64(state.sent)
        ids = np.arange(len(label), dtype=np.float64)
        src, dst = view.store.edges()
        seeds = {}
        touched = 0

        # --- deletions: split handling via forward label closure ---------
        du, dv = effect.deleted
        if len(du):
            # v's label is suspect iff it equals u's (may have flowed
            # through the deleted edge) and is not v's own id.
            A = affected_closure(
                label, du, dv, view.store,
                lambda p, c, i: (c == p) & (c != i.astype(np.float64)))
            aff = np.flatnonzero(A)
            if len(aff):
                rim = boundary_sources(A, label, src, dst)
                label[aff] = aff.astype(np.float64)   # reset to self-label
                sent[aff] = np.inf                    # re-flood own id
                sent[rim] = np.inf                    # re-emit valid labels
                seeds["invalidate"] = make_seed(
                    aff, aff.astype(np.float64), ANN_DELETE)
                seeds["repush"] = make_seed(rim, label[rim], ANN_ADJUST)
                touched += len(aff) + len(rim)

        # --- insertions: monotone merge ----------------------------------
        iu, iv = effect.inserted
        if len(iu):
            cand = label[iu]
            improves = cand < label[iv]
            tgt, val = iv[improves], cand[improves]
            if len(tgt):
                np.minimum.at(label, tgt, val)
                seeds["merge"] = make_seed(tgt, val, ANN_REPLACE)
                touched += len(np.unique(tgt))

        new_state = CCState(label=self.shard_f32(label),
                            sent=self.shard_f32(sent))
        return RepairPlan(state=new_state, touched_keys=touched,
                          seeds=seeds)

    def extract(self, view, state: CCState) -> np.ndarray:
        return self.flat64(state.label)[:self.snapshot.n_keys].astype(
            np.float32)

    def state_template(self, view):
        return cc.initial_state(self.snapshot)
