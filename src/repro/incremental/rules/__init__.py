"""Per-algorithm incremental repair rules, plugged in via a registry.

A rule knows how to (a) cold-start its algorithm on a view's base data,
(b) translate a :class:`~repro.incremental.stores.GraphBatchEffect` /
``PointBatchEffect`` into seed deltas over the converged state, and
(c) resume the engine's fixpoint from the repaired state.  New workloads
register with :func:`register` — the ViewManager looks rules up by name.
"""
from __future__ import annotations

from repro.incremental.rules.base import (IncrementalRule, RepairPlan,
                                          get_rule, register, registered)

# Importing the built-in rules registers them.
from repro.incremental.rules import components as _components  # noqa: F401,E402
from repro.incremental.rules import kmeans as _kmeans  # noqa: F401,E402
from repro.incremental.rules import pagerank as _pagerank  # noqa: F401,E402
from repro.incremental.rules import sssp as _sssp  # noqa: F401,E402

__all__ = ["IncrementalRule", "RepairPlan", "get_rule", "register",
           "registered"]
