"""Incremental SSSP: monotone decrease-repair + bounded re-derive fallback.

Insertions are the easy, monotone direction: an inserted edge (u, v) can
only improve v, so the seed is a →(t') replacement ``dist(v) ←
min(dist(v), dist(u)+1)``; any vertex that improved fails the
``dist < sent`` test and the warm resume pushes the improvement onward —
the classic frontier restart.

Deletions are non-monotone: a distance may have been *derived through* a
deleted edge.  The rule computes a conservative affected closure A —
heads of deleted tight edges, expanded forward along still-tight edges —
then (a) invalidates A (``−()``: dist ← ∞) and (b) marks the frontier of
still-valid in-neighbors of A for re-propagation (δ(E): sent ← ∞, so the
engine re-emits their settled distances).  This is the *bounded
re-derivation*: only A and its one-hop boundary re-enter the fixpoint.
When A grows past the ViewManager's threshold, the view falls back to a
cold recompute instead (the delta/dense duality lifted to the
update-to-update level).
"""
from __future__ import annotations

import numpy as np

from repro.algorithms import sssp
from repro.algorithms.sssp import SPState
from repro.core.delta import ANN_ADJUST, ANN_DELETE, ANN_REPLACE
from repro.incremental.rules.base import (GraphRuleBase, RepairPlan,
                                          make_seed, register)


def affected_closure(val: np.ndarray, del_u: np.ndarray, del_v: np.ndarray,
                     store, tight) -> np.ndarray:
    """Conservative forward closure of possibly-invalidated derivations.

    ``tight(parent_val, child_val, child_id)`` says whether the child's
    value could have been derived through the parent (e.g. ``c == p + 1``
    for SSSP).  Returns a bool mask over keys.  Correctness: any vertex
    NOT in the closure keeps at least one fully-valid derivation chain,
    by induction over chain length, so its value is untouched.

    Expansion walks only the frontier's out-edges through the store's
    sorted edge index, so host work is O(edges of the affected region),
    not O(closure depth × |E|).
    """
    n = len(val)
    A = np.zeros(n, bool)
    seed_ok = tight(val[del_u], val[del_v], del_v)
    frontier = np.unique(del_v[seed_ok])
    A[frontier] = True
    while len(frontier):
        eu, ev = store.edges_of(frontier)
        m = ~A[ev] & tight(val[eu], val[ev], ev)
        frontier = np.unique(ev[m])
        if not len(frontier):
            break
        A[frontier] = True
    return A


def boundary_sources(A: np.ndarray, val: np.ndarray, src: np.ndarray,
                     dst: np.ndarray) -> np.ndarray:
    """Still-valid in-neighbors of the affected set (the re-derive rim)."""
    m = ~A[src] & A[dst] & np.isfinite(val[src])
    return np.unique(src[m])


@register("sssp")
class SSSPRule(GraphRuleBase):

    def make_algo(self, view, src_capacity, edge_capacity):
        self.source = int(view.params.get("source", 0))
        return sssp.make_algorithm(self.snapshot, src_capacity,
                                   edge_capacity)

    def cold_impl(self, graph):
        state0 = sssp.initial_state(self.snapshot, self.source)
        return self.executor.run(self.algo, state0, 1, graph,
                                 self.max_iters, mode=self.mode)

    def repair(self, view, effect, state: SPState) -> RepairPlan:
        dist = self.flat64(state.dist)
        sent = self.flat64(state.sent)
        src, dst = view.store.edges()
        seeds = {}
        touched = 0

        # --- deletions: invalidate the affected closure, mark its rim ----
        du, dv = effect.deleted
        if len(du):
            A = affected_closure(
                dist, du, dv, view.store,
                lambda p, c, _i: np.isfinite(c) & (c == p + 1.0))
            A[self.source] = False          # dist(source)=0 is axiomatic
            aff = np.flatnonzero(A)
            if len(aff):
                rim = boundary_sources(A, dist, src, dst)
                dist[aff] = np.inf
                sent[aff] = np.inf
                sent[rim] = np.inf          # re-emit settled distances
                seeds["invalidate"] = make_seed(
                    aff, np.full(len(aff), np.inf), ANN_DELETE)
                seeds["repush"] = make_seed(
                    rim, dist[rim], ANN_ADJUST)
                touched += len(aff) + len(rim)

        # --- insertions: monotone one-step relaxation --------------------
        iu, iv = effect.inserted
        if len(iu):
            cand = dist[iu] + 1.0
            improves = cand < dist[iv]
            tgt, val = iv[improves], cand[improves]
            if len(tgt):
                np.minimum.at(dist, tgt, val)
                seeds["relax"] = make_seed(tgt, val, ANN_REPLACE)
                touched += len(np.unique(tgt))

        new_state = SPState(dist=self.shard_f32(dist),
                            sent=self.shard_f32(sent))
        return RepairPlan(state=new_state, touched_keys=touched,
                          seeds=seeds)

    def extract(self, view, state: SPState) -> np.ndarray:
        return self.flat64(state.dist)[:self.snapshot.n_keys].astype(
            np.float32)

    def state_template(self, view):
        return sssp.initial_state(self.snapshot, self.source)
