"""Rule protocol, registry, and seed-delta helpers.

A mutation batch becomes a set of **seed deltas** over the converged
state — host-built :class:`~repro.core.delta.DeltaBuffer`s carrying the
paper's annotations: ``−()`` invalidates derived values the batch may have
broken, ``→(t')`` replaces a value with a known-better bound, and ``δ(E)``
adjusts accumulated aggregates.  Applying the seeds edits the warm state so
that exactly the repaired keys fail the algorithm's convergence test; the
engine's ``resume`` then propagates the repair, doing O(|repair|) work
instead of a cold O(|base data| × strata) rerun.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

import jax.numpy as jnp

from repro.core.delta import DeltaBuffer

_REGISTRY: dict[str, Callable[[], "IncrementalRule"]] = {}


def register(name: str):
    """Class decorator: make a rule constructible by algorithm name."""

    def deco(cls):
        cls.algorithm = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_rule(name: str) -> "IncrementalRule":
    if name not in _REGISTRY:
        raise KeyError(
            f"no incremental rule registered for {name!r}; known: "
            f"{sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def registered() -> list[str]:
    return sorted(_REGISTRY)


@dataclasses.dataclass
class RepairPlan:
    """Outcome of translating one batch into seed deltas.

    ``state`` is the repaired (still host/device mixed) warm state;
    ``touched_keys`` drives the ViewManager's repair-vs-recompute policy;
    ``seeds`` records the DeltaBuffers that were folded in, for
    introspection and tests.
    """

    state: object
    touched_keys: int
    seeds: dict[str, DeltaBuffer] = dataclasses.field(default_factory=dict)


def make_seed(keys: np.ndarray, payload: np.ndarray, ann: int
              ) -> DeltaBuffer:
    """Host-built seed Δ buffer sized exactly to the batch (host code has
    no static-shape constraint — only the device fixpoint does)."""
    keys = np.asarray(keys, np.int32)
    payload = np.asarray(payload, np.float32)
    if payload.ndim == 1:
        payload = payload[:, None]
    n = len(keys)
    return DeltaBuffer(
        keys=jnp.asarray(keys),
        payload=jnp.asarray(payload),
        ann=jnp.full((n,), ann, jnp.int8),
        count=jnp.asarray(n, jnp.int32),
        overflowed=jnp.asarray(False))


class IncrementalRule:
    """Abstract per-algorithm repair rule.

    Lifecycle: ``bind(view)`` once at view creation (build the
    DeltaAlgorithm, executor, and jitted cold/resume callables against the
    store's pinned shapes); ``cold(view)`` for a from-scratch fixpoint;
    ``repair(view, effect, state)`` to translate one sealed batch;
    ``resume(view, state)`` to re-converge; ``extract(view, state)`` to
    produce the queryable result.  ``rebind`` is called when pinned
    capacities grew (one re-trace).
    """

    algorithm: str = "?"

    def bind(self, view) -> None:
        raise NotImplementedError

    def rebind(self, view) -> None:
        self.bind(view)

    def cold(self, view):
        """-> (state, FixpointResult)"""
        raise NotImplementedError

    def repair(self, view, effect, state) -> RepairPlan:
        raise NotImplementedError

    def resume(self, view, state):
        """-> (state, FixpointResult)"""
        raise NotImplementedError

    def extract(self, view, state) -> np.ndarray:
        raise NotImplementedError

    def state_template(self, view):
        """A zero-cost state pytree with the view's shapes — journal
        recovery uses it as the ``like`` argument when reloading."""
        raise NotImplementedError


class GraphRuleBase(IncrementalRule):
    """Shared machinery for rules over the sharded graph engine: builds the
    partition snapshot, executor, and jitted cold/resume callables; exposes
    flat <-> sharded state helpers for the host-side seed translation."""

    def bind(self, view) -> None:
        import jax

        from repro.core.engine import ShardedExecutor
        from repro.core.partition import PartitionSnapshot

        n, S = view.store.n, view.store.num_shards
        self.snapshot = PartitionSnapshot(n_keys=n, num_shards=S)
        self.edge_capacity = int(view.params.get(
            "edge_capacity", max(4 * n, 4096)))
        self.src_capacity = int(view.params.get(
            "src_capacity", self.snapshot.block_size))
        # Warm resumes run with a much tighter Δ budget: repairs are small
        # by construction, sparse-stratum cost is O(capacity) (static
        # shapes), and a flooding repair just falls back to the dense body
        # — correctness never depends on the budget.
        self.resume_edge_capacity = int(view.params.get(
            "resume_edge_capacity", max(self.edge_capacity // 8, 1024)))
        self.resume_src_capacity = int(view.params.get(
            "resume_src_capacity", max(self.src_capacity // 8, 64)))
        self.max_iters = int(view.params.get("max_iters", 80))
        self.mode = view.params.get("mode", "delta")
        # Density ladder (core/engine.py): per-stratum dispatch to the
        # smallest capacity rung that fits the predicted emission.  On the
        # resume executor this doubles as warm-start tier selection — a
        # small repair's strata run at tiny capacities for free.
        self.ladder_tiers = int(view.params.get("ladder_tiers", 4))
        # Rehash strategy (sort | scatter | auto): warm repairs are the
        # tail-stratum regime the scatter path targets, so default to the
        # per-rung cost model instead of pinning the sort.
        self.route_strategy = view.params.get("route_strategy", "auto")
        # Fault-tolerant warm resumes: with a "resilient_root" param the
        # repair fixpoint runs through ShardedExecutor.run_resilient — a
        # per-stratum replica chain under that directory absorbs executor
        # shard failures mid-repair (inject one for tests by setting
        # ``view.fault_plan``), so standing queries survive engine
        # failures without losing the in-flight repair.
        self.resilient_root = view.params.get("resilient_root")
        # Execution backend: views ran pinned to the simulated backend
        # before; backend/mesh/axis_name now flow through to both
        # executors so warm resumes run real-SPMD under shard_map too.
        backend_kw = dict(
            backend=view.params.get("backend", "simulated"),
            mesh=view.params.get("mesh"),
            axis_name=view.params.get("axis_name", "shards"),
            route_strategy=self.route_strategy,
            use_pallas_route=bool(view.params.get("use_pallas_route",
                                                  False)))
        self.executor = ShardedExecutor(
            snapshot=self.snapshot, seg_capacity=self.edge_capacity,
            edge_capacity=self.edge_capacity, src_capacity=self.src_capacity,
            ladder_tiers=self.ladder_tiers, **backend_kw)
        self.resume_executor = ShardedExecutor(
            snapshot=self.snapshot, seg_capacity=self.resume_edge_capacity,
            edge_capacity=self.resume_edge_capacity,
            src_capacity=self.resume_src_capacity,
            ladder_tiers=self.ladder_tiers, **backend_kw)
        self.algo = self.make_algo(view, self.src_capacity,
                                   self.edge_capacity)
        self.resume_algo = self.make_algo(view, self.resume_src_capacity,
                                          self.resume_edge_capacity)
        self._cold_fn = jax.jit(self.cold_impl)
        self._resume_fn = jax.jit(
            lambda st, g: self.resume_executor.resume(
                self.resume_algo, st, g, self.max_iters, mode=self.mode))

    def make_algo(self, view, src_capacity: int, edge_capacity: int):
        raise NotImplementedError

    def cold_impl(self, graph):
        """-> FixpointResult (traced; shapes pinned by the store)."""
        raise NotImplementedError

    def cold(self, view):
        res = self._cold_fn(view.immutable)
        return res.state, res

    def resume(self, view, state):
        fault_plan = getattr(view, "fault_plan", None)
        retry = getattr(view, "retry_policy", None)
        budget = getattr(view, "retry_budget", None)
        if self.resilient_root is None and fault_plan is None \
                and retry is None and budget is None:
            res = self._resume_fn(state, view.immutable)
            return res.state, res
        import shutil
        import tempfile
        # No configured root: a throwaway unique dir per repair — the
        # chain only needs to outlive this one resume (a fixed path
        # could collide across processes, and ReplicaChain wipes its
        # root on construction), so it is removed afterwards.
        root = self.resilient_root or tempfile.mkdtemp(
            prefix="rex_view_chain_")
        try:
            rr = self.resume_executor.resume_resilient(
                self.resume_algo, state, view.immutable, self.max_iters,
                mode=self.mode, ckpt_root=root, fault_plan=fault_plan,
                retry=retry, budget=budget)
        finally:
            if self.resilient_root is None:
                shutil.rmtree(root, ignore_errors=True)
            # Consumed even when the resume fails — a degraded view's
            # catch-up refresh must not re-inject the same faults.
            view.fault_plan = None
        view.last_recovery = rr.metrics
        return rr.result.state, rr.result

    # ---- flat <-> sharded helpers ---------------------------------------
    def flat64(self, field) -> np.ndarray:
        """[S, block] device array -> f64[padded_keys] host array."""
        return np.asarray(field, np.float64).reshape(-1)

    def shard_f32(self, flat: np.ndarray):
        S, B = self.snapshot.num_shards, self.snapshot.block_size
        return jnp.asarray(flat.astype(np.float32).reshape(S, B))
