"""Incremental PageRank: rank redistribution on edge change.

The converged delta-PageRank state satisfies (exactly, modulo float):

    acc(v) = Σ over edge occurrences (u, v) of sent(u) / max(deg(u), 1)

so a batch that edits the out-edge multiset of sources U breaks the
invariant only at the destinations of U's old and new edge sets.  The
repair is a pure δ(E) adjustment: for every changed source u, retract
``sent(u)/deg_old(u)`` along its old edges and grant ``sent(u)/deg_new(u)``
along its new ones.  After folding the adjustment into ``acc``, exactly
the touched destinations fail the ``|pr − sent| ≤ τ`` convergence test and
the engine's warm resume propagates the rank shift — O(deg(U) + repair)
work instead of a cold all-vertex fixpoint.
"""
from __future__ import annotations

import numpy as np

from repro.algorithms import pagerank
from repro.algorithms.pagerank import PRState
from repro.core.delta import ANN_ADJUST
from repro.incremental.rules.base import (GraphRuleBase, RepairPlan,
                                          make_seed, register)


@register("pagerank")
class PageRankRule(GraphRuleBase):

    def make_algo(self, view, src_capacity, edge_capacity):
        self.threshold = float(view.params.get("threshold", 1e-3))
        return pagerank.make_algorithm(
            self.snapshot, self.threshold, src_capacity, edge_capacity)

    def cold_impl(self, graph):
        state0 = pagerank.initial_state(self.snapshot)
        return self.executor.run(
            self.algo, state0, self.snapshot.padded_keys, graph,
            self.max_iters, mode=self.mode)

    def repair(self, view, effect, state: PRState) -> RepairPlan:
        sent = self.flat64(state.sent)
        acc = self.flat64(state.acc)
        adj = np.zeros_like(acc)

        # Per-edge contribution = sent(u)/max(deg(u),1) with deg taken on
        # the side (old/new) the edge set belongs to.  changed_src is
        # sorted, so degree lookup is a searchsorted.
        def fold(edges, deg_of_changed, sign):
            eu, ev = edges
            if not len(eu):
                return
            pos = np.searchsorted(effect.changed_src, eu)
            deg = np.maximum(deg_of_changed[pos], 1).astype(np.float64)
            np.add.at(adj, ev, sign * sent[eu] / deg)

        fold(effect.old_edges, effect.old_deg, -1.0)
        fold(effect.new_edges, effect.new_deg, +1.0)

        touched = np.flatnonzero(adj)
        seed = make_seed(touched, adj[touched], ANN_ADJUST)
        new_acc = self.shard_f32(acc + adj)
        return RepairPlan(state=PRState(acc=new_acc, sent=state.sent),
                          touched_keys=len(touched),
                          seeds={"acc_adjust": seed})

    def extract(self, view, state: PRState) -> np.ndarray:
        pr = pagerank.BASE + pagerank.DAMPING * self.flat64(state.acc)
        return pr[:self.snapshot.n_keys].astype(np.float32)

    def state_template(self, view):
        return pagerank.initial_state(self.snapshot)
