"""Incremental view maintenance: warm-state delta repair for standing
queries.

The engine (``repro.core``) already propagates deltas *within* one
fixpoint run; this package lifts the same idea to the life of a query.  A
:class:`~repro.incremental.view.MaterializedView` keeps a converged
``FixpointResult`` resident; base-data mutations (edge insert/delete/
reweight, point insert/remove) are batched by a versioned
:class:`~repro.incremental.mutations.MutationLog`, translated into seed
deltas by per-algorithm repair rules (``repro.incremental.rules``), and
absorbed by resuming the sharded fixpoint from the warm state.  When the
estimated repair volume exceeds a threshold, the view falls back to a
cold recompute — the paper's delta/dense duality at the update level.
"""
from repro.incremental.journal import ViewJournal
from repro.incremental.mutations import (EdgeDelete, EdgeInsert,
                                         EdgeReweight, MutationBatch,
                                         MutationLog, PointInsert,
                                         PointRemove)
from repro.incremental.rules import get_rule, register, registered
from repro.incremental.stores import GraphStore, PointStore
from repro.incremental.view import (MaterializedView, RefreshReport,
                                    ViewManager)

__all__ = [
    "EdgeDelete", "EdgeInsert", "EdgeReweight", "GraphStore",
    "MaterializedView", "MutationBatch", "MutationLog", "PointInsert",
    "PointRemove", "PointStore", "RefreshReport", "ViewJournal",
    "ViewManager", "get_rule", "register", "registered",
]
