"""Durable view journal, reusing the runtime's delta checkpoints.

Paper §4.3's hybrid checkpointing — periodic full snapshots plus cheap
per-stratum delta checkpoints — maps one-to-one onto standing queries:
the view's converged state (+ its base-data store) is the *full*
checkpoint, and every sealed mutation batch is a *delta* checkpoint
(keys = mutation sequence ids, payload = encoded mutations).  Recovery
is therefore the same replay loop the runtime already uses: restore the
latest full snapshot, then re-apply every journaled batch after it —
each replayed batch going through the normal repair/resume path, so the
recovered view is bit-identical to the lost one.

Layout:  <root>/views.json                      — manifest
         <root>/<view>/node0/full_*.npz         — base snapshots
         <root>/<view>/node0/delta_*.npz        — mutation batches
"""
from __future__ import annotations

import json
import os
from typing import Optional

import numpy as np

import jax

from repro.incremental.mutations import (MutationBatch, decode_batch,
                                         encode_batch)
from repro.incremental.stores import GraphStore, PointStore
from repro.runtime.checkpoint import CheckpointManager, atomic_write_json

_STORE_KINDS = {GraphStore: "graph", PointStore: "points"}
_STORE_CLASSES = {"graph": GraphStore, "points": PointStore}

# Structure templates for CheckpointManager.load_full's ``like`` argument
# (values are dummies — only the pytree structure matters).
_STORE_LIKES = {
    "graph": {k: np.zeros(()) for k in
              ("src", "dst", "n", "num_shards", "nnz_capacity")},
    "points": {k: np.zeros(()) for k in
               ("points", "valid", "num_shards", "capacity")},
}


def _state_leaves_dict(state) -> dict:
    return {f"s{i}": leaf for i, leaf in enumerate(jax.tree.leaves(state))}


class ViewJournal:
    """Per-view CheckpointManagers plus a JSON manifest of view configs."""

    def __init__(self, root: str, retrier=None):
        self.root = root
        # Optional runtime.retry.Retrier shared by every view's
        # CheckpointManager: transient read errors back off and retry
        # deterministically; corrupt files quarantine + fall back.
        self.retrier = retrier
        os.makedirs(root, exist_ok=True)
        self._manifest_path = os.path.join(root, "views.json")
        if os.path.exists(self._manifest_path):
            with open(self._manifest_path) as f:
                self.manifest = json.load(f)
        else:
            self.manifest = {}

    def _cm(self, name: str) -> CheckpointManager:
        return CheckpointManager(os.path.join(self.root, name),
                                 num_nodes=1, replication=1, keep=2,
                                 retrier=self.retrier)

    def _write_manifest(self) -> None:
        # Atomic + fsynced: the manifest names every recoverable view —
        # a torn manifest would orphan all of their checkpoints.
        atomic_write_json(self._manifest_path, self.manifest)

    def view_names(self) -> list[str]:
        return sorted(self.manifest)

    def forget(self, name: str) -> None:
        """Remove a view from the manifest and delete its checkpoints."""
        import shutil
        self.manifest.pop(name, None)
        self._write_manifest()
        d = os.path.join(self.root, name)
        if os.path.isdir(d):
            shutil.rmtree(d)

    # ---- write side ------------------------------------------------------
    def register_view(self, view) -> None:
        kind = _STORE_KINDS[type(view.store)]
        self.manifest[view.name] = {
            "algorithm": view.algorithm,
            "store_kind": kind,
            "params": view.params,          # must stay JSON-serializable
            "fallback_threshold": view.fallback_threshold,
            "state_leaves": len(jax.tree.leaves(view.state)),
        }
        self._write_manifest()

    def save_base(self, view) -> None:
        """Full checkpoint of (store, state) at the view's version; older
        bases and the deltas they cover are garbage-collected."""
        tree = {"store": view.store.to_arrays(),
                "state": _state_leaves_dict(view.state)}
        self._cm(view.name).save_full(node=0, step=view.version, tree=tree)

    def log_batch(self, view, batch: MutationBatch,
                  mode: Optional[str] = None) -> int:
        """Delta checkpoint of one sealed batch; returns bytes written.

        The refresh path taken ("repair"/"cold") is journaled too, so
        recovery replays the SAME path — without it a forced refresh
        would replay under the default policy and the restored view
        could settle in a different (equally converged) state.

        ``mode`` is passed explicitly when the batch is journaled BEFORE
        its fixpoint runs (the decided path; mid-repair crash durability);
        without it the last completed refresh's mode is used (legacy
        post-hoc logging).
        """
        keys, payload = encode_batch(batch)
        if mode is None:
            mode = view.history[-1].mode if view.history else "repair"
        return self._cm(view.name).save_delta(
            node=0, step=batch.version, keys=keys, payload=payload,
            meta={"view": view.name, "mutations": len(batch),
                  "mode": mode})

    # ---- recovery side ---------------------------------------------------
    def load_view(self, name: str):
        """-> (restored MaterializedView, batches to replay)."""
        from repro.incremental.view import MaterializedView

        info = self.manifest[name]
        like = {"store": _STORE_LIKES[info["store_kind"]],
                "state": {f"s{i}": np.zeros(())
                          for i in range(info["state_leaves"])}}
        tree, base_version = self._cm(name).load_full(node=0, like=like)

        store = _STORE_CLASSES[info["store_kind"]].from_arrays(
            {k: np.asarray(v) for k, v in tree["store"].items()})
        view = MaterializedView(
            name, info["algorithm"], store, params=info["params"],
            fallback_threshold=info["fallback_threshold"],
            _restored=(None, base_version))
        template = view.rule.state_template(view)
        leaves = [tree["state"][f"s{i}"]
                  for i in range(info["state_leaves"])]
        view.state = jax.tree.unflatten(
            jax.tree.structure(template), leaves)

        batches = [(decode_batch(step, keys, payload),
                    meta.get("mode", "repair"))
                   for step, keys, payload, meta in
                   self._cm(name).replay_deltas(node=0,
                                                since_step=base_version,
                                                with_meta=True)]
        return view, batches
