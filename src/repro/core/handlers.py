"""User-defined aggregators (UDAs) and delta handlers.

The paper (§3.3) defines four delta-handler forms:

  * ``AGGSTATE(state, delta)``  — fold a delta into per-key aggregate state,
  * ``AGGRESULT(state)``        — emit final deltas at end of stratum,
  * join-state ``update(leftBucket, rightBucket, delta)``,
  * while-state ``update(whileRelation, delta)``.

On TPU the keyed buckets are dense arrays indexed by key, and the handlers
become traced functions over (state arrays, DeltaBuffer).  An :class:`Aggregator`
bundles the handlers plus the optimizer-facing metadata from §5.2:
``composable`` (can be computed in parts and unioned — sum/avg yes, median no)
and ``multiply`` (the multiplicative-join compensation function).

Builtin aggregators mirror the paper's automatic handling of
insert/delete/replace deltas for min/max/sum/count/average; the ``δ(E)``
adjustment annotation is interpreted by ``apply_adjust``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.delta import (ANN_ADJUST, ANN_DELETE, ANN_INSERT, ANN_REPLACE,
                              PAD_KEY, DeltaBuffer)


@dataclasses.dataclass(frozen=True)
class Aggregator:
    """A UDA: delta handlers + optimizer metadata.

    apply_delta(state, db) -> state'
        AGGSTATE: fold an incoming DeltaBuffer into dense keyed state.
    emit(new_state, old_state) -> (keys_mask, payload)
        AGGRESULT: which keys changed materially and what to propagate.
        (The fixpoint driver compacts this into the next Δ buffer.)
    pre_aggregate(db, num_keys) -> db'
        Combiner (§5.2): merge deltas sharing a key *before* the rehash,
        shrinking collective bytes.  Only valid if ``composable``.
    multiply(payload, cardinality) -> payload
        §5.2 multiplicative-join compensation (sum-like UDAs: payload * n).
    identity
        Neutral element of the combiner (0 for sum, +inf for min, ...).
    combiner
        One of "add" | "min" | "max" | "replace" — the scatter combine used
        by delta application; drives kernel selection in kernels/delta_scatter.
    """

    name: str
    combiner: str
    identity: float
    composable: bool = True
    apply_delta: Optional[Callable] = None
    emit: Optional[Callable] = None
    multiply: Optional[Callable] = None

    def scatter_combine(self, state: jax.Array, db: DeltaBuffer) -> jax.Array:
        """Default AGGSTATE: scatter-combine payload column 0 into state."""
        mask = db.keys != PAD_KEY
        n = state.shape[0]
        keys = jnp.where(mask, db.keys, n)
        if self.combiner == "add":
            vals = jnp.where(mask, db.payload[:, 0], 0.0).astype(state.dtype)
            return jnp.concatenate([state, jnp.zeros((1,), state.dtype)]).at[
                keys].add(vals, mode="drop")[:n]
        if self.combiner == "min":
            vals = jnp.where(mask, db.payload[:, 0], jnp.inf).astype(state.dtype)
            return jnp.concatenate([state, jnp.zeros((1,), state.dtype)]).at[
                keys].min(vals, mode="drop")[:n]
        if self.combiner == "max":
            vals = jnp.where(mask, db.payload[:, 0], -jnp.inf).astype(state.dtype)
            return jnp.concatenate([state, jnp.zeros((1,), state.dtype)]).at[
                keys].max(vals, mode="drop")[:n]
        if self.combiner == "replace":
            vals = db.payload[:, 0].astype(state.dtype)
            return jnp.concatenate([state, jnp.zeros((1,), state.dtype)]).at[
                keys].set(vals, mode="drop")[:n]
        raise ValueError(f"unknown combiner {self.combiner!r}")


# ---------------------------------------------------------------------------
# Annotation-aware delta application (paper Definition 1 semantics).
# ---------------------------------------------------------------------------

def apply_annotated(state: jax.Array, exists: jax.Array, db: DeltaBuffer,
                    adjust_combiner: str = "add") -> tuple[jax.Array, jax.Array]:
    """Apply a mixed-annotation DeltaBuffer to (state, exists).

    Implements the paper's insertion/deletion/replacement rules plus the
    δ(E) adjustment (interpreted with ``adjust_combiner``) against a dense
    keyed relation: ``state[f32; N]`` with an ``exists[bool; N]`` occupancy
    mask (dense analogue of "tuple present in operator state").

    Deltas are applied as one vectorized pass per annotation class; within a
    class, collisions on the same key resolve by the scatter combine (adds
    accumulate; inserts/replaces last-writer-wins, matching the paper's
    sequential-application semantics under stable slot order).
    """
    n = state.shape[0]
    mask = db.keys != PAD_KEY
    keys = jnp.where(mask, db.keys, n)
    vals = db.payload[:, 0].astype(state.dtype)
    pad_state = jnp.concatenate([state, jnp.zeros((1,), state.dtype)])
    pad_exists = jnp.concatenate([exists, jnp.zeros((1,), jnp.bool_)])

    is_ins = mask & (db.ann == ANN_INSERT)
    is_del = mask & (db.ann == ANN_DELETE)
    is_rep = mask & (db.ann == ANN_REPLACE)
    is_adj = mask & (db.ann == ANN_ADJUST)

    # insert / replace: set value, mark existing
    set_keys = jnp.where(is_ins | is_rep, keys, n)
    pad_state = pad_state.at[set_keys].set(
        jnp.where(is_ins | is_rep, vals, 0.0), mode="drop")
    pad_exists = pad_exists.at[set_keys].set(True, mode="drop")

    # delete: clear occupancy
    del_keys = jnp.where(is_del, keys, n)
    pad_exists = pad_exists.at[del_keys].set(False, mode="drop")

    # adjust: combine into value (state must exist; adjustment creates it
    # from the combiner identity otherwise, matching "default object" in the
    # paper's AGGSTATE contract)
    adj_keys = jnp.where(is_adj, keys, n)
    if adjust_combiner == "add":
        pad_state = pad_state.at[adj_keys].add(
            jnp.where(is_adj, vals, 0.0), mode="drop")
    elif adjust_combiner == "min":
        pad_state = pad_state.at[adj_keys].min(
            jnp.where(is_adj, vals, jnp.inf), mode="drop")
    elif adjust_combiner == "max":
        pad_state = pad_state.at[adj_keys].max(
            jnp.where(is_adj, vals, -jnp.inf), mode="drop")
    else:
        raise ValueError(adjust_combiner)
    pad_exists = pad_exists.at[adj_keys].set(True, mode="drop")

    return pad_state[:n], pad_exists[:n]


# ---------------------------------------------------------------------------
# Pre-aggregation (the paper's combiner / §5.2 pushdown).
# ---------------------------------------------------------------------------

def pre_aggregate(db: DeltaBuffer, combiner: str) -> DeltaBuffer:
    """Merge deltas sharing a key (sender-side combiner, §5.2).

    Returns a buffer of the same capacity where each live key appears once.
    Reduces both downstream scatter work and — crucially — rehash bytes,
    because padding slots compress to nothing in the Δ-count accounting.
    """
    cap = db.capacity
    mask = db.keys != PAD_KEY
    # Unique-ify keys by sorting; segment-reduce payload.
    sort_keys = jnp.where(mask, db.keys, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(sort_keys, stable=True)
    skeys = sort_keys[order]
    spay = db.payload[order]
    is_head = jnp.concatenate([jnp.array([True]), skeys[1:] != skeys[:-1]])
    seg_id = jnp.cumsum(is_head.astype(jnp.int32)) - 1
    n_seg = cap  # upper bound
    if combiner == "add":
        merged = jnp.zeros((n_seg, db.payload_width), spay.dtype).at[
            seg_id].add(spay)
    elif combiner == "min":
        merged = jnp.full((n_seg, db.payload_width), jnp.inf, spay.dtype).at[
            seg_id].min(spay)
    elif combiner == "max":
        merged = jnp.full((n_seg, db.payload_width), -jnp.inf, spay.dtype).at[
            seg_id].max(spay)
    else:  # replace: last wins
        merged = jnp.zeros((n_seg, db.payload_width), spay.dtype).at[
            seg_id].set(spay)
    # All slots in a segment share the key, so a max-scatter recovers it.
    uniq_keys = jnp.zeros((n_seg,), jnp.int32).at[seg_id].max(skeys)
    live_seg = jnp.zeros((n_seg,), jnp.bool_).at[seg_id].set(
        skeys != jnp.iinfo(jnp.int32).max)
    out_keys = jnp.where(live_seg, uniq_keys, PAD_KEY)
    out_pay = jnp.where(live_seg[:, None], merged, 0.0)
    return DeltaBuffer(
        keys=out_keys, payload=out_pay,
        ann=jnp.full((cap,), ANN_ADJUST, jnp.int8),
        count=jnp.sum(live_seg.astype(jnp.int32)),
        overflowed=db.overflowed)


# ---------------------------------------------------------------------------
# Builtin UDAs (paper: min/max/sum/count/average handled automatically).
# ---------------------------------------------------------------------------

SUM = Aggregator(name="sum", combiner="add", identity=0.0, composable=True,
                 multiply=lambda payload, n: payload * n)
COUNT = Aggregator(name="count", combiner="add", identity=0.0, composable=True,
                   multiply=lambda payload, n: payload * n)
MIN = Aggregator(name="min", combiner="min", identity=float("inf"),
                 composable=True, multiply=lambda payload, n: payload)
MAX = Aggregator(name="max", combiner="max", identity=float("-inf"),
                 composable=True, multiply=lambda payload, n: payload)
LAST = Aggregator(name="last", combiner="replace", identity=0.0,
                  composable=False)
# AVERAGE is the classic two-part aggregate: pre-aggregate keeps (sum, count)
# in payload columns (0, 1); final result divides.  composable (§5.2).
AVERAGE = Aggregator(name="average", combiner="add", identity=0.0,
                     composable=True,
                     multiply=lambda payload, n: payload * n)
# MEDIAN: the paper's example of a NON-composable aggregate — no combiner may
# be pushed below a join/rehash; the optimizer must keep it at the top.
MEDIAN = Aggregator(name="median", combiner="replace", identity=0.0,
                    composable=False)

BUILTIN_UDAS = {a.name: a for a in
                [SUM, COUNT, MIN, MAX, LAST, AVERAGE, MEDIAN]}


def average_result(sum_count_state: jax.Array) -> jax.Array:
    """AGGRESULT for AVERAGE: state[..., 0]=sum, state[..., 1]=count."""
    return sum_count_state[..., 0] / jnp.maximum(sum_count_state[..., 1], 1.0)
