"""REX core: delta-based recursive data-centric computation (paper §3–§5).

Public surface:
  DeltaBuffer, annotations          repro.core.delta
  Aggregator / delta handlers       repro.core.handlers
  Relational operators              repro.core.operators
  Stratified fixpoint driver        repro.core.fixpoint
  Partition snapshots               repro.core.partition
  Sharded execution (rehash)        repro.core.engine
  Plan IR + cost-based optimizer    repro.core.plan / repro.core.optimizer
"""
from repro.core.delta import (ANN_ADJUST, ANN_DELETE, ANN_INSERT, ANN_REPLACE,
                              PAD_KEY, DeltaBuffer, combine_route,
                              combine_route_scatter)
from repro.core.engine import CapacityTier, DeltaAlgorithm, ShardedExecutor
from repro.core.fixpoint import (ROUTE_SCATTER, ROUTE_SORT, FixpointResult,
                                 StratumOutcome, StratumStats, run_strata,
                                 with_explicit_condition)
from repro.core.handlers import BUILTIN_UDAS, Aggregator
from repro.core.partition import PartitionSnapshot

__all__ = [
    "ANN_ADJUST", "ANN_DELETE", "ANN_INSERT", "ANN_REPLACE", "PAD_KEY",
    "DeltaBuffer", "combine_route", "combine_route_scatter", "CapacityTier",
    "DeltaAlgorithm", "ShardedExecutor", "FixpointResult",
    "ROUTE_SORT", "ROUTE_SCATTER",
    "StratumOutcome", "StratumStats", "run_strata",
    "with_explicit_condition", "BUILTIN_UDAS", "Aggregator",
    "PartitionSnapshot",
]
