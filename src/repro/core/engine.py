"""Distributed delta execution: the rehash operator + sharded fixpoint.

The paper's runtime (§4.1–4.2) pushes batched delta messages point-to-point
(TCP) between workers according to the partition snapshot.  The TPU-native
equivalent of that shuffle is a single ``all_to_all`` over equal-size
segments: each shard groups its outgoing deltas by destination
(``route_by_owner``), the collective swaps segments, the receiver recounts
live slots.  The dense (no-delta / fallback) path instead exchanges each
shard's full contribution vector with a summed all_to_all — the two
communication patterns are the delta/dense duality at the wire level, and
their byte counts are what benchmarks/bench_bandwidth.py reports (Fig. 11).

Two execution backends share all algorithm code:

  * ``simulated`` — shards are a leading array axis on one device; the
    all_to_all is an axis transpose.  Deterministically identical to the
    distributed run; used by unit tests and single-host benches.
  * ``shard_map`` — real SPMD over a mesh axis: ``jax.lax.all_to_all`` for
    rehash, ``psum`` for stratum votes.

Algorithms are written against :class:`DeltaAlgorithm` — five shard-local
functions; the engine owns routing, density switching, and the fixpoint
loop.  Outgoing deltas use GLOBAL keys; the engine routes by the partition
snapshot (paper §4.1).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import delta as deltamod
from repro.core.delta import PAD_KEY, DeltaBuffer
from repro.core.fixpoint import (ROUTE_SCATTER, ROUTE_SORT, FixpointResult,
                                 StratumOutcome, run_strata,
                                 with_explicit_condition)
from repro.core.partition import PartitionSnapshot


@dataclasses.dataclass(frozen=True)
class DeltaAlgorithm:
    """A REX recursive query lowered to shard-local callables.

    active_fn(state, imm) -> (active_mask[bool; block], est_edges[int32;])
        The Δᵢ set (keys whose refinement must propagate) plus the EXACT
        emission size if run sparsely (Σ out-degree of active keys).
    sparse_emit(state, imm, active, stratum, shard_id)
        -> (state_partial, DeltaBuffer)        — O(|Δ|) emission.
    dense_emit(state, imm, stratum, shard_id)
        -> (state_partial, contrib[f32; n_padded_global, payload_width])
        — full re-derivation: this shard's contribution to EVERY key.
    apply_sparse(state_partial, incoming: DeltaBuffer, imm, stratum, shard_id)
        -> (state', next_active_count[int32;])
    apply_dense(state_partial, incoming[f32; block, payload_width], imm,
        stratum, shard_id) -> (state', next_active_count)

    combiner — how concurrent contributions to one key merge ("add"|"min").
    payload_width, bytes_per_delta — wire accounting for Fig. 11.
    emit_factory(src_capacity, edge_capacity) -> sparse_emit-like callable
        Optional: rebuild the sparse emission at a different capacity tier.
        Providing it lets the executor compile the stratum body at several
        capacity rungs (the density ladder) and dispatch each stratum to the
        smallest rung that fits its exactly-predicted emission size.
    """

    active_fn: Callable
    sparse_emit: Callable
    dense_emit: Callable
    apply_sparse: Callable
    apply_dense: Callable
    combiner: str = "add"
    payload_width: int = 1
    bytes_per_delta: int = 8  # int32 key + f32 payload
    emit_factory: Optional[Callable] = None

    def dense_identity(self) -> float:
        return {"add": 0.0, "min": float("inf"), "max": float("-inf")}[
            self.combiner]


def _dense_combine(stacked: jax.Array, combiner: str, axis: int) -> jax.Array:
    if combiner == "add":
        return jnp.sum(stacked, axis=axis)
    if combiner == "min":
        return jnp.min(stacked, axis=axis)
    if combiner == "max":
        return jnp.max(stacked, axis=axis)
    raise ValueError(combiner)


def _shard_map_compat(body, mesh, in_specs, out_specs):
    """``jax.shard_map`` (new API, check_vma) with fallback to
    ``jax.experimental.shard_map`` (old API, check_rep) — one shim for
    every shard_map entry point in the engine."""
    try:
        from jax import shard_map as _shard_map
        return _shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    except (ImportError, TypeError):
        from jax.experimental.shard_map import shard_map as _shard_map
        return _shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)


# Per-device views under shard_map keep a length-1 leading shard axis;
# bodies squeeze it on entry and expand on exit.
_squeeze = partial(jax.tree.map, lambda x: x[0] if x.ndim else x)
_expand = partial(jax.tree.map,
                  lambda x: x[None] if hasattr(x, "ndim") else x)


class CapacityTier(NamedTuple):
    """One rung of the density ladder: the three sparse-stratum budgets."""

    src: int    # active-source compaction slots
    edge: int   # edge-emission slots
    seg: int    # per-destination rehash segment slots


@dataclasses.dataclass(frozen=True)
class ShardedExecutor:
    """Runs a DeltaAlgorithm over a partitioned key space.

    snapshot      — partition snapshot routed against (paper §4.1).
    seg_capacity  — per-destination segment slots in the sparse rehash.
    edge_capacity — stratum edge-slot budget for sparse emission; strata
                    whose predicted |Δ| edges exceed it run densely.
    src_capacity  — active-source compaction budget (sparse emission).

    Density ladder: with ``ladder_tiers > 1`` (and an algorithm providing
    ``emit_factory``) the sparse stratum body is compiled at ``ladder_tiers``
    capacity rungs — powers of ``ladder_factor`` below the configured
    capacities — and each stratum dispatches to the SMALLEST rung whose
    budgets cover the exactly-predicted emission size from ``active_fn``.
    The paper's |Δᵢ|-shrinks-as-we-converge observation (§3.3, Fig. 2) then
    translates into per-stratum cost that tracks |Δᵢ| instead of the static
    worst-case capacity: tail strata sort/scatter arrays 4–64× smaller.
    The dense body stays the top rung of the same ladder (the sparse/dense
    duality becomes a multi-rung density ladder).

    Rehash strategy: each capacity rung's local rehash runs one of two
    physical implementations (Pregelix-style per-operator-instance strategy
    choice) — ``"sort"`` (the fused single-lexicographic-sort
    ``combine_route``) or ``"scatter"`` (the sort-free
    ``combine_route_scatter``: dense per-destination slab + prefix-sum
    compaction, O(C + slab) instead of O(C log C)).  ``"auto"`` applies a
    static cost model per rung at trace time: sort cost ~ C·log₂C, scatter
    cost ~ weight·(C + slab cells), so big rungs (C ≳ slab) go scatter and
    tiny tail rungs on huge key spaces keep the sort.  Strategies are
    bit-identical in keys/ann/count/overflow; float "add" payloads may
    reassociate by ≤1 ulp (identical in practice on XLA CPU).  Algorithms
    whose combiner is not composable always route with the sort path.

    ``use_pallas_route`` dispatches the per-shard local rehash to the
    Pallas kernels (``kernels/delta_route`` for sort-strategy routing,
    ``kernels/scatter_route`` for the scatter strategy) — interpret mode
    on CPU, compiled on TPU — instead of the jnp implementations.

    Observability: an attached ``tracer`` (``repro.obs.Tracer``) records a
    per-stratum probe from inside the compiled loop —
    ``jax.debug.callback`` survives ``lax.while_loop`` and ``shard_map``,
    so arrival-time deltas measure per-stratum (per-shard under
    shard_map) wall clock along with tier/route/emitted/rehash-bytes.
    ``tracer=None`` (the default) emits no callbacks at all: the traced
    computation is exactly the uninstrumented one, bit-identical.

    ``route_strategy="measured"`` swaps the "auto" static cost model for
    a measured per-rung dispatch table (``route_table``, built by
    ``repro.obs.calibrate`` from real sort/scatter timings on the current
    backend) — the per-backend calibration the static weight
    approximated.
    """

    snapshot: PartitionSnapshot
    seg_capacity: int
    edge_capacity: int
    src_capacity: int
    backend: str = "simulated"
    axis_name: str = "shards"
    mesh: Optional[object] = None
    ladder_tiers: int = 1          # 1 = ladder off (single sparse rung)
    ladder_factor: int = 4         # capacity ratio between adjacent rungs
    ladder_src_floor: int = 64     # smallest useful src budget
    ladder_edge_floor: int = 256   # smallest useful edge/seg budget
    route_strategy: str = "sort"   # "sort" | "scatter" | "auto" | "measured"
    route_scatter_weight: float = 0.4  # auto model: relative cost of one
    #                                scatter/slab element vs one sort
    #                                compare·log₂C unit.  Calibrated from
    #                                benchmarks/bench_rehash.py on XLA CPU
    #                                (crossover between C=1024 and C=4096
    #                                at 65536 slab cells).
    use_pallas_route: bool = False  # kernels instead of jnp local rehash
    tracer: Optional[object] = dataclasses.field(
        default=None, compare=False)   # repro.obs.Tracer (None = untraced)
    route_table: Optional[object] = dataclasses.field(
        default=None, compare=False)   # obs.calibrate.RouteCostTable for
    #                                    route_strategy="measured"

    # ------------------------------------------------------------------
    # Density ladder.
    # ------------------------------------------------------------------
    def capacity_tiers(self, algo: DeltaAlgorithm) -> list[CapacityTier]:
        """Ascending capacity rungs for ``algo`` (top = configured budgets).

        Collapses to a single rung when the ladder is off or the algorithm
        cannot re-emit at other capacities (no ``emit_factory``).
        """
        top = CapacityTier(self.src_capacity, self.edge_capacity,
                           self.seg_capacity)
        if self.ladder_tiers <= 1 or algo.emit_factory is None:
            return [top]
        tiers: list[CapacityTier] = []
        for i in range(self.ladder_tiers - 1, 0, -1):
            d = self.ladder_factor ** i
            t = CapacityTier(
                src=min(max(self.src_capacity // d, self.ladder_src_floor),
                        top.src),
                edge=min(max(self.edge_capacity // d, self.ladder_edge_floor),
                         top.edge),
                seg=min(max(self.seg_capacity // d, self.ladder_edge_floor),
                        top.seg))
            if t != top and (not tiers or t != tiers[-1]):
                tiers.append(t)
        tiers.append(top)
        return tiers

    def _emit_fn(self, algo: DeltaAlgorithm, tier: CapacityTier) -> Callable:
        if (algo.emit_factory is None
                or (tier.src, tier.edge) == (self.src_capacity,
                                             self.edge_capacity)):
            return algo.sparse_emit
        return algo.emit_factory(tier.src, tier.edge)

    # ------------------------------------------------------------------
    # Rehash strategy selection (per capacity rung, at trace time).
    # ------------------------------------------------------------------
    def pick_route_strategy(self, edge_capacity: int,
                            combiner: Optional[str]) -> str:
        """Physical combine-route implementation for a rung whose routed
        buffer holds ``edge_capacity`` slots.

        The scatter strategy merges deltas by construction (one slab cell
        per key), so a non-composable combiner forces the sort path.  In
        "auto" mode a static cost model compares sort work (C·log₂C) with
        scatter work (C scatter ops + one pass over the slab —
        ``padded_keys`` cells for the block scheme, ×num_shards for the
        hash scheme's per-owner rank counts).  ``route_scatter_weight``
        calibrates the per-element cost ratio (benchmarks/bench_rehash.py
        measures it; XLA CPU sorts are far costlier per element than
        scatters, hence the weight < 1).

        In "measured" mode the static model is bypassed entirely: the
        attached ``route_table`` (measured sort/scatter seconds per rung
        capacity on this backend, ``repro.obs.calibrate``) decides."""
        if self.route_strategy not in ("sort", "scatter", "auto",
                                       "measured"):
            raise ValueError(self.route_strategy)
        if combiner is None:
            return "sort"
        if self.route_strategy == "measured":
            if self.route_table is None:
                raise ValueError(
                    "route_strategy='measured' needs a route_table — "
                    "build one with repro.obs.calibrate."
                    "calibrate_executor_table(executor, algo) (eagerly, "
                    "before tracing) or RouteCostTable.from_bench_records")
            return self.route_table.pick(edge_capacity)
        if self.route_strategy != "auto":
            return self.route_strategy
        slab = self.snapshot.padded_keys
        if self.snapshot.scheme != "block":
            slab *= self.snapshot.num_shards
        c = max(edge_capacity, 2)
        sort_cost = c * math.log2(c)
        scatter_cost = self.route_scatter_weight * (c + slab)
        return "scatter" if scatter_cost < sort_cost else "sort"

    # ------------------------------------------------------------------
    # Sparse rehash (fused combine + route).
    # ------------------------------------------------------------------
    def _route_one(self, db: DeltaBuffer, seg_capacity: int,
                   combiner: Optional[str], strategy: str = "sort"
                   ) -> DeltaBuffer:
        """Local half of the rehash: one shard's outgoing Δ -> per-owner
        segments.  With a composable ``combiner`` this is the FUSED
        combine-route — ``strategy`` picks the physical implementation
        (one lexicographic sort on (owner, key) vs the sort-free
        scatter-slab); without a combiner it is plain stable routing."""
        S = self.snapshot.num_shards
        owners = self.snapshot.owner_of(db.keys)
        # Interpret-mode Pallas everywhere except a real TPU backend —
        # the "interpret on CPU, compiled on TPU" dispatch contract.
        interp = jax.default_backend() != "tpu"
        if strategy == "scatter" and combiner is not None:
            if self.use_pallas_route:
                from repro.kernels.scatter_route import scatter_route_deltas
                return scatter_route_deltas(db, owners, S, seg_capacity,
                                            combiner,
                                            snapshot=self.snapshot,
                                            interpret=interp)
            return deltamod.combine_route_scatter(
                db, owners, S, seg_capacity, combiner,
                snapshot=self.snapshot)
        if combiner is not None:
            if self.use_pallas_route:
                # Kernel path: §5.2 pre-aggregation (jnp) + the Pallas
                # routing kernel — property-tested equal to the fused
                # single-sort combine_route.
                from repro.core.handlers import pre_aggregate
                from repro.kernels.delta_route import route_deltas
                agg = pre_aggregate(db, combiner)
                agg_owners = self.snapshot.owner_of(agg.keys)
                return route_deltas(agg, agg_owners, S, seg_capacity,
                                    max_key=self.snapshot.padded_keys,
                                    interpret=interp)
            return deltamod.combine_route(db, owners, S, seg_capacity,
                                          combiner)
        if self.use_pallas_route:
            from repro.kernels.delta_route import route_deltas
            return route_deltas(db, owners, S, seg_capacity,
                                max_key=self.snapshot.padded_keys,
                                interpret=interp)
        return deltamod.route_by_owner(db, owners, S, seg_capacity)

    def rehash_sparse_simulated(self, stacked: DeltaBuffer,
                                seg_capacity: Optional[int] = None,
                                combiner: Optional[str] = None,
                                strategy: str = "sort"
                                ) -> tuple[DeltaBuffer, jax.Array]:
        """stacked: [S] leading axis of per-shard outgoing Δ -> (incoming Δ,
        globally-summed routed delta count)."""
        S = self.snapshot.num_shards
        cap = self.seg_capacity if seg_capacity is None else seg_capacity
        if self.use_pallas_route:
            # pallas_call inside vmap is fragile in interpret mode: route
            # each shard's buffer explicitly (S is small and static).
            parts = [self._route_one(
                jax.tree.map(lambda x, i=i: x[i], stacked), cap, combiner,
                strategy) for i in range(S)]
            routed = jax.tree.map(lambda *xs: jnp.stack(xs), *parts)
        else:
            routed = jax.vmap(
                lambda db: self._route_one(db, cap, combiner,
                                           strategy))(stacked)
        keys = routed.keys.reshape(S, S, cap)             # [src, dst, cap]
        payload = routed.payload.reshape(S, S, cap, -1)
        ann = routed.ann.reshape(S, S, cap)
        keys = jnp.swapaxes(keys, 0, 1)                   # [dst, src, cap]
        payload = jnp.swapaxes(payload, 0, 1)
        ann = jnp.swapaxes(ann, 0, 1)
        overflow = jnp.broadcast_to(jnp.any(routed.overflowed), (S,))

        def assemble(k, p, a, o):
            total = S * cap
            db = DeltaBuffer(keys=k.reshape(total),
                             payload=p.reshape(total, p.shape[-1]),
                             ann=a.reshape(total),
                             count=jnp.zeros((), jnp.int32), overflowed=o)
            return deltamod.recount(db)

        return jax.vmap(assemble)(keys, payload, ann, overflow), jnp.sum(
            routed.count)

    def rehash_sparse_shard_map(self, db: DeltaBuffer,
                                seg_capacity: Optional[int] = None,
                                combiner: Optional[str] = None,
                                strategy: str = "sort"
                                ) -> tuple[DeltaBuffer, jax.Array]:
        S = self.snapshot.num_shards
        cap = self.seg_capacity if seg_capacity is None else seg_capacity
        routed = self._route_one(db, cap, combiner, strategy)
        keys = jax.lax.all_to_all(routed.keys.reshape(S, cap),
                                  self.axis_name, 0, 0, tiled=False)
        payload = jax.lax.all_to_all(
            routed.payload.reshape(S, cap, routed.payload_width),
            self.axis_name, 0, 0, tiled=False)
        ann = jax.lax.all_to_all(routed.ann.reshape(S, cap),
                                 self.axis_name, 0, 0, tiled=False)
        overflow = jax.lax.psum(routed.overflowed.astype(jnp.int32),
                                self.axis_name) > 0
        total = S * cap
        out = DeltaBuffer(keys=keys.reshape(total),
                          payload=payload.reshape(total, routed.payload_width),
                          ann=ann.reshape(total),
                          count=jnp.zeros((), jnp.int32), overflowed=overflow)
        return deltamod.recount(out), jax.lax.psum(routed.count,
                                                   self.axis_name)

    # ------------------------------------------------------------------
    # Dense rehash: contribution vectors -> summed local blocks.
    # ------------------------------------------------------------------
    def rehash_dense_simulated(self, contrib: jax.Array, combiner: str
                               ) -> jax.Array:
        """contrib: [S_src, n_padded, W] -> incoming [S_dst, block, W]."""
        S, block = self.snapshot.num_shards, self.snapshot.block_size
        w = contrib.shape[-1]
        seg = contrib.reshape(S, S, block, w)             # [src, dst, b, w]
        return _dense_combine(jnp.swapaxes(seg, 0, 1), combiner, axis=1)

    def rehash_dense_shard_map(self, contrib: jax.Array, combiner: str
                               ) -> jax.Array:
        """contrib: [n_padded, W] (one shard's view) -> [block, W]."""
        S, block = self.snapshot.num_shards, self.snapshot.block_size
        w = contrib.shape[-1]
        seg = jax.lax.all_to_all(contrib.reshape(S, block, w),
                                 self.axis_name, 0, 0, tiled=False)
        return _dense_combine(seg, combiner, axis=0)

    # ------------------------------------------------------------------
    # Stratum assembly.
    # ------------------------------------------------------------------
    def run(self, algo: DeltaAlgorithm, state0, live0, immutable,
            max_iters: int, mode: str = "delta",
            explicit_cond: Optional[Callable] = None) -> FixpointResult:
        """state0 / immutable carry a leading [S] shard axis in BOTH
        backends (shard_map splits that axis across devices)."""
        if mode not in ("delta", "nodelta"):
            raise ValueError(mode)
        if self.tracer is not None:
            # Anchor shard timelines at dispatch so the first stratum's
            # measured duration excludes host setup (eager calls; under
            # an enclosing jit this runs once at trace time, which only
            # shifts the first measured stratum).
            self.tracer.mark_shards(self.snapshot.num_shards)
        if self.backend == "simulated":
            stratum_fn = self._stratum_simulated(algo, immutable, mode)
        elif self.backend == "shard_map":
            stratum_fn = self._stratum_shard_map(algo, mode)
        else:
            raise ValueError(self.backend)
        if explicit_cond is not None:
            stratum_fn = with_explicit_condition(stratum_fn, explicit_cond)
        if self.backend == "shard_map":
            return self._run_shard_map_loop(stratum_fn, state0, live0,
                                            immutable, max_iters)
        return run_strata(stratum_fn, state0, jnp.asarray(live0, jnp.int32),
                          max_iters, tracer=self.tracer)

    # ------------------------------------------------------------------
    # Resume-from-state (incremental view maintenance).
    # ------------------------------------------------------------------
    def live_count(self, algo: DeltaAlgorithm, state, immutable) -> jax.Array:
        """Globally-reduced |Δ₀| of ``state``: how many keys would refine if
        the fixpoint were (re)entered right now.  This is the seed live
        count for :meth:`resume`."""
        active, _ = jax.vmap(algo.active_fn)(state, immutable)
        return jnp.sum(active.astype(jnp.int32))

    def resume(self, algo: DeltaAlgorithm, warm_state, immutable,
               max_iters: int, mode: str = "delta",
               explicit_cond: Optional[Callable] = None) -> FixpointResult:
        """Re-enter the fixpoint from a previously-converged (then repaired)
        state instead of the base case.

        This is the engine half of incremental view maintenance
        (repro.incremental): a base-data mutation is translated into seed
        deltas by editing ``warm_state`` so that the affected keys fail the
        algorithm's convergence test; the fixpoint then propagates only the
        repair.  Δ₀ is derived from ``active_fn`` — no caller-supplied live
        count, so an unchanged state returns immediately with zero strata.

        With the density ladder enabled the per-stratum dispatch doubles as
        warm-start tier selection: a small repair's first stratum (and every
        tail stratum after it) lands on a tiny capacity rung, so incremental
        views pay O(|repair|)-scaled sort/scatter cost instead of the full
        configured capacity.
        """
        live0 = self.live_count(algo, warm_state, immutable)
        return self.run(algo, warm_state, live0, immutable, max_iters,
                        mode=mode, explicit_cond=explicit_cond)

    def make_stratum_fn(self, algo: DeltaAlgorithm, immutable,
                        mode: str = "delta",
                        explicit_cond: Optional[Callable] = None):
        """One-stratum function (state, idx) -> (state', outcome) for the
        stratum-sliced drivers (runtime/recovery.py) — identical semantics
        to the fused while_loop, on BOTH backends: the simulated stratum
        body directly, or one shard_map dispatch per stratum (same specs
        as the fused loop, so a stratum-sliced run is bit-identical to
        ``run`` stratum for stratum).

        The simulated body is deliberately NOT wrapped in ``jax.jit``:
        ``run`` executes its while_loop eagerly, and whole-stratum jit
        changes float fusion (fma/reassociation) by ~1 ulp in
        add-combining algorithms — the eager stratum body is what
        reproduces ``run`` bit-for-bit, which recovery correctness tests
        rely on.  The shard_map path IS jitted: its body is a single
        compiled computation either way (bit-identical to the fused
        shard_map loop, verified both ways), and eager shard_map
        re-traces every call."""
        if self.backend == "simulated":
            fn = self._stratum_simulated(algo, immutable, mode)
            if explicit_cond is not None:
                fn = with_explicit_condition(fn, explicit_cond)
            return fn
        if self.backend != "shard_map":
            raise ValueError(self.backend)
        stratum = self._stratum_shard_map(algo, mode)
        if explicit_cond is not None:
            stratum = with_explicit_condition(stratum, explicit_cond)
        spec = P(self.axis_name)

        def one(state, imm, idx):
            (new_state, _), outcome = stratum(
                (_squeeze(state), _squeeze(imm)), idx)
            return _expand(new_state), outcome

        # immutable stays a runtime argument (as in ``run``) — closing
        # the jit over it would bake the full sharded graph into the
        # traced computation as constants.
        fn = jax.jit(_shard_map_compat(one, self.mesh,
                                       in_specs=(spec, spec, P()),
                                       out_specs=(spec, P())))
        return lambda state, idx: fn(state, immutable, idx)

    # ------------------------------------------------------------------
    # Fault-tolerant elastic execution (runtime/recovery.py driver).
    # ------------------------------------------------------------------
    def run_resilient(self, algo: DeltaAlgorithm, state0, live0, immutable,
                      max_iters: int, mode: str = "delta",
                      explicit_cond: Optional[Callable] = None, *,
                      ckpt_root: str, fault_plan=None, policy=None,
                      latency_model=None, remake=None, metrics=None,
                      retry=None, budget=None, tracer=None):
        """``run`` with fault tolerance and elasticity: stratum-sliced
        execution that maintains a per-stratum replica chain of
        changed-entry deltas (paper §4.1), rebuilds a failed shard from
        replicas and resumes warm, migrates state + in-flight route
        buffers to a fresh partition snapshot on rescale, and
        speculatively re-issues straggling shards against their replica.

        A failure-free resilient run is bit-identical to :meth:`run`.
        Returns a ``runtime.recovery.ResilientResult`` whose ``result``
        matches ``run``'s FixpointResult; ``metrics`` carries the Fig 12
        work/byte accounting and all recovery events.  See
        :class:`repro.runtime.recovery.ResilientDriver` for the knobs.

        ``ckpt_root`` must be a dedicated directory: the replica chain
        owns it and DELETES any existing contents at query start.
        """
        from repro.runtime.recovery import ResilientDriver
        driver = ResilientDriver(
            self, algo, state0, live0, immutable, max_iters, mode=mode,
            explicit_cond=explicit_cond, ckpt_root=ckpt_root,
            fault_plan=fault_plan, policy=policy,
            latency_model=latency_model, remake=remake, metrics=metrics,
            retry=retry, budget=budget, tracer=tracer)
        return driver.run()

    def resume_resilient(self, algo: DeltaAlgorithm, warm_state, immutable,
                         max_iters: int, mode: str = "delta",
                         explicit_cond: Optional[Callable] = None,
                         **resilient_kw):
        """:meth:`resume` (warm re-entry, Δ₀ from ``active_fn``) through
        the fault-tolerant driver — incremental views use this so standing
        queries survive executor failures mid-repair."""
        live0 = self.live_count(algo, warm_state, immutable)
        return self.run_resilient(algo, warm_state, live0, immutable,
                                  max_iters, mode=mode,
                                  explicit_cond=explicit_cond,
                                  **resilient_kw)

    # ---- simulated backend ------------------------------------------------
    def _stratum_simulated(self, algo: DeltaAlgorithm, immutable, mode):
        S = self.snapshot.num_shards
        shard_ids = jnp.arange(S, dtype=jnp.int32)
        tiers = self.capacity_tiers(algo)
        # Sender-side combiner (§5.2) is fused into the route: merging
        # deltas sharing a key BEFORE the rehash shrinks collective bytes
        # exactly as the paper's pre-aggregation pushdown prescribes, and
        # the fused single-sort pass halves the per-stratum sort work.
        combiner = (algo.combiner
                    if algo.combiner in ("add", "min", "max") else None)

        def make_sparse_body(tier: CapacityTier, tier_idx: int):
            emit_fn = self._emit_fn(algo, tier)
            # Physical rehash strategy is a per-rung trace-time constant:
            # the Pregelix-style choice between sort- and scatter-based
            # grouping, made from the rung's static capacities.
            strategy = self.pick_route_strategy(tier.edge, combiner)
            route_code = ROUTE_SCATTER if strategy == "scatter" \
                else ROUTE_SORT

            def sparse_body(state, stratum, active):
                partial_state, outgoing = jax.vmap(
                    emit_fn, in_axes=(0, 0, 0, None, 0))(
                    state, immutable, active, stratum, shard_ids)
                incoming, emitted = self.rehash_sparse_simulated(
                    outgoing, seg_capacity=tier.seg, combiner=combiner,
                    strategy=strategy)
                new_state, next_active = jax.vmap(
                    algo.apply_sparse, in_axes=(0, 0, 0, None, 0))(
                    partial_state, incoming, immutable, stratum, shard_ids)
                bytes_moved = emitted.astype(
                    jnp.float32) * algo.bytes_per_delta
                return new_state, StratumOutcome(
                    live_count=jnp.sum(next_active),
                    used_dense=jnp.asarray(False),
                    rehash_bytes=bytes_moved, emitted=emitted,
                    tier=jnp.asarray(tier_idx, jnp.int32),
                    route=jnp.asarray(route_code, jnp.int32))

            return sparse_body

        def dense_body(state, stratum, active):
            partial_state, contrib = jax.vmap(
                algo.dense_emit, in_axes=(0, 0, None, 0))(
                state, immutable, stratum, shard_ids)
            incoming = self.rehash_dense_simulated(contrib, algo.combiner)
            new_state, next_active = jax.vmap(
                algo.apply_dense, in_axes=(0, 0, 0, None, 0))(
                partial_state, incoming, immutable, stratum, shard_ids)
            n_padded = contrib.shape[1]
            bytes_moved = jnp.asarray(
                S * n_padded * algo.payload_width * 4, jnp.float32)
            return new_state, StratumOutcome(
                live_count=jnp.sum(next_active),
                used_dense=jnp.asarray(True),
                rehash_bytes=bytes_moved,
                emitted=jnp.sum(jax.vmap(lambda a: jnp.sum(
                    a.astype(jnp.int32)))(active)),
                tier=jnp.asarray(-1, jnp.int32),
                route=jnp.asarray(-1, jnp.int32))

        bodies = [make_sparse_body(t, i) for i, t in enumerate(tiers)]
        bodies.append(dense_body)

        def stratum(state, stratum_idx):
            active, est_edges = jax.vmap(algo.active_fn)(state, immutable)
            per_shard_src = jax.vmap(
                lambda a: jnp.sum(a.astype(jnp.int32)))(active)
            if mode == "nodelta":
                new_state, outcome = dense_body(state, stratum_idx, active)
            else:
                # Smallest rung whose budgets cover the exact predicted
                # sizes; tiers ascend, so "fits" is monotone and the rung
                # index is len(tiers) − (#rungs that fit).  No rung fits
                # -> dense body.  The seg budget is guarded too: one
                # shard's emission can land entirely in one destination
                # segment, so a rung with seg < edge must also cover the
                # edge count or deltas would be silently dropped by the
                # route.
                max_src = jnp.max(per_shard_src)
                max_edges = jnp.max(est_edges)
                fits = jnp.stack([(max_src <= t.src)
                                  & (max_edges <= min(t.edge, t.seg))
                                  for t in tiers])
                branch = len(tiers) - jnp.sum(fits.astype(jnp.int32))
                new_state, outcome = jax.lax.switch(
                    branch, bodies, state, stratum_idx, active)
            if self.tracer is not None:
                # One probe per stratum (all shards share the device);
                # ordered keeps arrival deltas = stratum wall clock even
                # inside the while_loop.
                self.tracer.stratum_probe(stratum_idx, outcome,
                                          ordered=True)
            return new_state, outcome

        return stratum

    # ---- shard_map backend --------------------------------------------
    def _stratum_shard_map(self, algo: DeltaAlgorithm, mode):
        axis = self.axis_name
        S = self.snapshot.num_shards
        tiers = self.capacity_tiers(algo)
        combiner = (algo.combiner
                    if algo.combiner in ("add", "min", "max") else None)

        def stratum(carry, stratum_idx):
            state, imm = carry
            shard_id = jax.lax.axis_index(axis)
            active, est_edges = algo.active_fn(state, imm)
            n_src = jnp.sum(active.astype(jnp.int32))

            def make_sparse_body(tier: CapacityTier, tier_idx: int):
                emit_fn = self._emit_fn(algo, tier)
                # Trace-time constant, identical on every shard (pure
                # function of static rung capacities).
                strategy = self.pick_route_strategy(tier.edge, combiner)
                route_code = ROUTE_SCATTER if strategy == "scatter" \
                    else ROUTE_SORT

                def sparse_body(st):
                    partial_state, outgoing = emit_fn(
                        st, imm, active, stratum_idx, shard_id)
                    incoming, emitted = self.rehash_sparse_shard_map(
                        outgoing, seg_capacity=tier.seg, combiner=combiner,
                        strategy=strategy)
                    new_state, next_active = algo.apply_sparse(
                        partial_state, incoming, imm, stratum_idx, shard_id)
                    return (new_state, imm), StratumOutcome(
                        live_count=jax.lax.psum(next_active, axis),
                        used_dense=jnp.asarray(False),
                        rehash_bytes=emitted.astype(jnp.float32)
                        * algo.bytes_per_delta,
                        emitted=emitted,
                        tier=jnp.asarray(tier_idx, jnp.int32),
                        route=jnp.asarray(route_code, jnp.int32))

                return sparse_body

            def dense_body(st):
                partial_state, contrib = algo.dense_emit(
                    st, imm, stratum_idx, shard_id)
                incoming = self.rehash_dense_shard_map(contrib, algo.combiner)
                new_state, next_active = algo.apply_dense(
                    partial_state, incoming, imm, stratum_idx, shard_id)
                n_padded = contrib.shape[0]
                return (new_state, imm), StratumOutcome(
                    live_count=jax.lax.psum(next_active, axis),
                    used_dense=jnp.asarray(True),
                    rehash_bytes=jnp.asarray(
                        S * n_padded * algo.payload_width * 4, jnp.float32),
                    emitted=jax.lax.psum(n_src, axis),
                    tier=jnp.asarray(-1, jnp.int32),
                    route=jnp.asarray(-1, jnp.int32))

            if mode == "nodelta":
                carry_out, outcome = dense_body(state)
            else:
                # Globally-reduced predicted sizes -> every shard picks
                # the same rung (the dispatch feeds a collective-bearing
                # branch).  The seg budget is guarded like the simulated
                # backend.
                max_src = jax.lax.pmax(n_src, axis)
                max_edges = jax.lax.pmax(est_edges, axis)
                fits = jnp.stack([(max_src <= t.src)
                                  & (max_edges <= min(t.edge, t.seg))
                                  for t in tiers])
                branch = len(tiers) - jnp.sum(fits.astype(jnp.int32))
                bodies = [make_sparse_body(t, i)
                          for i, t in enumerate(tiers)]
                bodies.append(dense_body)
                carry_out, outcome = jax.lax.switch(branch, bodies, state)
            if self.tracer is not None:
                # Per-shard probe: each device calls back with its own
                # shard id, so arrival times are per-shard stratum
                # latencies.  Unordered — ordered effects cannot cross
                # the shard_map collectives.
                self.tracer.stratum_probe(stratum_idx, outcome,
                                          shard_id=shard_id, ordered=False)
            return carry_out, outcome

        return stratum

    def _run_shard_map_loop(self, stratum_fn, state0, live0, immutable,
                            max_iters):
        def body(state, imm):
            state, imm = _squeeze(state), _squeeze(imm)
            res = run_strata(stratum_fn, (state, imm),
                             jnp.asarray(live0, jnp.int32), max_iters)
            final_state, _ = res.state
            return FixpointResult(state=_expand(final_state),
                                  stats=res.stats)

        spec = P(self.axis_name)
        fn = _shard_map_compat(body, self.mesh, in_specs=(spec, spec),
                               out_specs=FixpointResult(state=spec,
                                                        stats=P()))
        res = fn(state0, immutable)
        if self.tracer is not None:
            # Fixpoint marker outside the shard_map (replicated stats —
            # one probe, not one per shard).
            self.tracer.fixpoint_probe(res.stats.iterations, max_iters)
        return res
