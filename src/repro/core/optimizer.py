"""Cost-based optimizer (paper §5).

Implements the three optimizer contributions on the plan IR:

  1. **UDF/join interleaving by rank** (§5.1, after Hellerstein &
     Stonebraker's predicate migration): expensive predicates over the same
     relation are applied in increasing rank = cost_per_tuple / (1 −
     selectivity); interleavings with joins are enumerated branch-and-bound
     under the resource-vector overlap model.
  2. **UDA pre-aggregation pushdown** (§5.2): a composable UDA's combiner is
     pushed below rehash and joins (below any join if composable; only below
     key–FK joins otherwise), at most one pre-aggregation per UDA, maximally
     pushed.  Multiplicative joins are compensated with the ``multiply``
     UDF by inserting the opposite side's count(*).
  3. **Recursive cost estimation** (§5.3): simulate iterations, feeding each
     stratum's estimated output into the next, capping cardinality and cost
     to be monotonically non-increasing (convergence assumption + fixpoint
     dedup), until estimated output reaches zero or max_iters.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Sequence, Tuple

from repro.core.plan import (PlanNode, plan_runtime, preagg, rehash,
                             sequential_combine, total_resource, runtime_of)


# ---------------------------------------------------------------------------
# §5.1 — rank ordering of expensive UDFs.
# ---------------------------------------------------------------------------

def order_udfs_by_rank(udfs: Sequence[PlanNode]) -> List[PlanNode]:
    """Optimal application order of independent expensive predicates over one
    relation: increasing rank (cheap or highly selective first)."""
    return sorted(udfs, key=lambda u: u.rank())


def apply_udf_chain(base: PlanNode, udfs: Sequence[PlanNode]) -> PlanNode:
    """Rebuild a select/UDF chain over ``base`` with recomputed stats."""
    node = base
    for u in udfs:
        card_in = node.out_cardinality
        cpu = card_in * u.cost_per_tuple * (0.8 if u.deterministic else 1.0)
        node = u.clone(children=(node,),
                       out_cardinality=card_in * u.selectivity,
                       resource=(cpu, 0.0, 0.0))
    return node


def best_udf_join_interleaving(base: PlanNode, udfs: Sequence[PlanNode],
                               join_builder, join_positions: int
                               ) -> Tuple[PlanNode, float]:
    """Enumerate where the join sits within the rank-ordered UDF chain.

    The rank ordering fixes the relative order of the UDFs (provably optimal
    for same-relation predicates); the remaining freedom — which prefix runs
    before the join — is linear, so we scan all split points with
    branch-and-bound on the overlap-model runtime.

    join_builder(node) -> PlanNode wrapping ``node`` in the join.
    """
    ordered = order_udfs_by_rank(udfs)
    best_plan, best_cost = None, float("inf")
    for split in range(len(ordered) + 1):
        pre, post = ordered[:split], ordered[split:]
        node = apply_udf_chain(base, pre)
        node = join_builder(node)
        node = apply_udf_chain(node, post)
        cost = plan_runtime(node)
        if cost < best_cost - 1e-15:
            best_plan, best_cost = node, cost
        elif cost > best_cost * 4:  # bound: later splits only defer more work
            pass
    return best_plan, best_cost


# ---------------------------------------------------------------------------
# §5.2 — pre-aggregation pushdown.
# ---------------------------------------------------------------------------

def push_preaggregation(node: PlanNode, reduction: float = 0.1) -> PlanNode:
    """Push one combiner per UDA maximally below rehash / eligible joins.

    Rules (paper §5.2):
      * composable UDA           → may cross any join and any rehash;
      * non-composable UDA       → may cross a key–FK join only;
      * non-composable, non-FK   → no pushdown;
      * at most ONE pre-aggregation per UDA, maximally pushed;
      * crossing a non-FK join with a cardinality-dependent UDA requires a
        ``multiply`` compensation (caller sets has_multiply).
    """
    if node.op != "groupby":
        return dataclasses.replace(
            node, children=tuple(push_preaggregation(c, reduction)
                                 for c in node.children))

    child = node.children[0]
    # Descend while crossing is legal, tracking the deepest legal spot.
    path: List[PlanNode] = []
    cur = child
    while True:
        if cur.op == "rehash":
            path.append(cur)
            cur = cur.children[0]
            continue
        if cur.op == "join":
            legal = node.composable or cur.key_fk_join
            needs_mult = (not cur.key_fk_join) and node.composable
            if legal and (not needs_mult or node.has_multiply):
                path.append(cur)
                cur = cur.children[0]   # push down the probe (left) side
                continue
        break
    if not path:
        return node  # nothing to cross — pre-agg would be a no-op locally

    combined = preagg(cur, node.uda_name or "sum", reduction)
    # Rebuild the crossed spine above the combiner.
    rebuilt = combined
    for spine in reversed(path):
        new_children = (rebuilt,) + tuple(spine.children[1:])
        card = rebuilt.out_cardinality
        if spine.op == "rehash":
            res = (0.0, 0.0, card * 2e-8)
            rebuilt = spine.clone(children=new_children, out_cardinality=card,
                                  resource=res)
        else:  # join
            if spine.key_fk_join:
                card_out = card * spine.selectivity
            else:
                right = spine.children[1].out_cardinality
                card_out = card * max(right, 1.0) * spine.selectivity
            cpu = (card + spine.children[1].out_cardinality) * 5e-9
            rebuilt = spine.clone(children=new_children,
                                  out_cardinality=card_out,
                                  resource=(cpu, 0.0, 0.0))
    return dataclasses.replace(node, children=(rebuilt,))


# ---------------------------------------------------------------------------
# §5.3 — recursive cost estimation.
# ---------------------------------------------------------------------------

def estimate_recursive_cost(base_cost: float, base_card: float,
                            step_cost_fn, step_card_fn,
                            max_iters: int = 64) -> Tuple[float, float, int]:
    """Simulated-iteration estimator with the paper's monotone caps.

    step_cost_fn(card_in) -> cost of one recursive stratum
    step_card_fn(card_in) -> estimated Δ cardinality emitted by the stratum

    Divergence guard: per-step cost and cardinality are capped at the
    previous step's values (convergence focus + fixpoint dedup), so a bad
    hint (e.g. ×2 growth) cannot explode the estimate.
    Returns (total_cost, final_cardinality, iterations_estimated).
    """
    total = base_cost
    card = base_card
    prev_cost = float("inf")
    iters = 0
    for i in range(max_iters):
        if card < 1.0:
            break
        cost = step_cost_fn(card)
        new_card = step_card_fn(card)
        # Monotone caps (paper §5.3).
        cost = min(cost, prev_cost)
        new_card = min(new_card, card)
        total += cost
        prev_cost = cost
        card = new_card
        iters += 1
    return total, card, iters


# ---------------------------------------------------------------------------
# Whole-plan entry point.
# ---------------------------------------------------------------------------

def optimize(node: PlanNode, preagg_reduction: float = 0.1) -> PlanNode:
    """Top-down rewrite pass: currently pre-aggregation pushdown everywhere
    (UDF interleaving is applied at plan construction via
    :func:`best_udf_join_interleaving`, which needs the join builder)."""
    return push_preaggregation(node, reduction=preagg_reduction)


def worst_case_node_cost(per_node_costs: Sequence[float]) -> float:
    """Many-node estimation (paper §5): the stratum completes when the
    slowest shard finishes — the engine models completion as the max."""
    return max(per_node_costs)
