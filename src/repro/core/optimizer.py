"""Cost-based optimizer (paper §5) — IR-to-IR rewrites over core/plan.py.

Implements the three optimizer contributions as rewrite passes the frontend
compiler (repro.frontend) runs over every plan:

  1. **UDF/join interleaving by rank** (§5.1, after Hellerstein &
     Stonebraker's predicate migration): expensive predicates over the same
     relation are applied in increasing rank = cost_per_tuple / (1 −
     selectivity); interleavings with joins are enumerated branch-and-bound
     under the resource-vector overlap model.  :func:`interleave_udf_joins`
     applies this as a tree rewrite wherever a chain of *independent*
     (non-``pinned``) UDFs surrounds a join.
  2. **UDA pre-aggregation pushdown** (§5.2): a composable UDA's combiner is
     pushed below rehash and joins (below any join if composable; only below
     key–FK joins otherwise), at most one pre-aggregation per UDA, maximally
     pushed.  Multiplicative joins are compensated with the ``multiply``
     UDF by inserting the opposite side's count(*).
  3. **Recursive cost estimation** (§5.3 + §6): fixpoint nodes re-run their
     simulated-iteration estimate after the child subplans were rewritten,
     taking the delta-retraction decay path for idempotent combiners.

Per-tuple cost constants live in :class:`CostModel`.  The defaults are the
hand-calibrated static values; :meth:`CostModel.from_route_table` derives
the routed-tuple cost from a *measured* ``obs/calibrate.py:RouteCostTable``
instead, so plan costing and the executor's rung dispatch
(``route_strategy="measured"``) share one calibration source.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core import plan as _plan
from repro.core.plan import (PlanNode, plan_runtime, preagg, rehash,
                             sequential_combine, total_resource, runtime_of)


# ---------------------------------------------------------------------------
# Cost model: static constants or measured calibration.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CostModel:
    """Per-tuple cost constants consulted by the rewrite passes (and by the
    frontend planner when building nodes)."""

    rehash_net_per_tuple: float = 2e-8
    join_cpu_per_tuple: float = 5e-9
    agg_cpu_per_tuple: float = 4e-9
    scan_disk_per_tuple: float = 1e-8
    source: str = "static"

    @classmethod
    def from_route_table(cls, table, **overrides) -> "CostModel":
        """Derive the routed-tuple network cost from a measured
        :class:`repro.obs.calibrate.RouteCostTable` (median over its rungs
        of the cheaper strategy's per-tuple cost); everything not measured
        keeps the static default."""
        kw = dict(rehash_net_per_tuple=table.median_per_tuple(),
                  source=f"measured:{table.backend}")
        kw.update(overrides)
        return cls(**kw)


DEFAULT_COST_MODEL = CostModel()


# ---------------------------------------------------------------------------
# §5.1 — rank ordering of expensive UDFs.
# ---------------------------------------------------------------------------

def order_udfs_by_rank(udfs: Sequence[PlanNode]) -> List[PlanNode]:
    """Optimal application order of independent expensive predicates over one
    relation: increasing rank (cheap or highly selective first)."""
    return sorted(udfs, key=lambda u: u.rank())


def apply_udf_chain(base: PlanNode, udfs: Sequence[PlanNode]) -> PlanNode:
    """Rebuild a select/UDF chain over ``base`` with recomputed stats."""
    node = base
    for u in udfs:
        card_in = node.out_cardinality
        cpu = card_in * u.cost_per_tuple * (0.8 if u.deterministic else 1.0)
        node = u.clone(children=(node,),
                       out_cardinality=card_in * u.selectivity,
                       resource=(cpu, 0.0, 0.0))
    return node


def best_udf_join_interleaving(base: PlanNode, udfs: Sequence[PlanNode],
                               join_builder, join_positions: int
                               ) -> Tuple[PlanNode, float]:
    """Enumerate where the join sits within the rank-ordered UDF chain.

    The rank ordering fixes the relative order of the UDFs (provably optimal
    for same-relation predicates); the remaining freedom — which prefix runs
    before the join — is linear, so we scan all split points with
    branch-and-bound on the overlap-model runtime.

    join_builder(node) -> PlanNode wrapping ``node`` in the join.
    """
    ordered = order_udfs_by_rank(udfs)
    best_plan, best_cost = None, float("inf")
    for split in range(len(ordered) + 1):
        pre, post = ordered[:split], ordered[split:]
        node = apply_udf_chain(base, pre)
        node = join_builder(node)
        node = apply_udf_chain(node, post)
        cost = plan_runtime(node)
        if cost < best_cost - 1e-15:
            best_plan, best_cost = node, cost
        elif cost > best_cost * 4:  # bound: later splits only defer more work
            pass
    return best_plan, best_cost


def interleave_udf_joins(node: PlanNode,
                         cost_model: Optional[CostModel] = None) -> PlanNode:
    """IR rewrite (§5.1): wherever a chain of independent UDFs sits around a
    join — some directly above it, some on its probe (left) input — re-split
    the rank-ordered chain across the join at the cheapest point.

    ``pinned`` UDFs (frontend-semantic nodes like the recursive value view
    or the rule term, whose outputs feed each other) are never reordered;
    a pinned node terminates the chain walk on both sides.
    """
    cm = cost_model or DEFAULT_COST_MODEL
    new_children = tuple(interleave_udf_joins(c, cm) for c in node.children)
    if new_children != tuple(node.children):
        node = node.clone(children=new_children)

    above: List[PlanNode] = []
    cur = node
    while (cur.op == "udf" and not cur.pinned and len(cur.children) == 1):
        above.append(cur)
        cur = cur.children[0]
    if cur.op != "join":
        return node
    join_node = cur
    below: List[PlanNode] = []
    lc = join_node.children[0]
    while lc.op == "udf" and not lc.pinned and len(lc.children) == 1:
        below.append(lc)
        lc = lc.children[0]
    udfs = above + below
    if not udfs:
        return node
    base, right = lc, join_node.children[1]

    def join_builder(n: PlanNode) -> PlanNode:
        card_left = n.out_cardinality
        if join_node.key_fk_join:
            card = card_left * join_node.selectivity
        else:
            card = (card_left * max(right.out_cardinality, 1.0)
                    * join_node.selectivity)
        cpu = (card_left + right.out_cardinality) * cm.join_cpu_per_tuple
        return join_node.clone(children=(n, right), out_cardinality=card,
                               resource=(cpu, 0.0, 0.0))

    best, cost = best_udf_join_interleaving(base, udfs, join_builder, 1)
    # Strictly-better guard keeps the pass idempotent (re-running on an
    # already-optimal chain is a no-op, not a cosmetic reshuffle).
    if best is not None and cost < plan_runtime(node) - 1e-15:
        return best
    return node


# ---------------------------------------------------------------------------
# §5.2 — pre-aggregation pushdown.
# ---------------------------------------------------------------------------

def push_preaggregation(node: PlanNode, reduction: float = 0.1,
                        cost_model: Optional[CostModel] = None) -> PlanNode:
    """Push one combiner per UDA maximally below rehash / eligible joins.

    Rules (paper §5.2):
      * composable UDA           → may cross any join and any rehash;
      * non-composable UDA       → may cross a key–FK join only;
      * non-composable, non-FK   → no pushdown;
      * at most ONE pre-aggregation per UDA, maximally pushed;
      * crossing a non-FK join with a cardinality-dependent UDA requires a
        ``multiply`` compensation (caller sets has_multiply).
    """
    cm = cost_model or DEFAULT_COST_MODEL
    if node.op != "groupby":
        return node.clone(children=tuple(
            push_preaggregation(c, reduction, cm) for c in node.children))

    child = node.children[0]
    # Descend while crossing is legal, tracking the deepest legal spot.
    path: List[PlanNode] = []
    cur = child
    while True:
        if cur.op == "preagg":
            # Already pushed (at most one pre-aggregation per UDA): the
            # rewrite is idempotent.
            return node
        if cur.op == "rehash":
            path.append(cur)
            cur = cur.children[0]
            continue
        if cur.op == "join":
            legal = node.composable or cur.key_fk_join
            needs_mult = (not cur.key_fk_join) and node.composable
            if legal and (not needs_mult or node.has_multiply):
                path.append(cur)
                cur = cur.children[0]   # push down the probe (left) side
                continue
        break
    if not path:
        return node  # nothing to cross — pre-agg would be a no-op locally

    combined = preagg(cur, node.uda_name or "sum", reduction,
                      cpu_per_tuple=cm.agg_cpu_per_tuple,
                      combiner=node.combiner)
    # Rebuild the crossed spine above the combiner.
    rebuilt = combined
    for spine in reversed(path):
        new_children = (rebuilt,) + tuple(spine.children[1:])
        card = rebuilt.out_cardinality
        if spine.op == "rehash":
            res = (0.0, 0.0, card * cm.rehash_net_per_tuple)
            rebuilt = spine.clone(children=new_children, out_cardinality=card,
                                  resource=res)
        else:  # join
            if spine.key_fk_join:
                card_out = card * spine.selectivity
            else:
                right = spine.children[1].out_cardinality
                card_out = card * max(right, 1.0) * spine.selectivity
            cpu = (card + spine.children[1].out_cardinality) \
                * cm.join_cpu_per_tuple
            rebuilt = spine.clone(children=new_children,
                                  out_cardinality=card_out,
                                  resource=(cpu, 0.0, 0.0))
    return node.clone(children=(rebuilt,))


# ---------------------------------------------------------------------------
# §5.3 — recursive cost estimation.
# ---------------------------------------------------------------------------

def estimate_recursive_cost(base_cost: float, base_card: float,
                            step_cost_fn, step_card_fn,
                            max_iters: int = 64) -> Tuple[float, float, int]:
    """Simulated-iteration estimator with the paper's monotone caps.

    step_cost_fn(card_in) -> cost of one recursive stratum
    step_card_fn(card_in) -> estimated Δ cardinality emitted by the stratum

    Divergence guard: per-step cost and cardinality are capped at the
    previous step's values (convergence focus + fixpoint dedup), so a bad
    hint (e.g. ×2 growth) cannot explode the estimate.
    Returns (total_cost, final_cardinality, iterations_estimated).
    """
    total = base_cost
    card = base_card
    prev_cost = float("inf")
    iters = 0
    for i in range(max_iters):
        if card < 1.0:
            break
        cost = step_cost_fn(card)
        new_card = step_card_fn(card)
        # Monotone caps (paper §5.3).
        cost = min(cost, prev_cost)
        new_card = min(new_card, card)
        total += cost
        prev_cost = cost
        card = new_card
        iters += 1
    return total, card, iters


def refresh_fixpoint_estimates(node: PlanNode) -> PlanNode:
    """Re-run every fixpoint node's simulated-iteration estimate bottom-up,
    so rewrites below it (pre-agg pushdown, interleaving) are reflected in
    its per-stratum cost — and the idempotent delta-retraction decay
    (paper §6) is applied from the fixpoint's combiner annotation."""
    new_children = tuple(refresh_fixpoint_estimates(c)
                         for c in node.children)
    if node.op == "fixpoint":
        return _plan.fixpoint(new_children[0], new_children[1],
                              max_iters=node.max_iters or 64,
                              combiner=node.combiner)
    if new_children != tuple(node.children):
        return node.clone(children=new_children)
    return node


# ---------------------------------------------------------------------------
# Whole-plan entry point.
# ---------------------------------------------------------------------------

def optimize(node: PlanNode, preagg_reduction: float = 0.1,
             cost_model: Optional[CostModel] = None) -> PlanNode:
    """The compilation rewrite pipeline: UDF/join interleaving by rank,
    pre-aggregation pushdown, fixpoint cost refresh.  Idempotent:
    ``optimize(optimize(p)) == optimize(p)``."""
    cm = cost_model or DEFAULT_COST_MODEL
    out = interleave_udf_joins(node, cm)
    out = push_preaggregation(out, reduction=preagg_reduction, cost_model=cm)
    out = refresh_fixpoint_estimates(out)
    return out


def worst_case_node_cost(per_node_costs: Sequence[float]) -> float:
    """Many-node estimation (paper §5): the stratum completes when the
    slowest shard finishes — the engine models completion as the max."""
    return max(per_node_costs)
