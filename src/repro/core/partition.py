"""Partition snapshots (paper §4.1).

REX distributes every query together with a *snapshot* of the key-space
partitioning as seen by the requestor; all data is routed according to that
snapshot for the lifetime of the query, so routing stays consistent even as
the cluster changes.  Recovery and elastic re-scaling produce a *new*
snapshot and migrate state accordingly (runtime/elastic.py).

On TPU the "nodes" are devices in the flattened mesh.  Keys are integers in
[0, n_keys).  We support two schemes:

  * ``block``  — contiguous ranges (key // block_size), the natural layout
    for dense keyed state sharded along its leading axis; this is what the
    distributed engine uses, because a block partition makes the dense state
    of shard s exactly ``state[s*block : (s+1)*block]``.
  * ``hash``   — multiplicative hash mod shards (the paper's consistent
    hashing analogue) for skew resistance when keys are adversarial.

Replicas: shard s's state is replicated on shards (s+1..s+R-1) mod S — the
paper's replication chain used by incremental recovery (§4.3).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_HASH_MULT = jnp.uint32(2654435761)  # Knuth multiplicative hash


@dataclasses.dataclass(frozen=True)
class PartitionSnapshot:
    n_keys: int
    num_shards: int
    scheme: str = "block"           # "block" | "hash"
    replication: int = 3

    def __post_init__(self):
        if self.scheme not in ("block", "hash"):
            raise ValueError(self.scheme)
        if self.num_shards < 1:
            raise ValueError("num_shards must be >= 1")

    @property
    def block_size(self) -> int:
        """Keys per shard (block scheme); key space is padded to a multiple."""
        return -(-self.n_keys // self.num_shards)

    @property
    def padded_keys(self) -> int:
        return self.block_size * self.num_shards

    def owner_of(self, keys: jax.Array) -> jax.Array:
        """Owning shard for each key (vectorized; negative keys -> -1)."""
        keys = keys.astype(jnp.int32)
        if self.scheme == "block":
            owner = keys // self.block_size
        else:
            h = (keys.astype(jnp.uint32) * _HASH_MULT) >> jnp.uint32(16)
            owner = (h % jnp.uint32(self.num_shards)).astype(jnp.int32)
        return jnp.where(keys < 0, -1, owner)

    def local_index(self, keys: jax.Array) -> jax.Array:
        """Index of a key within its owner's dense state block."""
        keys = keys.astype(jnp.int32)
        if self.scheme == "block":
            local = keys % self.block_size
        else:
            # hash scheme keeps a dense per-shard table of size block_size
            # addressed by key // num_shards (uniform under the hash).
            local = keys // self.num_shards
        return jnp.where(keys < 0, -1, local)

    def replicas_of(self, shard: int) -> list[int]:
        """Replication chain for a shard (paper §4.1, factor R)."""
        return [(shard + r) % self.num_shards
                for r in range(1, min(self.replication, self.num_shards))]

    def global_keys(self, shard, local_idx):
        """Inverse of (owner_of, local_index) for in-range local indices —
        how replica-chain entries (kept per shard, indexed locally) are
        re-keyed to the GLOBAL key space so they can be re-routed under a
        different snapshot (elastic migration).  Block scheme only: the
        hash scheme's owner is not invertible from (shard, local)."""
        if self.scheme != "block":
            raise ValueError("global_keys requires the block scheme")
        return shard * self.block_size + local_idx

    def shard_slice(self, shard: int) -> slice:
        """Dense key range owned by ``shard`` (block scheme only)."""
        if self.scheme != "block":
            raise ValueError("shard_slice requires the block scheme")
        return slice(shard * self.block_size, (shard + 1) * self.block_size)

    def resnapshot(self, num_shards: int) -> "PartitionSnapshot":
        """New snapshot after the node set changes (elastic / recovery)."""
        return dataclasses.replace(self, num_shards=num_shards)


def shard_dense_state(snapshot: PartitionSnapshot, state: jax.Array
                      ) -> jax.Array:
    """Pad + reshape a dense keyed array to [num_shards, block_size, ...]."""
    pad = snapshot.padded_keys - state.shape[0]
    if pad:
        state = jnp.concatenate(
            [state, jnp.zeros((pad,) + state.shape[1:], state.dtype)])
    return state.reshape((snapshot.num_shards, snapshot.block_size)
                         + state.shape[1:])


def unshard_dense_state(snapshot: PartitionSnapshot, sharded: jax.Array
                        ) -> jax.Array:
    """Inverse of :func:`shard_dense_state` (drops padding)."""
    flat = sharded.reshape((snapshot.padded_keys,) + sharded.shape[2:])
    return flat[:snapshot.n_keys]
