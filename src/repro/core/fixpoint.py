"""Stratified fixpoint execution (paper §3.1, §3.4, §4.2).

REX executes recursive queries in *strata*: the base case seeds the mutable
set; each stratum applies incoming deltas to operator state and emits the
next Δ set; punctuation ends a stratum; the engine terminates *implicitly*
(no new deltas — a fixpoint) or *explicitly* (a user condition over
consecutive strata, which REX converts to implicit by filtering deltas).

TPU mapping: a stratum is one iteration of ``jax.lax.while_loop``.  The
"punctuation + stratum vote at the requestor" becomes a global reduction of
the live-delta count (a ``psum`` when sharded) carried into the loop
condition.  Each stratum chooses between the **sparse** (delta) body —
O(|Δᵢ|) work — and the **dense** body (full re-derivation) *before* doing
any work, from the exactly-predicted emission size (Σ out-degree of active
keys).  This is the delta analogue of direction-optimizing BFS push/pull
switching and replaces post-hoc overflow recovery: the decision is made on
exact counts so no delta is ever dropped.

Per-stratum statistics (Δᵢ counts, dense fallbacks, bytes rehashed) are
carried in preallocated arrays so they can be reported like the paper's
Figure 2 / Figure 11.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


ROUTE_SORT = 0     # stratum rehash ran the sort-based combine-route
ROUTE_SCATTER = 1  # stratum rehash ran the scatter-based combine-route


class StratumStats(NamedTuple):
    delta_counts: jax.Array   # int32[max_iters]   — |Δᵢ| emitted per stratum
    used_dense: jax.Array     # bool[max_iters]    — stratum ran densely
    rehash_bytes: jax.Array   # float32[max_iters] — bytes moved by the rehash
    iterations: jax.Array     # int32[]            — strata actually executed
    tiers: jax.Array          # int32[max_iters]   — ladder rung per stratum
    #                           (0 = smallest sparse tier, -1 = dense / n.a.)
    routes: jax.Array = None  # int32[max_iters]   — rehash strategy per
    #                           stratum (ROUTE_SORT / ROUTE_SCATTER,
    #                           -1 = dense / n.a.)


class StratumOutcome(NamedTuple):
    """What one stratum reports back to the driver (globally reduced)."""

    live_count: jax.Array    # int32[]  — |Δ| still live after this stratum
    used_dense: jax.Array    # bool[]   — ran the dense body
    rehash_bytes: jax.Array  # float32[] — bytes the rehash moved
    emitted: jax.Array       # int32[]  — deltas emitted this stratum
    tier: jax.Array = -1     # int32[]  — capacity-ladder rung (-1 = dense)
    route: jax.Array = -1    # int32[]  — ROUTE_SORT / ROUTE_SCATTER
    #                           (-1 = dense / n.a.)


class FixpointResult(NamedTuple):
    state: object
    stats: StratumStats


def run_strata(stratum_fn: Callable, state0, live0, max_iters: int,
               tracer=None) -> FixpointResult:
    """Run ``stratum_fn`` until no live deltas remain or ``max_iters``.

    stratum_fn(state, stratum) -> (state', StratumOutcome)
        Owns the whole stratum: density decision, emission, rehash
        (collectives), application.  Outcome fields must be globally
        reduced (identical on every shard) — they feed the loop condition.
    live0
        Globally-reduced initial live count (size of Δ₀).
    tracer
        Optional ``repro.obs.Tracer``: fires a fixpoint-complete probe
        after the loop (per-stratum probes live inside ``stratum_fn``,
        inserted by the engine).  None leaves the computation untouched.
    """
    stats0 = StratumStats(
        delta_counts=jnp.zeros((max_iters,), jnp.int32),
        used_dense=jnp.zeros((max_iters,), jnp.bool_),
        rehash_bytes=jnp.zeros((max_iters,), jnp.float32),
        iterations=jnp.zeros((), jnp.int32),
        tiers=jnp.full((max_iters,), -1, jnp.int32),
        routes=jnp.full((max_iters,), -1, jnp.int32),
    )

    def cond_fn(carry):
        _, stratum, live, _ = carry
        return (stratum < max_iters) & (live > 0)

    def body_fn(carry):
        state, stratum, _, stats = carry
        new_state, outcome = stratum_fn(state, stratum)
        stats = StratumStats(
            delta_counts=stats.delta_counts.at[stratum].set(outcome.emitted),
            used_dense=stats.used_dense.at[stratum].set(outcome.used_dense),
            rehash_bytes=stats.rehash_bytes.at[stratum].set(
                outcome.rehash_bytes),
            iterations=stratum + 1,
            tiers=stats.tiers.at[stratum].set(outcome.tier),
            routes=stats.routes.at[stratum].set(outcome.route),
        )
        return (new_state, stratum + 1, outcome.live_count, stats)

    carry = (state0, jnp.zeros((), jnp.int32), jnp.asarray(live0, jnp.int32),
             stats0)
    state, _, _, stats = jax.lax.while_loop(cond_fn, body_fn, carry)
    if tracer is not None:
        tracer.fixpoint_probe(stats.iterations, max_iters)
    return FixpointResult(state=state, stats=stats)


def empty_stats(max_iters: int) -> StratumStats:
    """Stats of a run that executed zero strata (warm resume no-op)."""
    return StratumStats(
        delta_counts=jnp.zeros((max_iters,), jnp.int32),
        used_dense=jnp.zeros((max_iters,), jnp.bool_),
        rehash_bytes=jnp.zeros((max_iters,), jnp.float32),
        iterations=jnp.zeros((), jnp.int32),
        tiers=jnp.full((max_iters,), -1, jnp.int32),
        routes=jnp.full((max_iters,), -1, jnp.int32),
    )


def stats_from_outcomes(outcomes: list, max_iters: int) -> StratumStats:
    """Assemble :class:`StratumStats` from host-collected per-stratum
    outcomes — the stratum-sliced drivers' (runtime/recovery.py) equivalent
    of the recording done inside :func:`run_strata`'s while_loop.

    ``outcomes`` may be longer than ``max_iters`` when strata were redone
    after a failure (restart recovery); the stats then record the LAST
    ``max_iters`` outcomes and ``iterations`` is clipped to ``max_iters``
    so every consumer invariant (``stats.x[:iterations]`` in bounds) holds
    — the driver's work-unit metrics account the redone strata exactly.
    """
    import numpy as np
    n = min(len(outcomes), max_iters)
    tail = outcomes[-max_iters:]

    def col(getter, dtype, fill):
        arr = np.full((max_iters,), fill, dtype)
        for i, o in enumerate(tail):
            arr[i] = getter(o)
        return jnp.asarray(arr)

    return StratumStats(
        delta_counts=col(lambda o: int(o.emitted), np.int32, 0),
        used_dense=col(lambda o: bool(o.used_dense), np.bool_, False),
        rehash_bytes=col(lambda o: float(o.rehash_bytes), np.float32, 0.0),
        iterations=jnp.asarray(n, jnp.int32),
        tiers=col(lambda o: int(o.tier), np.int32, -1),
        routes=col(lambda o: int(o.route), np.int32, -1),
    )


def merge_stats(a: StratumStats, b: StratumStats) -> StratumStats:
    """Concatenate the per-stratum stats of two consecutive runs (host-side;
    used by incremental views to account a cold start plus its warm resumes
    as one logical computation)."""
    import numpy as np
    ia, ib = int(a.iterations), int(b.iterations)

    def cat(xa, xb):
        return jnp.asarray(np.concatenate(
            [np.asarray(xa)[:ia], np.asarray(xb)[:ib]]))

    return StratumStats(
        delta_counts=cat(a.delta_counts, b.delta_counts),
        used_dense=cat(a.used_dense, b.used_dense),
        rehash_bytes=cat(a.rehash_bytes, b.rehash_bytes),
        iterations=jnp.asarray(ia + ib, jnp.int32),
        tiers=cat(a.tiers, b.tiers),
        routes=cat(a.routes, b.routes),
    )


# ---------------------------------------------------------------------------
# Explicit termination (paper §3.4): a user condition over consecutive
# strata, converted to the implicit form by zeroing the live count.
# ---------------------------------------------------------------------------

def with_explicit_condition(stratum_fn: Callable, cond: Callable) -> Callable:
    """Wrap a stratum so that ``cond(new_state, old_state, stratum) -> bool``
    (True = keep iterating) gates the live count — the paper's conversion of
    explicit termination into the implicit fixpoint form."""

    def wrapped(state, stratum):
        new_state, outcome = stratum_fn(state, stratum)
        keep = cond(new_state, state, stratum)
        return new_state, outcome._replace(
            live_count=jnp.where(keep, outcome.live_count, 0))

    return wrapped
