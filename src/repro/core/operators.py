"""Relational operators over dense columnar tables (paper §3.2, §4.2).

REX supports standard relational operators — selection, projection,
``applyFunction`` (UDF map), ``group by`` with UDAs, joins, ``rehash`` — all
pipelined and delta-aware.  The TPU realization keeps a relation as a struct
of dense columns plus a validity mask (deleted/filtered rows stay in place as
masked slots: static shapes).  Stateless operators propagate annotations
untouched (paper rule); stateful operators use the Aggregator handlers.

These operators power the non-recursive side of the system: the OLAP
benchmark (paper Fig. 4), the analytics-pipeline example, and the logical
plans produced by core/plan.py.  The recursive algorithms (PageRank &c.) use
the specialized CSR join in ``algorithms/`` for the immutable set, as the
paper's query plans do (nbrBucket in Fig. 1).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Tuple

import jax
import jax.numpy as jnp

from repro.core.handlers import BUILTIN_UDAS, Aggregator


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Table:
    """Dense columnar relation with a validity mask."""

    columns: Dict[str, jax.Array]
    valid: jax.Array  # bool[N]

    @property
    def capacity(self) -> int:
        return self.valid.shape[0]

    def count(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32))

    def column(self, name: str) -> jax.Array:
        return self.columns[name]

    @staticmethod
    def from_columns(**columns: jax.Array) -> "Table":
        n = next(iter(columns.values())).shape[0]
        return Table(columns=dict(columns), valid=jnp.ones((n,), jnp.bool_))


# ---------------------------------------------------------------------------
# Stateless operators: selection / projection / applyFunction.
# Annotations (delta-ness) ride along untouched — here the validity mask is
# the only "annotation" these operators manipulate.
# ---------------------------------------------------------------------------

def select(table: Table, predicate: Callable[[Table], jax.Array]) -> Table:
    """σ — mask rows failing the predicate (UDF or built-in comparison)."""
    keep = predicate(table)
    return dataclasses.replace(table, valid=table.valid & keep)


def project(table: Table, names: Tuple[str, ...]) -> Table:
    return dataclasses.replace(
        table, columns={n: table.columns[n] for n in names})


def apply_function(table: Table, fn: Callable[..., Mapping[str, jax.Array]],
                   in_cols: Tuple[str, ...]) -> Table:
    """applyFunction — vectorized UDF producing new column(s).

    The paper invokes Java UDFs per tuple-batch via reflection; tracing makes
    the batch the whole column with zero dispatch overhead.
    """
    outs = fn(*[table.columns[c] for c in in_cols])
    cols = dict(table.columns)
    cols.update(outs)
    return dataclasses.replace(table, columns=cols)


# ---------------------------------------------------------------------------
# Stateful: group by with UDAs.
# ---------------------------------------------------------------------------

def group_by(table: Table, key_col: str,
             aggs: Mapping[str, Tuple[str, str]], n_keys: int) -> Table:
    """γ — segment-aggregate valid rows into a keyed result table.

    aggs: out_name -> (uda_name, in_col).  Each UDA's scatter combine is the
    AGGSTATE fold; the returned table is the AGGRESULT at end of stratum.
    ``average`` composes sum+count (pre-aggregate pair, paper §3.3/§5.2).
    """
    keys = table.columns[key_col].astype(jnp.int32)
    keys = jnp.where(table.valid, keys, n_keys)  # invalid -> dropped slot
    out_cols: Dict[str, jax.Array] = {
        "key": jnp.arange(n_keys, dtype=jnp.int32)}
    touched = jnp.zeros((n_keys + 1,), jnp.bool_).at[keys].set(
        table.valid, mode="drop")[:n_keys]
    for out_name, (uda_name, in_col) in aggs.items():
        uda = BUILTIN_UDAS[uda_name]
        if uda_name == "count":
            vals = table.valid.astype(jnp.float32)
        else:
            vals = table.columns[in_col].astype(jnp.float32)
        if uda_name == "average":
            s = jnp.zeros((n_keys + 1,), jnp.float32).at[keys].add(
                jnp.where(table.valid, vals, 0.0), mode="drop")[:n_keys]
            c = jnp.zeros((n_keys + 1,), jnp.float32).at[keys].add(
                table.valid.astype(jnp.float32), mode="drop")[:n_keys]
            out_cols[out_name] = s / jnp.maximum(c, 1.0)
            continue
        if uda.combiner == "add":
            init, v = 0.0, jnp.where(table.valid, vals, 0.0)
            res = jnp.full((n_keys + 1,), init, jnp.float32).at[keys].add(
                v, mode="drop")[:n_keys]
        elif uda.combiner == "min":
            v = jnp.where(table.valid, vals, jnp.inf)
            res = jnp.full((n_keys + 1,), jnp.inf, jnp.float32).at[keys].min(
                v, mode="drop")[:n_keys]
        elif uda.combiner == "max":
            v = jnp.where(table.valid, vals, -jnp.inf)
            res = jnp.full((n_keys + 1,), -jnp.inf, jnp.float32).at[keys].max(
                v, mode="drop")[:n_keys]
        else:  # replace (last)
            res = jnp.zeros((n_keys + 1,), jnp.float32).at[keys].set(
                jnp.where(table.valid, vals, 0.0), mode="drop")[:n_keys]
        out_cols[out_name] = res
    return Table(columns=out_cols, valid=touched)


def group_by_uda(table: Table, key_col: str, in_cols: Tuple[str, ...],
                 uda_apply: Callable, uda_result: Callable, n_keys: int,
                 state_width: int) -> Table:
    """γ with a fully user-defined aggregator (AGGSTATE/AGGRESULT pair).

    uda_apply(state[f32; n_keys, W], keys, cols..., valid) -> state'
    uda_result(state') -> dict of output columns (each [n_keys])
    """
    state = jnp.zeros((n_keys, state_width), jnp.float32)
    state = uda_apply(state, table.columns[key_col].astype(jnp.int32),
                      *[table.columns[c] for c in in_cols], table.valid)
    keys = jnp.where(table.valid, table.columns[key_col].astype(jnp.int32),
                     n_keys)
    touched = jnp.zeros((n_keys + 1,), jnp.bool_).at[keys].set(
        True, mode="drop")[:n_keys]
    cols = dict(uda_result(state))
    cols["key"] = jnp.arange(n_keys, dtype=jnp.int32)
    return Table(columns=cols, valid=touched)


# ---------------------------------------------------------------------------
# Joins.
# ---------------------------------------------------------------------------

def fk_join(left: Table, right: Table, left_key: str, right_key: str,
            n_keys: int, suffix: str = "_r") -> Table:
    """Key–foreign-key equi-join (right side unique on its key).

    Dense-index build on the right (the pipelined hash join's bucket array),
    gather-probe from the left — the common shape for joining facts against
    a keyed dimension (or Δ tuples against keyed state).  Output has left's
    capacity; unmatched rows are masked out.
    """
    rkeys = jnp.where(right.valid, right.columns[right_key].astype(jnp.int32),
                      n_keys)
    row_of_key = jnp.full((n_keys + 1,), -1, jnp.int32).at[rkeys].set(
        jnp.arange(right.capacity, dtype=jnp.int32), mode="drop")[:n_keys]
    lkeys = left.columns[left_key].astype(jnp.int32)
    safe = (lkeys >= 0) & (lkeys < n_keys) & left.valid
    rrow = jnp.where(safe, row_of_key[jnp.clip(lkeys, 0, n_keys - 1)], -1)
    matched = safe & (rrow >= 0)
    gather = jnp.clip(rrow, 0, right.capacity - 1)
    cols = dict(left.columns)
    for name, col in right.columns.items():
        out_name = name if name not in cols else name + suffix
        cols[out_name] = col[gather]
    return Table(columns=cols, valid=matched)


def theta_join_counts(left: Table, right: Table, left_key: str,
                      right_key: str, n_keys: int) -> jax.Array:
    """count(*) per key on the right — the optimizer-inserted cardinality
    input for the multiplicative-join compensation (paper §5.2)."""
    rkeys = jnp.where(right.valid,
                      right.columns[right_key].astype(jnp.int32), n_keys)
    return jnp.zeros((n_keys + 1,), jnp.int32).at[rkeys].add(
        1, mode="drop")[:n_keys]
