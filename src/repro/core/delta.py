"""Delta representation for the REX engine.

The paper (§3.3) defines a delta as a pair ``(α, t)`` — an annotation α plus a
tuple t — where α ∈ {+(), −(), →(t'), δ(E)}.  On a TPU, tuple streams become
fixed-shape tensors, so a Δᵢ set is materialized as a *fixed-capacity delta
buffer*: parallel arrays of keys, payloads, and annotations with a live
``count``.  Slots ≥ count are padding (key = ``PAD_KEY``) and are ignored by
every consumer.

Capacity is static (XLA requirement).  When a stratum would emit more than
``capacity`` deltas, the producer sets ``overflowed`` and the fixpoint driver
falls back to a dense stratum (see ``core/fixpoint.py``) — correctness is
preserved, only the sparsity advantage is lost for that stratum.  The paper's
observation that |Δᵢ| shrinks as computation converges is what makes a modest
capacity effective in the tail iterations.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

# Annotation codes (paper §3.3, Definition 1).
ANN_INSERT = 0   # +()    : insert tuple
ANN_DELETE = 1   # -()    : delete tuple
ANN_REPLACE = 2  # ->(t') : replace tuple
ANN_ADJUST = 3   # δ(E)   : user-interpreted adjustment (handler-defined)

PAD_KEY = jnp.int32(-1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeltaBuffer:
    """Fixed-capacity Δ set: (keys, payload, annotation, count, overflowed).

    keys:       int32[C]      — target key of each delta (PAD_KEY when unused)
    payload:    f32[C, P]     — handler-interpreted value(s) (δ(E) arguments)
    ann:        int8[C]       — annotation code per delta
    count:      int32[]       — number of live slots (<= C)
    overflowed: bool[]        — producer wanted to emit > C deltas
    """

    keys: jax.Array
    payload: jax.Array
    ann: jax.Array
    count: jax.Array
    overflowed: jax.Array

    # ---- static helpers -------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.keys.shape[0]

    @property
    def payload_width(self) -> int:
        return self.payload.shape[1]

    def valid_mask(self) -> jax.Array:
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.count

    @staticmethod
    def empty(capacity: int, payload_width: int = 1,
              payload_dtype=jnp.float32) -> "DeltaBuffer":
        return DeltaBuffer(
            keys=jnp.full((capacity,), PAD_KEY, dtype=jnp.int32),
            payload=jnp.zeros((capacity, payload_width), dtype=payload_dtype),
            ann=jnp.zeros((capacity,), dtype=jnp.int8),
            count=jnp.zeros((), dtype=jnp.int32),
            overflowed=jnp.zeros((), dtype=jnp.bool_),
        )

    @staticmethod
    def from_dense_mask(mask: jax.Array, keys: jax.Array, payload: jax.Array,
                        capacity: int, ann_code: int = ANN_ADJUST,
                        ann: Optional[jax.Array] = None) -> "DeltaBuffer":
        """Compact (mask, keys, payload) into a delta buffer of ``capacity``.

        mask: bool[N]; keys: int32[N]; payload: f32[N, P].
        Deterministic: keeps ascending positions.  Sets ``overflowed`` if the
        number of true entries exceeds capacity (excess deltas are DROPPED —
        callers must honour ``overflowed`` and redo the stratum densely).

        ``ann`` (int8[N], optional) carries per-delta annotation codes through
        the compaction; without it every slot is stamped ``ann_code``.
        """
        n = mask.shape[0]
        total = jnp.sum(mask.astype(jnp.int32))
        # Stable compaction: position of each selected element among selected.
        pos = jnp.cumsum(mask.astype(jnp.int32)) - 1          # int32[N]
        slot = jnp.where(mask & (pos < capacity), pos, capacity)
        out_keys = jnp.full((capacity + 1,), PAD_KEY, jnp.int32).at[slot].set(
            keys.astype(jnp.int32), mode="drop")[:capacity]
        out_payload = jnp.zeros((capacity + 1, payload.shape[1]),
                                payload.dtype).at[slot].set(
            payload, mode="drop")[:capacity]
        if ann is None:
            out_ann = jnp.full((capacity + 1,), ann_code, jnp.int8)[:capacity]
        else:
            out_ann = jnp.full((capacity + 1,), ann_code, jnp.int8).at[
                slot].set(ann.astype(jnp.int8), mode="drop")[:capacity]
        return DeltaBuffer(
            keys=out_keys,
            payload=out_payload,
            ann=out_ann,
            count=jnp.minimum(total, capacity),
            overflowed=total > capacity,
        )

    def to_dense(self, n_keys: int, combiner: str = "add") -> jax.Array:
        """Materialize payload column 0 as a dense vector of size n_keys.

        Uses key-occupancy masking so it is valid both for compacted buffers
        and for segment-strided (post-rehash) buffers."""
        mask = self.keys != PAD_KEY
        keys = jnp.where(mask, self.keys, n_keys)  # out-of-range -> dropped
        vals = jnp.where(mask, self.payload[:, 0], 0.0)
        base = jnp.zeros((n_keys + 1,), self.payload.dtype)
        if combiner == "add":
            out = base.at[keys].add(vals, mode="drop")
        elif combiner == "min":
            base = jnp.full((n_keys + 1,), jnp.inf, self.payload.dtype)
            vals = jnp.where(mask, self.payload[:, 0], jnp.inf)
            out = base.at[keys].min(vals, mode="drop")
        elif combiner == "max":
            base = jnp.full((n_keys + 1,), -jnp.inf, self.payload.dtype)
            vals = jnp.where(mask, self.payload[:, 0], -jnp.inf)
            out = base.at[keys].max(vals, mode="drop")
        else:
            raise ValueError(f"unknown combiner {combiner!r}")
        return out[:n_keys]


def concat(a: DeltaBuffer, b: DeltaBuffer, capacity: Optional[int] = None
           ) -> DeltaBuffer:
    """Concatenate two delta buffers (used when merging stratum outputs).

    Annotation codes travel with their deltas: concatenating buffers that
    carry insert/delete/replace deltas preserves each slot's α (previously
    the compaction re-stamped every slot ``ANN_ADJUST``, silently corrupting
    mixed-annotation merges).
    """
    cap = capacity if capacity is not None else a.capacity + b.capacity
    keys = jnp.concatenate([a.keys, b.keys])
    payload = jnp.concatenate([a.payload, b.payload])
    ann = jnp.concatenate([a.ann, b.ann])
    mask = keys != PAD_KEY
    out = DeltaBuffer.from_dense_mask(mask, keys, payload, cap, ann=ann)
    return dataclasses.replace(
        out, overflowed=out.overflowed | a.overflowed | b.overflowed)


@partial(jax.jit, static_argnames=("num_shards", "per_shard_capacity"))
def route_by_owner(db: DeltaBuffer, owners: jax.Array, num_shards: int,
                   per_shard_capacity: int) -> DeltaBuffer:
    """Group deltas by destination shard into equal-size segments.

    This is the *local half* of the paper's ``rehash`` operator: the output
    buffer has ``num_shards`` contiguous segments of ``per_shard_capacity``
    slots each, segment s holding the deltas owned by shard s (padded with
    PAD_KEY).  An ``all_to_all`` over the leading segment axis then completes
    the redistribution (see core/engine.py).

    owners: int32[C] — destination shard per delta (from the partition
    snapshot).  Padding slots must have owner outside [0, num_shards).
    """
    mask = db.valid_mask()
    owners = jnp.where(mask, owners, num_shards)
    # Rank of each delta within its destination segment (stable, sort-based:
    # O(C log C) rather than the O(C^2) "count earlier slots with same owner").
    order = jnp.argsort(owners, stable=True)            # deltas grouped by owner
    sorted_owners = owners[order]
    is_start = jnp.concatenate([jnp.array([True]),
                                sorted_owners[1:] != sorted_owners[:-1]])
    group_id = jnp.cumsum(is_start.astype(jnp.int32)) - 1
    pos = jnp.arange(db.capacity, dtype=jnp.int32)
    group_start = jnp.full((db.capacity,), db.capacity, jnp.int32).at[
        group_id].min(pos, mode="drop")
    rank_sorted = pos - group_start[group_id]
    seg_rank = jnp.zeros_like(owners).at[order].set(rank_sorted)

    slot = owners * per_shard_capacity + seg_rank
    valid = mask & (seg_rank < per_shard_capacity) & (owners < num_shards)
    total_cap = num_shards * per_shard_capacity
    slot = jnp.where(valid, slot, total_cap)

    out_keys = jnp.full((total_cap + 1,), PAD_KEY, jnp.int32).at[slot].set(
        db.keys, mode="drop")[:total_cap]
    out_payload = jnp.zeros((total_cap + 1, db.payload_width),
                            db.payload.dtype).at[slot].set(
        db.payload, mode="drop")[:total_cap]
    out_ann = jnp.zeros((total_cap + 1,), jnp.int8).at[slot].set(
        db.ann, mode="drop")[:total_cap]
    per_shard_counts = jnp.zeros((num_shards,), jnp.int32).at[
        jnp.where(valid, owners, num_shards)].add(1, mode="drop")
    overflow = db.overflowed | jnp.any(
        (jnp.zeros((num_shards + 1,), jnp.int32).at[owners].add(
            mask.astype(jnp.int32), mode="drop")[:num_shards])
        > per_shard_capacity)
    return DeltaBuffer(
        keys=out_keys, payload=out_payload, ann=out_ann,
        count=jnp.sum(per_shard_counts), overflowed=overflow)


@partial(jax.jit, static_argnames=("num_shards", "per_shard_capacity",
                                   "combiner"))
def combine_route(db: DeltaBuffer, owners: jax.Array, num_shards: int,
                  per_shard_capacity: int, combiner: str = "add"
                  ) -> DeltaBuffer:
    """Fused sender-side combiner + rehash routing (one sort, not two).

    Semantically ``route_by_owner(pre_aggregate(db, combiner), owners', S,
    cap)`` — merge deltas sharing a key, then group the merged deltas into
    per-destination segments — but done in a single pass: ONE stable
    lexicographic sort on ``(owner, key)`` (``jax.lax.sort`` with two key
    operands), one segmented reduce, and direct placement of each merged
    segment at ``owner * cap + rank``.  The back-to-back ``argsort`` passes
    the composition pays (by key, then by owner) collapse into one.

    Bit-identical to the composition whenever ``owners`` is a function of
    the key (always true when routing by a partition snapshot): sorting by
    (owner, key) then ranks within owner reproduces exactly the slot
    assignment of the two-pass pipeline, and per-segment reduction order is
    the same stable order, so float combining matches bit-for-bit.

    Merged slots are stamped ``ANN_ADJUST`` (combining implies adjustment
    semantics), dead slots carry ann 0 — the same convention the
    pre_aggregate → route_by_owner composition produces.
    """
    C = db.capacity
    int_max = jnp.iinfo(jnp.int32).max
    mask = db.keys != PAD_KEY
    # Out-of-range owners (incl. -1 from owner_of on padding) route with the
    # padding: they sort to the tail and are dropped from placement.
    owners = jnp.where(mask & (owners >= 0) & (owners < num_shards),
                       owners, num_shards)
    mask = mask & (owners < num_shards)
    sort_keys = jnp.where(mask, db.keys, int_max)
    iota = jnp.arange(C, dtype=jnp.int32)
    # One stable sort, lexicographic by (owner, key); padding (num_shards,
    # INT32_MAX) sinks to the tail.
    sowner, skeys, order = jax.lax.sort((owners, sort_keys, iota),
                                        num_keys=2, is_stable=True)
    spay = db.payload[order]
    # Segment = run of equal (owner, key).
    is_head = jnp.concatenate([
        jnp.array([True]),
        (sowner[1:] != sowner[:-1]) | (skeys[1:] != skeys[:-1])])
    seg_id = jnp.cumsum(is_head.astype(jnp.int32)) - 1
    w = db.payload_width
    if combiner == "add":
        merged = jnp.zeros((C, w), spay.dtype).at[seg_id].add(spay)
    elif combiner == "min":
        merged = jnp.full((C, w), jnp.inf, spay.dtype).at[seg_id].min(spay)
    elif combiner == "max":
        merged = jnp.full((C, w), -jnp.inf, spay.dtype).at[seg_id].max(spay)
    elif combiner == "replace":
        # Last (stable order) wins — selected explicitly: scatter-set with
        # duplicate indices has an unspecified winner in JAX, so only each
        # segment's tail element writes (single writer, deterministic).
        is_tail = jnp.concatenate([
            (sowner[1:] != sowner[:-1]) | (skeys[1:] != skeys[:-1]),
            jnp.array([True])])
        merged = jnp.zeros((C, w), spay.dtype).at[seg_id].add(
            jnp.where(is_tail[:, None], spay, 0.0))
    else:
        raise ValueError(f"unknown combiner {combiner!r}")
    # Per-segment key/owner (all members agree) + liveness.
    seg_ids = jnp.arange(C, dtype=jnp.int32)
    seg_key = jnp.zeros((C,), jnp.int32).at[seg_id].max(skeys)
    seg_owner = jnp.full((C,), num_shards, jnp.int32).at[seg_id].set(sowner)
    live_seg = jnp.zeros((C,), jnp.bool_).at[seg_id].set(skeys != int_max)
    # Rank of each segment within its owner = seg index − owner's first seg.
    owner_start = jnp.full((num_shards + 2,), C, jnp.int32).at[
        jnp.clip(seg_owner, 0, num_shards + 1)].min(seg_ids)
    rank = seg_ids - owner_start[jnp.clip(seg_owner, 0, num_shards + 1)]
    valid = (live_seg & (rank < per_shard_capacity)
             & (seg_owner >= 0) & (seg_owner < num_shards))
    total_cap = num_shards * per_shard_capacity
    slot = jnp.where(valid, seg_owner * per_shard_capacity + rank, total_cap)
    out_keys = jnp.full((total_cap + 1,), PAD_KEY, jnp.int32).at[slot].set(
        seg_key, mode="drop")[:total_cap]
    out_payload = jnp.zeros((total_cap + 1, w), db.payload.dtype).at[
        slot].set(merged, mode="drop")[:total_cap]
    out_ann = jnp.zeros((total_cap + 1,), jnp.int8).at[slot].set(
        jnp.int8(ANN_ADJUST), mode="drop")[:total_cap]
    per_owner_segs = jnp.zeros((num_shards + 1,), jnp.int32).at[
        jnp.clip(seg_owner, 0, num_shards)].add(
        live_seg.astype(jnp.int32), mode="drop")[:num_shards]
    overflow = db.overflowed | jnp.any(per_owner_segs > per_shard_capacity)
    return DeltaBuffer(
        keys=out_keys, payload=out_payload, ann=out_ann,
        count=jnp.sum(valid.astype(jnp.int32)), overflowed=overflow)


def recount(db: DeltaBuffer) -> DeltaBuffer:
    """Recompute ``count`` from PAD_KEY occupancy (after an all_to_all the
    receiving shard's segments carry padding interleaved with live slots, so
    the transferred scalar count is meaningless)."""
    live = (db.keys != PAD_KEY).astype(jnp.int32)
    return dataclasses.replace(db, count=jnp.sum(live))


def valid_mask_by_key(db: DeltaBuffer) -> jax.Array:
    """Validity from key occupancy (order-independent, post-rehash safe)."""
    return db.keys != PAD_KEY
