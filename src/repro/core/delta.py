"""Delta representation for the REX engine.

The paper (§3.3) defines a delta as a pair ``(α, t)`` — an annotation α plus a
tuple t — where α ∈ {+(), −(), →(t'), δ(E)}.  On a TPU, tuple streams become
fixed-shape tensors, so a Δᵢ set is materialized as a *fixed-capacity delta
buffer*: parallel arrays of keys, payloads, and annotations with a live
``count``.  Slots ≥ count are padding (key = ``PAD_KEY``) and are ignored by
every consumer.

Capacity is static (XLA requirement).  When a stratum would emit more than
``capacity`` deltas, the producer sets ``overflowed`` and the fixpoint driver
falls back to a dense stratum (see ``core/fixpoint.py``) — correctness is
preserved, only the sparsity advantage is lost for that stratum.  The paper's
observation that |Δᵢ| shrinks as computation converges is what makes a modest
capacity effective in the tail iterations.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

# Annotation codes (paper §3.3, Definition 1).
ANN_INSERT = 0   # +()    : insert tuple
ANN_DELETE = 1   # -()    : delete tuple
ANN_REPLACE = 2  # ->(t') : replace tuple
ANN_ADJUST = 3   # δ(E)   : user-interpreted adjustment (handler-defined)

PAD_KEY = jnp.int32(-1)


def _last_writer_mask(addr: jax.Array, valid: jax.Array, size: int
                      ) -> jax.Array:
    """True at the LAST valid slot scattering to each address in
    ``[0, size)`` (stable slot order).  Scatter-set with duplicate
    indices has an unspecified winner in JAX, so every replace-combining
    path selects its single writer through this mask — keeping the
    last-wins convention identical across ``to_dense``,
    ``combine_route`` and the scatter strategy."""
    iota = jnp.arange(addr.shape[0], dtype=jnp.int32)
    win = jnp.full((size,), -1, jnp.int32).at[addr].max(
        jnp.where(valid, iota, -1), mode="drop")
    return valid & (win[jnp.clip(addr, 0, size - 1)] == iota)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeltaBuffer:
    """Fixed-capacity Δ set: (keys, payload, annotation, count, overflowed).

    keys:       int32[C]      — target key of each delta (PAD_KEY when unused)
    payload:    f32[C, P]     — handler-interpreted value(s) (δ(E) arguments)
    ann:        int8[C]       — annotation code per delta
    count:      int32[]       — number of live slots (<= C)
    overflowed: bool[]        — producer wanted to emit > C deltas
    """

    keys: jax.Array
    payload: jax.Array
    ann: jax.Array
    count: jax.Array
    overflowed: jax.Array

    # ---- static helpers -------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.keys.shape[0]

    @property
    def payload_width(self) -> int:
        return self.payload.shape[1]

    def valid_mask(self) -> jax.Array:
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.count

    @staticmethod
    def empty(capacity: int, payload_width: int = 1,
              payload_dtype=jnp.float32) -> "DeltaBuffer":
        return DeltaBuffer(
            keys=jnp.full((capacity,), PAD_KEY, dtype=jnp.int32),
            payload=jnp.zeros((capacity, payload_width), dtype=payload_dtype),
            ann=jnp.zeros((capacity,), dtype=jnp.int8),
            count=jnp.zeros((), dtype=jnp.int32),
            overflowed=jnp.zeros((), dtype=jnp.bool_),
        )

    @staticmethod
    def from_dense_mask(mask: jax.Array, keys: jax.Array, payload: jax.Array,
                        capacity: int, ann_code: int = ANN_ADJUST,
                        ann: Optional[jax.Array] = None) -> "DeltaBuffer":
        """Compact (mask, keys, payload) into a delta buffer of ``capacity``.

        mask: bool[N]; keys: int32[N]; payload: f32[N, P].
        Deterministic: keeps ascending positions.  Sets ``overflowed`` if the
        number of true entries exceeds capacity (excess deltas are DROPPED —
        callers must honour ``overflowed`` and redo the stratum densely).

        ``ann`` (int8[N], optional) carries per-delta annotation codes through
        the compaction; without it every slot is stamped ``ann_code``.
        """
        n = mask.shape[0]
        total = jnp.sum(mask.astype(jnp.int32))
        # Stable compaction: position of each selected element among selected.
        pos = jnp.cumsum(mask.astype(jnp.int32)) - 1          # int32[N]
        slot = jnp.where(mask & (pos < capacity), pos, capacity)
        out_keys = jnp.full((capacity + 1,), PAD_KEY, jnp.int32).at[slot].set(
            keys.astype(jnp.int32), mode="drop")[:capacity]
        out_payload = jnp.zeros((capacity + 1, payload.shape[1]),
                                payload.dtype).at[slot].set(
            payload, mode="drop")[:capacity]
        if ann is None:
            out_ann = jnp.full((capacity + 1,), ann_code, jnp.int8)[:capacity]
        else:
            out_ann = jnp.full((capacity + 1,), ann_code, jnp.int8).at[
                slot].set(ann.astype(jnp.int8), mode="drop")[:capacity]
        return DeltaBuffer(
            keys=out_keys,
            payload=out_payload,
            ann=out_ann,
            count=jnp.minimum(total, capacity),
            overflowed=total > capacity,
        )

    def to_dense(self, n_keys: int, combiner: str = "add") -> jax.Array:
        """Materialize payload column 0 as a dense vector of size n_keys.

        Uses key-occupancy masking so it is valid both for compacted buffers
        and for segment-strided (post-rehash) buffers.  Supports the same
        combiner set as ``combine_route`` — for ``"replace"`` the LAST live
        slot of each key wins (stable slot order), selected explicitly
        because scatter-set with duplicate indices has an unspecified
        winner in JAX."""
        mask = self.keys != PAD_KEY
        keys = jnp.where(mask, self.keys, n_keys)  # out-of-range -> dropped
        vals = jnp.where(mask, self.payload[:, 0], 0.0)
        base = jnp.zeros((n_keys + 1,), self.payload.dtype)
        if combiner == "add":
            out = base.at[keys].add(vals, mode="drop")
        elif combiner == "min":
            base = jnp.full((n_keys + 1,), jnp.inf, self.payload.dtype)
            vals = jnp.where(mask, self.payload[:, 0], jnp.inf)
            out = base.at[keys].min(vals, mode="drop")
        elif combiner == "max":
            base = jnp.full((n_keys + 1,), -jnp.inf, self.payload.dtype)
            vals = jnp.where(mask, self.payload[:, 0], -jnp.inf)
            out = base.at[keys].max(vals, mode="drop")
        elif combiner == "replace":
            is_winner = _last_writer_mask(keys, mask, n_keys + 1)
            out = base.at[keys].add(jnp.where(is_winner, vals, 0.0),
                                    mode="drop")
        else:
            raise ValueError(f"unknown combiner {combiner!r}")
        return out[:n_keys]


def concat(a: DeltaBuffer, b: DeltaBuffer, capacity: Optional[int] = None
           ) -> DeltaBuffer:
    """Concatenate two delta buffers (used when merging stratum outputs).

    Annotation codes travel with their deltas: concatenating buffers that
    carry insert/delete/replace deltas preserves each slot's α (previously
    the compaction re-stamped every slot ``ANN_ADJUST``, silently corrupting
    mixed-annotation merges).
    """
    cap = capacity if capacity is not None else a.capacity + b.capacity
    keys = jnp.concatenate([a.keys, b.keys])
    payload = jnp.concatenate([a.payload, b.payload])
    ann = jnp.concatenate([a.ann, b.ann])
    mask = keys != PAD_KEY
    out = DeltaBuffer.from_dense_mask(mask, keys, payload, cap, ann=ann)
    return dataclasses.replace(
        out, overflowed=out.overflowed | a.overflowed | b.overflowed)


@partial(jax.jit, static_argnames=("num_shards", "per_shard_capacity"))
def route_by_owner(db: DeltaBuffer, owners: jax.Array, num_shards: int,
                   per_shard_capacity: int) -> DeltaBuffer:
    """Group deltas by destination shard into equal-size segments.

    This is the *local half* of the paper's ``rehash`` operator: the output
    buffer has ``num_shards`` contiguous segments of ``per_shard_capacity``
    slots each, segment s holding the deltas owned by shard s (padded with
    PAD_KEY).  An ``all_to_all`` over the leading segment axis then completes
    the redistribution (see core/engine.py).

    owners: int32[C] — destination shard per delta (from the partition
    snapshot).  Padding slots must have owner outside [0, num_shards).
    """
    mask = db.valid_mask()
    owners = jnp.where(mask, owners, num_shards)
    # Rank of each delta within its destination segment (stable, sort-based:
    # O(C log C) rather than the O(C^2) "count earlier slots with same owner").
    order = jnp.argsort(owners, stable=True)            # deltas grouped by owner
    sorted_owners = owners[order]
    is_start = jnp.concatenate([jnp.array([True]),
                                sorted_owners[1:] != sorted_owners[:-1]])
    group_id = jnp.cumsum(is_start.astype(jnp.int32)) - 1
    pos = jnp.arange(db.capacity, dtype=jnp.int32)
    group_start = jnp.full((db.capacity,), db.capacity, jnp.int32).at[
        group_id].min(pos, mode="drop")
    rank_sorted = pos - group_start[group_id]
    seg_rank = jnp.zeros_like(owners).at[order].set(rank_sorted)

    slot = owners * per_shard_capacity + seg_rank
    valid = mask & (seg_rank < per_shard_capacity) & (owners < num_shards)
    total_cap = num_shards * per_shard_capacity
    slot = jnp.where(valid, slot, total_cap)

    out_keys = jnp.full((total_cap + 1,), PAD_KEY, jnp.int32).at[slot].set(
        db.keys, mode="drop")[:total_cap]
    out_payload = jnp.zeros((total_cap + 1, db.payload_width),
                            db.payload.dtype).at[slot].set(
        db.payload, mode="drop")[:total_cap]
    out_ann = jnp.zeros((total_cap + 1,), jnp.int8).at[slot].set(
        db.ann, mode="drop")[:total_cap]
    per_shard_counts = jnp.zeros((num_shards,), jnp.int32).at[
        jnp.where(valid, owners, num_shards)].add(1, mode="drop")
    overflow = db.overflowed | jnp.any(
        (jnp.zeros((num_shards + 1,), jnp.int32).at[owners].add(
            mask.astype(jnp.int32), mode="drop")[:num_shards])
        > per_shard_capacity)
    return DeltaBuffer(
        keys=out_keys, payload=out_payload, ann=out_ann,
        count=jnp.sum(per_shard_counts), overflowed=overflow)


@partial(jax.jit, static_argnames=("num_shards", "per_shard_capacity",
                                   "combiner"))
def combine_route(db: DeltaBuffer, owners: jax.Array, num_shards: int,
                  per_shard_capacity: int, combiner: str = "add"
                  ) -> DeltaBuffer:
    """Fused sender-side combiner + rehash routing (one sort, not two).

    Semantically ``route_by_owner(pre_aggregate(db, combiner), owners', S,
    cap)`` — merge deltas sharing a key, then group the merged deltas into
    per-destination segments — but done in a single pass: ONE stable
    lexicographic sort on ``(owner, key)`` (``jax.lax.sort`` with two key
    operands), one segmented reduce, and direct placement of each merged
    segment at ``owner * cap + rank``.  The back-to-back ``argsort`` passes
    the composition pays (by key, then by owner) collapse into one.

    Bit-identical to the composition whenever ``owners`` is a function of
    the key (always true when routing by a partition snapshot): sorting by
    (owner, key) then ranks within owner reproduces exactly the slot
    assignment of the two-pass pipeline, and per-segment reduction order is
    the same stable order, so float combining matches bit-for-bit.

    Merged slots are stamped ``ANN_ADJUST`` (combining implies adjustment
    semantics), dead slots carry ann 0 — the same convention the
    pre_aggregate → route_by_owner composition produces.
    """
    C = db.capacity
    int_max = jnp.iinfo(jnp.int32).max
    mask = db.keys != PAD_KEY
    # Out-of-range owners (incl. -1 from owner_of on padding) route with the
    # padding: they sort to the tail and are dropped from placement.
    owners = jnp.where(mask & (owners >= 0) & (owners < num_shards),
                       owners, num_shards)
    mask = mask & (owners < num_shards)
    sort_keys = jnp.where(mask, db.keys, int_max)
    iota = jnp.arange(C, dtype=jnp.int32)
    # One stable sort, lexicographic by (owner, key); padding (num_shards,
    # INT32_MAX) sinks to the tail.
    sowner, skeys, order = jax.lax.sort((owners, sort_keys, iota),
                                        num_keys=2, is_stable=True)
    spay = db.payload[order]
    # Segment = run of equal (owner, key).
    is_head = jnp.concatenate([
        jnp.array([True]),
        (sowner[1:] != sowner[:-1]) | (skeys[1:] != skeys[:-1])])
    seg_id = jnp.cumsum(is_head.astype(jnp.int32)) - 1
    w = db.payload_width
    if combiner == "add":
        merged = jnp.zeros((C, w), spay.dtype).at[seg_id].add(spay)
    elif combiner == "min":
        merged = jnp.full((C, w), jnp.inf, spay.dtype).at[seg_id].min(spay)
    elif combiner == "max":
        merged = jnp.full((C, w), -jnp.inf, spay.dtype).at[seg_id].max(spay)
    elif combiner == "replace":
        # Last (stable order) wins — selected explicitly: scatter-set with
        # duplicate indices has an unspecified winner in JAX, so only each
        # segment's tail element writes (single writer, deterministic).
        is_tail = jnp.concatenate([
            (sowner[1:] != sowner[:-1]) | (skeys[1:] != skeys[:-1]),
            jnp.array([True])])
        merged = jnp.zeros((C, w), spay.dtype).at[seg_id].add(
            jnp.where(is_tail[:, None], spay, 0.0))
    else:
        raise ValueError(f"unknown combiner {combiner!r}")
    # Per-segment key/owner (all members agree) + liveness.
    seg_ids = jnp.arange(C, dtype=jnp.int32)
    seg_key = jnp.zeros((C,), jnp.int32).at[seg_id].max(skeys)
    seg_owner = jnp.full((C,), num_shards, jnp.int32).at[seg_id].set(sowner)
    live_seg = jnp.zeros((C,), jnp.bool_).at[seg_id].set(skeys != int_max)
    # Rank of each segment within its owner = seg index − owner's first seg.
    owner_start = jnp.full((num_shards + 2,), C, jnp.int32).at[
        jnp.clip(seg_owner, 0, num_shards + 1)].min(seg_ids)
    rank = seg_ids - owner_start[jnp.clip(seg_owner, 0, num_shards + 1)]
    valid = (live_seg & (rank < per_shard_capacity)
             & (seg_owner >= 0) & (seg_owner < num_shards))
    total_cap = num_shards * per_shard_capacity
    slot = jnp.where(valid, seg_owner * per_shard_capacity + rank, total_cap)
    out_keys = jnp.full((total_cap + 1,), PAD_KEY, jnp.int32).at[slot].set(
        seg_key, mode="drop")[:total_cap]
    out_payload = jnp.zeros((total_cap + 1, w), db.payload.dtype).at[
        slot].set(merged, mode="drop")[:total_cap]
    out_ann = jnp.zeros((total_cap + 1,), jnp.int8).at[slot].set(
        jnp.int8(ANN_ADJUST), mode="drop")[:total_cap]
    per_owner_segs = jnp.zeros((num_shards + 1,), jnp.int32).at[
        jnp.clip(seg_owner, 0, num_shards)].add(
        live_seg.astype(jnp.int32), mode="drop")[:num_shards]
    overflow = db.overflowed | jnp.any(per_owner_segs > per_shard_capacity)
    return DeltaBuffer(
        keys=out_keys, payload=out_payload, ann=out_ann,
        count=jnp.sum(valid.astype(jnp.int32)), overflowed=overflow)


@partial(jax.jit, static_argnames=("num_shards", "per_shard_capacity",
                                   "combiner", "snapshot"))
def combine_route_scatter(db: DeltaBuffer, owners: jax.Array,
                          num_shards: int, per_shard_capacity: int,
                          combiner: str = "add", *, snapshot
                          ) -> DeltaBuffer:
    """Sort-free combine + route: scatter into a dense per-destination slab.

    Same contract as :func:`combine_route` — merge deltas sharing a key,
    then place each owner's merged deltas in its segment in ascending-key
    order — but implemented without the O(C log C) sort.  Because
    ``owners`` is a function of the key (routing always goes through the
    partition snapshot), every key has exactly one slab cell: payloads are
    scatter-combined into a dense accumulator addressed by the global key
    (equivalently ``(owner, local_index)``), and each owner's slab is then
    stably compacted into its segment by a prefix-sum over cell occupancy
    — O(C + slab) work, where slab = ``snapshot.padded_keys`` cells.

    Output layout is slot-for-slot identical to the sort path: ascending
    cell order within an owner IS ascending key order, overflowing owners
    keep their ``per_shard_capacity`` smallest keys, and count/overflow
    match.  Payloads are bit-identical for min/max/replace (order-free or
    single-writer merges); float "add" may reassociate the per-key sum and
    differ by ≤1 ulp from the sorted segmented reduce (XLA CPU applies
    scatter updates in slot order, which equals the stable sorted order
    within a key, so in practice "add" matches bit-for-bit there too).

    Requirements (enforced by the caller, see ``ShardedExecutor``):
    ``owners`` must agree across slots sharing a key (out-of-range owners
    drop the whole key, matching the sort path), and live keys must lie in
    ``[0, snapshot.padded_keys)``.
    """
    if snapshot.num_shards != num_shards:
        raise ValueError(
            f"snapshot has {snapshot.num_shards} shards, caller asked for "
            f"{num_shards}")
    C = db.capacity
    S = num_shards
    N = snapshot.padded_keys          # slab cells (one per routable key)
    w = db.payload_width
    cap = per_shard_capacity
    total_cap = S * cap
    mask = db.keys != PAD_KEY
    valid = (mask & (owners >= 0) & (owners < S)
             & (db.keys >= 0) & (db.keys < N))
    addr = jnp.where(valid, db.keys, N)          # N = drop sentinel

    # ---- combine: one slab cell per key ------------------------------
    occ = None
    if combiner == "add":
        # Occupancy rides the payload scatter as an extra column: one
        # C-sized scatter loop instead of two (XLA CPU scatters are
        # sequential per update).  Counts ≤ C stay exact in f32.
        aug = jnp.concatenate(
            [db.payload, jnp.ones((C, 1), db.payload.dtype)], axis=1)
        slab_aug = jnp.zeros((N + 1, w + 1), db.payload.dtype).at[
            addr].add(jnp.where(valid[:, None], aug, 0.0), mode="drop")
        slab = slab_aug[:, :w]
        occ = (slab_aug[:N, w] > 0).astype(jnp.int32)
    elif combiner == "min":
        slab = jnp.full((N + 1, w), jnp.inf, db.payload.dtype).at[addr].min(
            jnp.where(valid[:, None], db.payload, jnp.inf), mode="drop")
    elif combiner == "max":
        slab = jnp.full((N + 1, w), -jnp.inf, db.payload.dtype).at[
            addr].max(jnp.where(valid[:, None], db.payload, -jnp.inf),
                      mode="drop")
    elif combiner == "replace":
        # Last (stable slot order) wins — single-writer selection, same
        # convention as combine_route.
        is_winner = _last_writer_mask(addr, valid, N + 1)
        slab = jnp.zeros((N + 1, w), db.payload.dtype).at[addr].add(
            jnp.where(is_winner[:, None], db.payload, 0.0), mode="drop")
    else:
        raise ValueError(f"unknown combiner {combiner!r}")
    if occ is None:
        occ = jnp.zeros((N + 1,), jnp.int32).at[addr].add(
            valid.astype(jnp.int32), mode="drop")[:N]
    slab = slab[:N]
    live_cell = occ > 0

    # ---- compact: output slot (s, r) GATHERS its cell -----------------
    # Scattering all N slab cells into the segments would pay an N-sized
    # scalar scatter loop on XLA CPU; instead each of the S·cap output
    # slots binary-searches the per-owner occupancy prefix sum for the
    # (r+1)-th live cell of its owner — O(S·cap·log) vectorized gathers,
    # no scatter.  Ascending cell order within an owner IS ascending key
    # order, so the layout matches the sort path exactly.
    # An owner can hold at most one live cell per slab cell it owns, so
    # only min(cap, cells-per-owner) leading slots of each segment can
    # ever fill — query just those and pad the rest (big top-rung
    # segments stop paying O(cap) searches).
    if snapshot.scheme == "block":
        # Cell c belongs to owner c // block_size: one row-wise prefix
        # sum over the [S, B] slab view.
        B = snapshot.block_size
        capq = min(cap, B)
        queries = jnp.arange(1, capq + 1, dtype=jnp.int32)
        cum = jnp.cumsum(live_cell.reshape(S, B).astype(jnp.int32), axis=1)
        per_owner = cum[:, -1]
        idx = jax.vmap(lambda c: jnp.searchsorted(c, queries))(cum)
        filled = idx < B                                     # [S, capq]
        cell = (jnp.arange(S, dtype=jnp.int32)[:, None] * B
                + jnp.minimum(idx, B - 1).astype(jnp.int32))
    else:
        # Hash scheme: a cell's owner is not a function of its position,
        # so recover it from the (key-consistent) owners array and count
        # per owner with a one-hot prefix sum — O(N·S), still sort-free.
        capq = min(cap, N)
        queries = jnp.arange(1, capq + 1, dtype=jnp.int32)
        cell_owner = jnp.full((N + 1,), S, jnp.int32).at[addr].min(
            jnp.where(valid, owners, S), mode="drop")[:N]
        onehot = ((cell_owner[:, None] == jnp.arange(S)[None, :])
                  & live_cell[:, None]).astype(jnp.int32)
        counts = jnp.cumsum(onehot, axis=0)                  # [N, S]
        per_owner = counts[-1, :]
        idx = jax.vmap(lambda c: jnp.searchsorted(c, queries))(counts.T)
        filled = idx < N                                     # [S, capq]
        cell = jnp.minimum(idx, N - 1).astype(jnp.int32)
    seg_keys = jnp.where(filled, cell, PAD_KEY)
    seg_payload = jnp.where(filled[..., None], slab[cell],
                            jnp.zeros((), db.payload.dtype))
    seg_ann = jnp.where(filled, jnp.int8(ANN_ADJUST), jnp.int8(0))
    pad = cap - capq
    if pad:
        seg_keys = jnp.pad(seg_keys, ((0, 0), (0, pad)),
                           constant_values=PAD_KEY)
        seg_payload = jnp.pad(seg_payload, ((0, 0), (0, pad), (0, 0)))
        seg_ann = jnp.pad(seg_ann, ((0, 0), (0, pad)))
    out_keys = seg_keys.reshape(total_cap)
    out_payload = seg_payload.reshape(total_cap, w)
    out_ann = seg_ann.reshape(total_cap)
    overflow = db.overflowed | jnp.any(per_owner > cap)
    return DeltaBuffer(
        keys=out_keys, payload=out_payload, ann=out_ann,
        count=jnp.sum(jnp.minimum(per_owner, cap)), overflowed=overflow)


def recount(db: DeltaBuffer) -> DeltaBuffer:
    """Recompute ``count`` from PAD_KEY occupancy (after an all_to_all the
    receiving shard's segments carry padding interleaved with live slots, so
    the transferred scalar count is meaningless)."""
    live = (db.keys != PAD_KEY).astype(jnp.int32)
    return dataclasses.replace(db, count=jnp.sum(live))


def valid_mask_by_key(db: DeltaBuffer) -> jax.Array:
    """Validity from key occupancy (order-independent, post-rehash safe)."""
    return db.keys != PAD_KEY
