"""Typed logical-plan IR for RQL-style queries (paper §3.2, §5).

A plan is a DAG of typed operator nodes — scan / select / project / apply
(UDF) / join / group-aggregate / pre-aggregate / rehash / fixpoint — each
carrying an output *schema* (column names), an optional *combiner*
annotation (``add``/``min``/``max`` for aggregation and fixpoint nodes) and
the per-operator cost metadata the optimizer works on.  The frontend
(repro.frontend) builds these plans from rule programs; the optimizer
(core/optimizer.py) rewrites them IR-to-IR (UDF/join interleaving by rank,
pre-aggregation pushdown, fixpoint cost refresh); the lowering pass
(frontend/lower.py) emits ``DeltaAlgorithm`` callables from the optimized
plan via core/operators.py Table ops.

Costs follow the paper's model: per-operator (cpu, disk, net) *resource
vectors* (§5 "Accounting for CPU-I/O overlap") — combining two concurrent
subplans costs the max over each resource lane, not the sum.

Recursive cost (§5.3 + §6): :func:`fixpoint` runs a simulated-iteration
estimate at construction.  A monotone-``add`` accumulator conservatively
assumes the Δ set does not shrink (every stratum re-touches the full
frontier); an *idempotent* combiner (``min``/``max``) takes the
delta-retraction path — superseded deltas retract, so |Δᵢ| decays
geometrically and the estimate both converges earlier and costs less.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

ResourceVector = Tuple[float, float, float]  # (cpu, disk, net) seconds

Schema = Tuple[str, ...]                     # output column names

#: Combiners with idempotent merge (x ⊕ x = x): their delta semantics allow
#: retraction of superseded contributions (paper §6), unlike ``add``.
IDEMPOTENT_COMBINERS = frozenset({"min", "max"})

#: uda_name -> combiner annotation, for plans built via :func:`groupby`.
_UDA_COMBINERS = {"sum": "add", "count": "add", "add": "add",
                  "min": "min", "max": "max"}


def overlap_combine(a: ResourceVector, b: ResourceVector) -> ResourceVector:
    """Paper §5: two pipelined subplans overlap; each resource lane is
    additive (both plans consume it), but the *runtime* is bounded by the
    busiest lane — see :func:`runtime_of`.  Combination is lane-wise sum."""
    return (a[0] + b[0], a[1] + b[1], a[2] + b[2])


def sequential_combine(a: ResourceVector, b: ResourceVector) -> ResourceVector:
    return (a[0] + b[0], a[1] + b[1], a[2] + b[2])


def runtime_of(v: ResourceVector, pipelined: bool = True) -> float:
    """Pipelined runtime = max lane (full overlap, §5's extreme case);
    non-pipelined = sum of lanes."""
    return max(v) if pipelined else sum(v)


@dataclasses.dataclass
class PlanNode:
    op: str                               # scan|select|project|udf|join|
    #                                       groupby|rehash|preagg|fixpoint
    children: Sequence["PlanNode"] = ()
    # --- statistics / calibration --------------------------------------
    out_cardinality: float = 0.0          # estimated output rows
    selectivity: float = 1.0              # rows_out / rows_in   (select/udf)
    cost_per_tuple: float = 0.0           # cpu seconds per input row (udf)
    resource: ResourceVector = (0.0, 0.0, 0.0)
    # --- typing ----------------------------------------------------------
    schema: Schema = ()                   # output column names (may be ())
    combiner: Optional[str] = None        # groupby/preagg/fixpoint: add|min|max
    # --- semantic flags --------------------------------------------------
    name: str = ""
    uda_name: Optional[str] = None        # groupby/preagg: which aggregator
    composable: bool = True               # §5.2 — can pre-agg cross any join
    key_fk_join: bool = False             # join on key–foreign-key?
    has_multiply: bool = False            # §5.2 multiplicative compensation
    deterministic: bool = True            # UDF caching eligibility (§5.1)
    volatile: bool = False
    cost_hint: Optional[Callable[[float], float]] = None  # §5.1 "big-O" hints
    expr: Optional[object] = None         # frontend scalar expression payload
    pinned: bool = False                  # frontend-semantic UDF: optimizer
    #                                       must not reorder it across joins
    max_iters: int = 0                    # fixpoint: iteration budget
    estimated_iterations: int = 0         # fixpoint: simulated-iteration count

    def __post_init__(self):
        self.children = tuple(self.children)
        self._validate()

    def _validate(self) -> None:  # typed subclasses override
        pass

    def rank(self) -> float:
        """Predicate-migration rank (paper §5.1, after [13]):
        cost-per-tuple / (1 - selectivity).  Lower rank ⇒ apply earlier:
        cheap predicates and highly selective predicates come first."""
        drop = 1.0 - min(self.selectivity, 1.0 - 1e-9)
        return self.cost_per_tuple / drop

    def clone(self, **overrides) -> "PlanNode":
        return dataclasses.replace(self, **overrides)


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


@dataclasses.dataclass
class Scan(PlanNode):
    def _validate(self):
        _require(self.op == "scan" and not self.children,
                 "scan is a leaf node")


@dataclasses.dataclass
class Select(PlanNode):
    def _validate(self):
        _require(self.op == "select" and len(self.children) == 1,
                 "select takes one child")
        if not self.schema:
            self.schema = self.children[0].schema


@dataclasses.dataclass
class Project(PlanNode):
    def _validate(self):
        _require(self.op == "project" and len(self.children) == 1,
                 "project takes one child")
        child_schema = self.children[0].schema
        if child_schema:
            missing = [c for c in self.schema if c not in child_schema]
            _require(not missing,
                     f"project columns {missing} not in child schema "
                     f"{child_schema}")


@dataclasses.dataclass
class Apply(PlanNode):
    """applyFunction / expensive-predicate node (op kept as ``udf`` for
    compatibility with rank-based interleaving)."""

    def _validate(self):
        _require(self.op == "udf" and len(self.children) == 1,
                 "apply/udf takes one child")
        if not self.schema:
            self.schema = self.children[0].schema


@dataclasses.dataclass
class Join(PlanNode):
    def _validate(self):
        _require(self.op == "join" and len(self.children) == 2,
                 "join takes two children")
        if not self.schema:
            self.schema = tuple(self.children[0].schema) + tuple(
                c for c in self.children[1].schema
                if c not in self.children[0].schema)


@dataclasses.dataclass
class Rehash(PlanNode):
    def _validate(self):
        _require(self.op == "rehash" and len(self.children) == 1,
                 "rehash takes one child")
        if not self.schema:
            self.schema = self.children[0].schema


@dataclasses.dataclass
class GroupAggregate(PlanNode):
    def _validate(self):
        _require(self.op == "groupby" and len(self.children) == 1,
                 "group-aggregate takes one child")
        _require(self.combiner in (None, "add", "min", "max"),
                 f"unknown combiner {self.combiner!r}")


@dataclasses.dataclass
class PreAggregate(PlanNode):
    def _validate(self):
        _require(self.op == "preagg" and len(self.children) == 1,
                 "pre-aggregate takes one child")
        if not self.schema:
            self.schema = self.children[0].schema


@dataclasses.dataclass
class Fixpoint(PlanNode):
    def _validate(self):
        _require(self.op == "fixpoint" and len(self.children) == 2,
                 "fixpoint takes (base, recursive) children")
        _require(self.combiner in (None, "add", "min", "max"),
                 f"unknown combiner {self.combiner!r}")

    @property
    def base(self) -> PlanNode:
        return self.children[0]

    @property
    def recursive(self) -> PlanNode:
        return self.children[1]

    @property
    def idempotent(self) -> bool:
        return self.combiner in IDEMPOTENT_COMBINERS


# ---------------------------------------------------------------------------
# Constructors (stats + resource vectors computed here).
# ---------------------------------------------------------------------------

def scan(name: str, cardinality: float, disk_per_tuple: float = 1e-8,
         schema: Schema = ()) -> Scan:
    return Scan(op="scan", name=name, out_cardinality=cardinality,
                resource=(0.0, cardinality * disk_per_tuple, 0.0),
                schema=tuple(schema))


def select(child: PlanNode, name: str = "", selectivity: float = 1.0,
           cost_per_tuple: float = 1e-9,
           expr: Optional[object] = None) -> Select:
    card_in = child.out_cardinality
    return Select(op="select", children=(child,), name=name,
                  selectivity=selectivity, cost_per_tuple=cost_per_tuple,
                  out_cardinality=card_in * selectivity,
                  resource=(card_in * cost_per_tuple, 0.0, 0.0), expr=expr)


def project(child: PlanNode, schema: Schema) -> Project:
    return Project(op="project", children=(child,), schema=tuple(schema),
                   out_cardinality=child.out_cardinality)


def udf(child: PlanNode, name: str, cost_per_tuple: float,
        selectivity: float = 1.0, deterministic: bool = True,
        cost_hint: Optional[Callable[[float], float]] = None,
        expr: Optional[object] = None, pinned: bool = False,
        schema: Schema = ()) -> Apply:
    card_in = child.out_cardinality
    per_tuple = cost_per_tuple
    if cost_hint is not None:
        # §5.1: the hint gives the shape; calibration fixes the coefficient.
        per_tuple = cost_per_tuple * cost_hint(card_in) / max(cost_hint(1.0),
                                                              1e-12)
    cpu = card_in * per_tuple
    if deterministic:
        # §5.1 caching: deterministic UDFs hit the cache for repeated values.
        # Model a calibrated 20% repeat rate.
        cpu *= 0.8
    return Apply(op="udf", children=(child,), name=name,
                 selectivity=selectivity, cost_per_tuple=per_tuple,
                 out_cardinality=card_in * selectivity,
                 resource=(cpu, 0.0, 0.0), deterministic=deterministic,
                 cost_hint=cost_hint, expr=expr, pinned=pinned,
                 schema=tuple(schema))


apply = udf  # typed-IR alias: applyFunction node


def rehash(child: PlanNode, net_per_tuple: float = 2e-8) -> Rehash:
    card = child.out_cardinality
    return Rehash(op="rehash", children=(child,), out_cardinality=card,
                  resource=(0.0, 0.0, card * net_per_tuple))


def join(left: PlanNode, right: PlanNode, selectivity: float = 1.0,
         key_fk: bool = False, cpu_per_tuple: float = 5e-9,
         schema: Schema = ()) -> Join:
    card = left.out_cardinality * max(right.out_cardinality, 1.0) * selectivity
    if key_fk:
        card = left.out_cardinality * selectivity
    cpu = (left.out_cardinality + right.out_cardinality) * cpu_per_tuple
    return Join(op="join", children=(left, right), selectivity=selectivity,
                out_cardinality=card, resource=(cpu, 0.0, 0.0),
                key_fk_join=key_fk, schema=tuple(schema))


def groupby(child: PlanNode, uda_name: str, n_groups: float,
            composable: bool = True, has_multiply: bool = False,
            cpu_per_tuple: float = 4e-9) -> GroupAggregate:
    return GroupAggregate(
        op="groupby", children=(child,), uda_name=uda_name,
        out_cardinality=n_groups, composable=composable,
        has_multiply=has_multiply,
        combiner=_UDA_COMBINERS.get(uda_name),
        resource=(child.out_cardinality * cpu_per_tuple, 0.0, 0.0))


def group_aggregate(child: PlanNode, key: str, combiner: str,
                    n_groups: float, composable: bool = True,
                    cpu_per_tuple: float = 4e-9) -> GroupAggregate:
    """Typed group-aggregate: group ``child`` rows by column ``key`` folding
    values with ``combiner`` (add|min|max)."""
    uda = {"add": "sum"}.get(combiner, combiner)
    return GroupAggregate(
        op="groupby", children=(child,), uda_name=uda, combiner=combiner,
        name=f"by:{key}", out_cardinality=n_groups, composable=True,
        schema=(key, "val"),
        resource=(child.out_cardinality * cpu_per_tuple, 0.0, 0.0))


def preagg(child: PlanNode, uda_name: str, reduction: float,
           cpu_per_tuple: float = 4e-9,
           combiner: Optional[str] = None) -> PreAggregate:
    """Combiner node (§5.2): shrinks cardinality by ``reduction`` before a
    rehash/join at the cost of one local aggregation pass."""
    return PreAggregate(
        op="preagg", children=(child,), uda_name=uda_name,
        combiner=combiner or _UDA_COMBINERS.get(uda_name),
        out_cardinality=child.out_cardinality * reduction,
        resource=(child.out_cardinality * cpu_per_tuple, 0.0, 0.0))


# ---------------------------------------------------------------------------
# Fixpoint construction + simulated-iteration cost estimate (§5.3, §6).
# ---------------------------------------------------------------------------

def _scale(v: ResourceVector, f: float) -> ResourceVector:
    return (v[0] * f, v[1] * f, v[2] * f)


def estimate_fixpoint(base: PlanNode, recursive: PlanNode, max_iters: int,
                      combiner: Optional[str],
                      step_selectivity: float = 1.0,
                      retraction_decay: float = 0.5
                      ) -> Tuple[ResourceVector, int]:
    """Simulated-iteration estimate of the strata BEYOND the first.

    Each stratum's cost is the recursive subplan scaled by |Δᵢ|/|Δ₀|.  For a
    monotone ``add`` accumulator there is no retraction: contributions only
    pile up, so the conservative §5.3 assumption is a non-shrinking frontier
    (|Δᵢ₊₁| = |Δᵢ| · step_selectivity, capped at 1.0) and the estimate runs
    the full ``max_iters``.  An idempotent combiner (min/max) takes the §6
    delta-retraction path: a delta superseded by a better value retracts,
    so the frontier decays at least geometrically
    (|Δᵢ₊₁| = |Δᵢ| · min(step_selectivity, retraction_decay)) and the
    simulation stops as soon as the frontier empties.

    Returns ``(extra_resource, iterations)`` where ``extra_resource``
    excludes the base scan and the first stratum (both already counted by
    :func:`total_resource` over the fixpoint's children).
    """
    step = total_resource(recursive)
    card0 = max(base.out_cardinality, 0.0)
    if combiner in IDEMPOTENT_COMBINERS:
        decay = min(step_selectivity, retraction_decay)
    else:
        decay = min(step_selectivity, 1.0)
    extra = (0.0, 0.0, 0.0)
    card = card0
    iters = 0
    for i in range(max_iters):
        if card < 1.0:
            break
        if i > 0:  # first stratum is already in total_resource(recursive)
            extra = sequential_combine(extra,
                                       _scale(step, card / max(card0, 1.0)))
        card *= decay
        iters += 1
    return extra, iters


def fixpoint(base: PlanNode, recursive: PlanNode, max_iters: int = 64,
             combiner: Optional[str] = None, step_selectivity: float = 1.0,
             retraction_decay: float = 0.5) -> Fixpoint:
    extra, iters = estimate_fixpoint(base, recursive, max_iters, combiner,
                                     step_selectivity, retraction_decay)
    return Fixpoint(op="fixpoint", children=(base, recursive),
                    out_cardinality=base.out_cardinality,
                    combiner=combiner, max_iters=max_iters,
                    estimated_iterations=iters, resource=extra,
                    schema=base.schema, name=f"fixpoint[{max_iters}]")


# ---------------------------------------------------------------------------
# Whole-plan aggregation.
# ---------------------------------------------------------------------------

def total_resource(node: PlanNode) -> ResourceVector:
    acc = node.resource
    for c in node.children:
        acc = sequential_combine(acc, total_resource(c))
    return acc


def plan_runtime(node: PlanNode, pipelined: bool = True) -> float:
    return runtime_of(total_resource(node), pipelined=pipelined)


def walk(node: PlanNode):
    """Pre-order traversal of a plan tree."""
    yield node
    for c in node.children:
        yield from walk(c)
