"""Logical plan IR for RQL-style queries (paper §3.2, §5).

A plan is a DAG of operators with per-operator cost metadata.  The optimizer
(core/optimizer.py) rewrites this IR: interleaving expensive UDFs with joins
by rank, pushing pre-aggregation below rehash/join, and estimating recursive
cost by simulated iteration.  Physical execution lowers plan nodes onto
core/operators.py (non-recursive) or a FixpointJob (recursive).

Costs follow the paper's model: per-operator (cpu, disk, net) *resource
vectors* (§5 "Accounting for CPU-I/O overlap") — combining two concurrent
subplans costs the max over each resource lane, not the sum.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

ResourceVector = Tuple[float, float, float]  # (cpu, disk, net) seconds


def overlap_combine(a: ResourceVector, b: ResourceVector) -> ResourceVector:
    """Paper §5: two pipelined subplans overlap; each resource lane is
    additive (both plans consume it), but the *runtime* is bounded by the
    busiest lane — see :func:`runtime_of`.  Combination is lane-wise sum."""
    return (a[0] + b[0], a[1] + b[1], a[2] + b[2])


def sequential_combine(a: ResourceVector, b: ResourceVector) -> ResourceVector:
    return (a[0] + b[0], a[1] + b[1], a[2] + b[2])


def runtime_of(v: ResourceVector, pipelined: bool = True) -> float:
    """Pipelined runtime = max lane (full overlap, §5's extreme case);
    non-pipelined = sum of lanes."""
    return max(v) if pipelined else sum(v)


@dataclasses.dataclass
class PlanNode:
    op: str                               # scan|select|udf|join|groupby|
    #                                       rehash|preagg|fixpoint
    children: Sequence["PlanNode"] = ()
    # --- statistics / calibration --------------------------------------
    out_cardinality: float = 0.0          # estimated output rows
    selectivity: float = 1.0              # rows_out / rows_in   (select/udf)
    cost_per_tuple: float = 0.0           # cpu seconds per input row (udf)
    resource: ResourceVector = (0.0, 0.0, 0.0)
    # --- semantic flags --------------------------------------------------
    name: str = ""
    uda_name: Optional[str] = None        # groupby/preagg: which aggregator
    composable: bool = True               # §5.2 — can pre-agg cross any join
    key_fk_join: bool = False             # join on key–foreign-key?
    has_multiply: bool = False            # §5.2 multiplicative compensation
    deterministic: bool = True            # UDF caching eligibility (§5.1)
    volatile: bool = False
    cost_hint: Optional[Callable[[float], float]] = None  # §5.1 "big-O" hints

    def rank(self) -> float:
        """Predicate-migration rank (paper §5.1, after [13]):
        cost-per-tuple / (1 - selectivity).  Lower rank ⇒ apply earlier:
        cheap predicates and highly selective predicates come first."""
        drop = 1.0 - min(self.selectivity, 1.0 - 1e-9)
        return self.cost_per_tuple / drop

    def clone(self, **overrides) -> "PlanNode":
        return dataclasses.replace(self, **overrides)


def scan(name: str, cardinality: float, disk_per_tuple: float = 1e-8
         ) -> PlanNode:
    return PlanNode(op="scan", name=name, out_cardinality=cardinality,
                    resource=(0.0, cardinality * disk_per_tuple, 0.0))


def udf(child: PlanNode, name: str, cost_per_tuple: float,
        selectivity: float = 1.0, deterministic: bool = True,
        cost_hint: Optional[Callable[[float], float]] = None) -> PlanNode:
    card_in = child.out_cardinality
    per_tuple = cost_per_tuple
    if cost_hint is not None:
        # §5.1: the hint gives the shape; calibration fixes the coefficient.
        per_tuple = cost_per_tuple * cost_hint(card_in) / max(cost_hint(1.0),
                                                              1e-12)
    cpu = card_in * per_tuple
    if deterministic:
        # §5.1 caching: deterministic UDFs hit the cache for repeated values.
        # Model a calibrated 20% repeat rate.
        cpu *= 0.8
    return PlanNode(op="udf", children=(child,), name=name,
                    selectivity=selectivity, cost_per_tuple=per_tuple,
                    out_cardinality=card_in * selectivity,
                    resource=(cpu, 0.0, 0.0), deterministic=deterministic,
                    cost_hint=cost_hint)


def rehash(child: PlanNode, net_per_tuple: float = 2e-8) -> PlanNode:
    card = child.out_cardinality
    return PlanNode(op="rehash", children=(child,), out_cardinality=card,
                    resource=(0.0, 0.0, card * net_per_tuple))


def join(left: PlanNode, right: PlanNode, selectivity: float = 1.0,
         key_fk: bool = False, cpu_per_tuple: float = 5e-9) -> PlanNode:
    card = left.out_cardinality * max(right.out_cardinality, 1.0) * selectivity
    if key_fk:
        card = left.out_cardinality * selectivity
    cpu = (left.out_cardinality + right.out_cardinality) * cpu_per_tuple
    return PlanNode(op="join", children=(left, right), selectivity=selectivity,
                    out_cardinality=card, resource=(cpu, 0.0, 0.0),
                    key_fk_join=key_fk)


def groupby(child: PlanNode, uda_name: str, n_groups: float,
            composable: bool = True, has_multiply: bool = False,
            cpu_per_tuple: float = 4e-9) -> PlanNode:
    return PlanNode(op="groupby", children=(child,), uda_name=uda_name,
                    out_cardinality=n_groups, composable=composable,
                    has_multiply=has_multiply,
                    resource=(child.out_cardinality * cpu_per_tuple, 0.0, 0.0))


def preagg(child: PlanNode, uda_name: str, reduction: float,
           cpu_per_tuple: float = 4e-9) -> PlanNode:
    """Combiner node (§5.2): shrinks cardinality by ``reduction`` before a
    rehash/join at the cost of one local aggregation pass."""
    return PlanNode(op="preagg", children=(child,), uda_name=uda_name,
                    out_cardinality=child.out_cardinality * reduction,
                    resource=(child.out_cardinality * cpu_per_tuple, 0.0, 0.0))


def fixpoint(base: PlanNode, recursive: PlanNode, max_iters: int = 64
             ) -> PlanNode:
    return PlanNode(op="fixpoint", children=(base, recursive),
                    out_cardinality=base.out_cardinality,
                    resource=(0.0, 0.0, 0.0),
                    name=f"fixpoint[{max_iters}]")


def total_resource(node: PlanNode) -> ResourceVector:
    acc = node.resource
    for c in node.children:
        acc = sequential_combine(acc, total_resource(c))
    return acc


def plan_runtime(node: PlanNode, pipelined: bool = True) -> float:
    return runtime_of(total_resource(node), pipelined=pipelined)
