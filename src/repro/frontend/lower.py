"""Lowering: optimized logical plan → the five ``DeltaAlgorithm`` callables.

``compile_program`` runs the full frontend pipeline

    Program ──planner──▶ plan IR ──optimizer──▶ optimized IR ──lower──▶
    CompiledProgram (DeltaAlgorithm factory + initial state + value view)

and the resulting algorithm plugs into ``core/engine.py:ShardedExecutor``
unchanged — compiled programs inherit the capacity ladder (``emit_factory``),
route_strategy dispatch, the resilient driver and observability for free.

The generic recursive state is the pair ``(store, sent)``:

  * ``store`` — the aggregation-head relation (one f32 per vertex), seeded
    from the combiner identity, then the ``:=`` initializer / ground facts;
  * ``sent`` — the *value* each vertex last propagated, in value space
    (``value = view(store)`` when the program defines a view, else the
    store itself).

Per combiner the stratum semantics follow the handwritten algorithms
exactly (and are property-tested bit-identical to them):

  * ``add`` — a vertex is active when ``|value − sent|`` exceeds the
    program threshold; the emitted term is evaluated on the *retained
    delta* ``value − sent`` (sound because we require the term to be
    homogeneous-linear in the recursive relation: ``T(a) − T(b) = T(a−b)``);
    receivers fold with ``+``; dense strata re-derive and REPLACE.
  * ``min`` / ``max`` (idempotent) — active when the value improved since
    last send; the term is evaluated on the value itself and folded with
    minimum/maximum; superseded deltas simply lose the fold (paper §6).

The shard-local relational steps route through ``core/operators.py`` Table
ops (``applyFunction`` for the view and the rule term, ``select`` for the
Δ-activity predicate); emission/routing reuses ``algorithms/emission.py``
like every handwritten algorithm does.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.algorithms import emission
from repro.core import operators
from repro.core import plan as P
from repro.core.delta import DeltaBuffer
from repro.core.engine import DeltaAlgorithm, ShardedExecutor
from repro.core.fixpoint import FixpointResult
from repro.core.optimizer import CostModel, optimize
from repro.core.partition import PartitionSnapshot
from repro.frontend import expr as E
from repro.frontend.planner import GraphStats, plan_program
from repro.frontend.rules import FrontendError, Program

_IDENTITY = {"add": 0.0, "min": float("inf"), "max": float("-inf")}


def _as_col(val, like: jax.Array) -> jax.Array:
    """Coerce a scalar term result (constant-only rule) to a column; leave
    array results untouched so the compiled arithmetic stays token-identical
    to the handwritten algorithms."""
    if getattr(val, "shape", None) == like.shape:
        return val
    return jnp.broadcast_to(jnp.asarray(val, like.dtype), like.shape)


@dataclasses.dataclass(frozen=True)
class LoweredSpec:
    """Everything lowering needs, extracted from the *optimized* plan."""

    combiner: str                 # add | min | max
    threshold: float              # add-combiner convergence threshold
    head: str                     # aggregation-head relation (the store)
    value_rel: str                # relation the rule term references
    term: E.Expr                  # scalar rule term (in value space)
    view: Optional[E.Expr]        # value = view(store), None = identity


def _extract_spec(program: Program, optimized: P.PlanNode) -> LoweredSpec:
    if optimized.op != "fixpoint":
        raise FrontendError("optimized plan root must be a fixpoint node")
    rule = program.rules[0]
    combiner = optimized.combiner
    if combiner not in ("add", "min", "max"):
        raise FrontendError(f"fixpoint combiner {combiner!r} is not lowerable")

    view_expr = None
    view_rel = None
    term_expr = None
    for node in P.walk(optimized):
        if node.op != "udf" or node.expr is None:
            continue
        if node.name.startswith("view:"):
            view_expr, view_rel = node.expr, node.name[len("view:"):]
        elif node.name == "term":
            term_expr = node.expr
    if term_expr is None:
        raise FrontendError("optimized plan lost the rule-term UDF")

    value_rel = view_rel if view_expr is not None else rule.head

    # --- semantic validation (what this lowering can and cannot express) ---
    if view_expr is not None and combiner in P.IDEMPOTENT_COMBINERS:
        raise NotImplementedError(
            f"a value view over an idempotent ({combiner}) head is not "
            "supported: min/max propagate the store itself")
    bad = {r.rel for r in E.refs(term_expr)} - {value_rel, "deg"}
    if bad:
        raise FrontendError(
            f"rule term may only reference {value_rel!r} and deg(); "
            f"got {sorted(bad)}")
    if combiner == "add" and E.degree_in(term_expr, {value_rel}) != 1:
        raise FrontendError(
            f"add-aggregation term must be homogeneous-linear in "
            f"{value_rel!r} (T(a) - T(b) = T(a - b)) for the delta rewrite "
            "to be sound; rewrite constants into a view "
            "(e.g. PageRank: acc(v) add= rank(u)/deg(u), "
            "rank(v) = 0.15 + 0.85 * acc(v))")
    if view_expr is not None:
        bad = {r.rel for r in E.refs(view_expr)} - {rule.head}
        if bad:
            raise FrontendError(
                f"view may only reference the aggregation head "
                f"{rule.head!r}; got {sorted(bad)}")
    for init in program.inits:
        if init.rel != rule.head:
            raise FrontendError(
                f"initializer for {init.rel!r} does not seed the "
                f"aggregation head {rule.head!r}")
        bad = {r.rel for r in E.refs(init.expr)} - {"id"}
        if bad:
            raise FrontendError(
                f"initializer may only reference id(); got {sorted(bad)}")
    for fact in program.facts:
        if fact.rel != rule.head:
            raise FrontendError(
                f"fact for {fact.rel!r} does not seed the aggregation "
                f"head {rule.head!r}")
        if fact.key < 0:
            raise FrontendError(f"fact key must be non-negative: {fact.key}")

    return LoweredSpec(combiner=combiner, threshold=program.threshold,
                       head=rule.head, value_rel=value_rel, term=term_expr,
                       view=view_expr)


@dataclasses.dataclass(frozen=True)
class CompiledProgram:
    """A rule program carried through plan → optimize → lower."""

    program: Program
    logical: P.Fixpoint           # planner output (pre-optimization)
    optimized: P.PlanNode         # optimizer output (what lowering consumed)
    spec: LoweredSpec

    @property
    def combiner(self) -> str:
        return self.spec.combiner

    # ------------------------------------------------------------------
    # Value view (store space -> user-visible value space).
    # ------------------------------------------------------------------
    def _view_of(self, store: jax.Array) -> jax.Array:
        spec = self.spec
        if spec.view is None:
            return store
        tbl = operators.apply_function(
            operators.Table.from_columns(store=store),
            lambda s: {"cur": E.evaluate(spec.view, {spec.head: s})},
            ("store",))
        return tbl.column("cur")

    def values(self, state) -> jax.Array:
        """User-visible per-vertex values from an executor state."""
        store = state[0]
        if self.spec.view is None:
            return store.reshape(-1)
        return E.evaluate(self.spec.view,
                          {self.spec.head: store}).reshape(-1)

    # ------------------------------------------------------------------
    # Initial state.
    # ------------------------------------------------------------------
    def initial_state(self, snapshot: PartitionSnapshot
                      ) -> Tuple[jax.Array, jax.Array]:
        S, block = snapshot.num_shards, snapshot.block_size
        fill = _IDENTITY[self.spec.combiner]
        if fill == 0.0:
            store = jnp.zeros((S, block), jnp.float32)
        else:
            store = jnp.full((S, block), fill, jnp.float32)
        init = self.program.init_for(self.spec.head)
        if init is not None:
            ids = jnp.arange(S * block, dtype=jnp.float32).reshape(S, block)
            store = _as_col(E.evaluate(init.expr, {"id": ids}), store)
        for fact in self.program.facts_for(self.spec.head):
            store = store.at[fact.key // block,
                             fact.key % block].set(fact.value)
        sent = jnp.full((S, block), fill, jnp.float32)
        return store, sent

    # ------------------------------------------------------------------
    # DeltaAlgorithm emission.
    # ------------------------------------------------------------------
    def make_algorithm(self, snapshot: PartitionSnapshot,
                       src_capacity: int = 1024, edge_capacity: int = 16384
                       ) -> DeltaAlgorithm:
        spec = self.spec
        block = snapshot.block_size
        combiner = spec.combiner
        threshold = spec.threshold
        fill = _IDENTITY[combiner]
        view_of = self._view_of

        if combiner == "add":
            def activity(t):
                return jnp.abs(t.column("cur") - t.column("sent")) > threshold
        elif combiner == "min":
            def activity(t):
                return t.column("cur") < t.column("sent")
        else:
            def activity(t):
                return t.column("cur") > t.column("sent")

        def active_mask(cur, sent):
            tbl = operators.Table.from_columns(cur=cur, sent=sent)
            return operators.select(tbl, activity).valid

        def next_count(store, sent):
            return jnp.sum(active_mask(view_of(store), sent)
                           .astype(jnp.int32))

        def term_payload(value_col, deg):
            tbl = operators.apply_function(
                operators.Table.from_columns(value=value_col, deg=deg),
                lambda v, d: {"payload": _as_col(
                    E.evaluate(spec.term, {spec.value_rel: v, "deg": d}), v)},
                ("value", "deg"))
            return tbl.column("payload")

        def active_fn(state, graph):
            store, sent = state
            active = active_mask(view_of(store), sent)
            est_edges = jnp.sum(jnp.where(active, graph.out_degree, 0))
            return active, est_edges

        def make_sparse_emit(src_cap: int, edge_cap: int):
            def sparse_emit(state, graph, active, stratum, shard_id):
                store, sent = state
                cur = view_of(store)
                deg = jnp.maximum(graph.out_degree, 1).astype(cur.dtype)
                # add: emit the retained delta (cur − sent) through the
                # (homogeneous-linear) term; idempotent: emit the value.
                value_col = cur - sent if combiner == "add" else cur
                payload = jnp.where(active, term_payload(value_col, deg),
                                    fill)
                out = emission.emit_over_edges(graph, active, payload,
                                               src_cap, edge_cap)
                new_sent = jnp.where(active, cur, sent)
                return (store, new_sent), out
            return sparse_emit

        def dense_emit(state, graph, stratum, shard_id):
            store, sent = state
            cur = view_of(store)
            deg = jnp.maximum(graph.out_degree, 1).astype(cur.dtype)
            dst, pay = emission.dense_push(graph, term_payload(cur, deg))
            n_padded = snapshot.padded_keys
            slot = jnp.where(dst >= 0, dst, n_padded)
            if combiner == "add":
                contrib = jnp.zeros((n_padded + 1,), pay.dtype).at[
                    slot].add(pay, mode="drop")[:n_padded]
            elif combiner == "min":
                # dense_push zeroes invalid payload slots; refill identity.
                pay = jnp.where(dst >= 0, pay, jnp.inf)
                contrib = jnp.full((n_padded + 1,), jnp.inf, pay.dtype).at[
                    slot].min(pay, mode="drop")[:n_padded]
            else:
                pay = jnp.where(dst >= 0, pay, -jnp.inf)
                contrib = jnp.full((n_padded + 1,), -jnp.inf, pay.dtype).at[
                    slot].max(pay, mode="drop")[:n_padded]
            return (store, cur), contrib[:, None]

        def apply_sparse(state, incoming: DeltaBuffer, graph, stratum,
                         shard_id):
            store, sent = state
            inc = emission.scatter_local(incoming, shard_id, block, combiner)
            if combiner == "add":
                store = store + inc
            elif combiner == "min":
                store = jnp.minimum(store, inc)
            else:
                store = jnp.maximum(store, inc)
            return (store, sent), next_count(store, sent)

        def apply_dense(state, incoming, graph, stratum, shard_id):
            store, sent = state
            if combiner == "add":   # dense strata re-derive: REPLACE
                store = incoming[:, 0]
            elif combiner == "min":
                store = jnp.minimum(store, incoming[:, 0])
            else:
                store = jnp.maximum(store, incoming[:, 0])
            return (store, sent), next_count(store, sent)

        return DeltaAlgorithm(
            active_fn=active_fn,
            sparse_emit=make_sparse_emit(src_capacity, edge_capacity),
            dense_emit=dense_emit, apply_sparse=apply_sparse,
            apply_dense=apply_dense, combiner=combiner, payload_width=1,
            bytes_per_delta=8, emit_factory=make_sparse_emit)

    # ------------------------------------------------------------------
    # End-to-end driver (mirrors algorithms/*.run).
    # ------------------------------------------------------------------
    def run(self, graph_sharded, snapshot: PartitionSnapshot,
            mode: str = "delta", max_iters: int = 64,
            executor: Optional[ShardedExecutor] = None,
            src_capacity: int = 1024, edge_capacity: int = 16384,
            ladder_tiers: int = 1, route_strategy: str = "sort"
            ) -> Tuple[jax.Array, FixpointResult]:
        algo = self.make_algorithm(snapshot, src_capacity, edge_capacity)
        if executor is None:
            executor = ShardedExecutor(
                snapshot=snapshot, seg_capacity=edge_capacity,
                edge_capacity=edge_capacity, src_capacity=src_capacity,
                ladder_tiers=ladder_tiers, route_strategy=route_strategy)
        state0 = self.initial_state(snapshot)
        live0 = executor.live_count(algo, state0, graph_sharded)
        res = executor.run(algo, state0, live0, graph_sharded, max_iters,
                           mode=mode)
        return self.values(res.state), res


def compile_program(program: Program, stats: Optional[GraphStats] = None,
                    cost_model: Optional[CostModel] = None,
                    preagg_reduction: float = 0.1) -> CompiledProgram:
    """Plan, optimize and lower a rule program."""
    logical = plan_program(program, stats=stats, cost_model=cost_model)
    optimized = optimize(logical, preagg_reduction=preagg_reduction,
                         cost_model=cost_model)
    spec = _extract_spec(program, optimized)
    return CompiledProgram(program=program, logical=logical,
                           optimized=optimized, spec=spec)
