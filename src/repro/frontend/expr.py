"""Scalar expression DSL for rule terms (frontend layer).

An :class:`Expr` is a tiny arithmetic AST over relation references
(``rank(u)``), builtin vertex attributes (``deg(u)``, ``id(u)``) and float
constants, with ``+ - * /``.  The same AST serves three masters:

  * the **builder API** (operator overloading: ``0.15 + 0.85 * ref("acc")``),
  * the **text grammar** (rendering via :func:`to_text` round-trips exactly
    through ``frontend.parser``),
  * the **lowering** (:func:`evaluate` maps it over jax arrays per shard —
    python-float constants keep jax weak typing, so the emitted arithmetic
    is token-identical to the hand-written algorithms).

For ``add``-combiner rules the emission rewrite substitutes the recursive
reference with the *retained delta* (cur − sent); that rewrite is only sound
when the term is homogeneous-linear in the recursive relation —
:func:`degree_in` checks this structurally (degree 0, 1, or None=nonlinear).
"""
from __future__ import annotations

import dataclasses
import operator as _operator
from typing import Callable, Mapping, Optional, Set

#: builtin per-vertex attributes usable in terms: out-degree (clamped ≥1,
#: as the handwritten algorithms do) and the global vertex id.
BUILTINS = ("deg", "id")

_OPS: Mapping[str, Callable] = {"+": _operator.add, "-": _operator.sub,
                                "*": _operator.mul, "/": _operator.truediv}
_PREC = {"+": 1, "-": 1, "*": 2, "/": 2}


class Expr:
    """Base expression; subclasses are frozen dataclasses (structural ==)."""

    def __add__(self, o): return BinOp("+", self, wrap(o))

    def __radd__(self, o): return BinOp("+", wrap(o), self)

    def __sub__(self, o): return BinOp("-", self, wrap(o))

    def __rsub__(self, o): return BinOp("-", wrap(o), self)

    def __mul__(self, o): return BinOp("*", self, wrap(o))

    def __rmul__(self, o): return BinOp("*", wrap(o), self)

    def __truediv__(self, o): return BinOp("/", self, wrap(o))

    def __rtruediv__(self, o): return BinOp("/", wrap(o), self)

    def __neg__(self):
        if isinstance(self, Const):
            return Const(-self.value)
        return BinOp("-", Const(0.0), self)


def wrap(x) -> Expr:
    if isinstance(x, Expr):
        return x
    if isinstance(x, (int, float)):
        return Const(float(x))
    raise TypeError(f"cannot use {type(x).__name__} in a rule expression")


@dataclasses.dataclass(frozen=True)
class Const(Expr):
    value: float


@dataclasses.dataclass(frozen=True)
class Ref(Expr):
    """Reference to relation ``rel`` at variable ``var`` (``rank(u)``).

    ``var=None`` means "the context variable" — the builder normalizes it
    to the enclosing rule's source / view's head variable at build()."""

    rel: str
    var: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class BinOp(Expr):
    op: str     # + - * /
    lhs: Expr
    rhs: Expr


def ref(rel: str, var: Optional[str] = None) -> Ref:
    return Ref(rel, var)


def deg(var: Optional[str] = None) -> Ref:
    return Ref("deg", var)


def vid(var: Optional[str] = None) -> Ref:
    """The global vertex id builtin (text form ``id(v)``)."""
    return Ref("id", var)


# ---------------------------------------------------------------------------
# Structural tools.
# ---------------------------------------------------------------------------

def refs(expr: Expr) -> Set[Ref]:
    if isinstance(expr, Ref):
        return {expr}
    if isinstance(expr, BinOp):
        return refs(expr.lhs) | refs(expr.rhs)
    return set()


def transform(expr: Expr, fn: Callable[[Ref], Expr]) -> Expr:
    """Rebuild ``expr`` with every Ref replaced by ``fn(ref)``."""
    if isinstance(expr, Ref):
        return fn(expr)
    if isinstance(expr, BinOp):
        return BinOp(expr.op, transform(expr.lhs, fn),
                     transform(expr.rhs, fn))
    return expr


def degree_in(expr: Expr, rels: Set[str]) -> Optional[int]:
    """Polynomial degree of ``expr`` in references to ``rels``: 0 (does not
    depend), 1 (homogeneous linear), or None (nonlinear / non-homogeneous
    affine — ``T(a) − T(b) ≠ T(a − b)``, so the delta rewrite is unsound)."""
    if isinstance(expr, Const):
        return 0
    if isinstance(expr, Ref):
        return 1 if expr.rel in rels else 0
    if isinstance(expr, BinOp):
        dl = degree_in(expr.lhs, rels)
        dr = degree_in(expr.rhs, rels)
        if dl is None or dr is None:
            return None
        if expr.op in ("+", "-"):
            return dl if dl == dr else None
        if expr.op == "*":
            d = dl + dr
            return d if d <= 1 else None
        if expr.op == "/":
            return dl if dr == 0 else None
    return None


def is_linear_in(expr: Expr, rels: Set[str]) -> bool:
    return degree_in(expr, rels) == 1


# ---------------------------------------------------------------------------
# Evaluation (host numpy or traced jax arrays — pure jnp/python arithmetic).
# ---------------------------------------------------------------------------

def evaluate(expr: Expr, env: Mapping[str, object]):
    """Evaluate with relation/builtin names bound to arrays (or floats).

    Constants stay python floats so jax weak typing matches the handwritten
    algorithms bit-for-bit."""
    if isinstance(expr, Const):
        return expr.value
    if isinstance(expr, Ref):
        try:
            return env[expr.rel]
        except KeyError:
            raise KeyError(f"no binding for relation {expr.rel!r} "
                           f"(have: {sorted(env)})") from None
    if isinstance(expr, BinOp):
        return _OPS[expr.op](evaluate(expr.lhs, env), evaluate(expr.rhs, env))
    raise TypeError(f"not an expression: {expr!r}")


# ---------------------------------------------------------------------------
# Rendering (exact round-trip through frontend.parser).
# ---------------------------------------------------------------------------

def to_text(expr: Expr) -> str:
    if isinstance(expr, Const):
        return repr(expr.value)
    if isinstance(expr, Ref):
        return f"{expr.rel}({expr.var or '_'})"
    if isinstance(expr, BinOp):
        p = _PREC[expr.op]
        lhs = to_text(expr.lhs)
        rhs = to_text(expr.rhs)
        if isinstance(expr.lhs, BinOp) and _PREC[expr.lhs.op] < p:
            lhs = f"({lhs})"
        # All operators parse left-associative: parenthesize a right child of
        # equal precedence so the tree (not just the value) round-trips.
        if isinstance(expr.rhs, BinOp) and _PREC[expr.rhs.op] <= p:
            rhs = f"({rhs})"
        return f"{lhs} {expr.op} {rhs}"
    raise TypeError(f"not an expression: {expr!r}")
