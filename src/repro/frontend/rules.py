"""Rule-program AST + builder API (frontend layer).

A :class:`Program` is a small Datalog-ish recursive program over one binary
edge relation: base facts / all-vertex initializers seed a recursive head
relation, one aggregation rule (``add``/``min``/``max`` head) propagates a
scalar UDF term along edges, and an optional *view* maps the aggregation
state to the user-visible value (PageRank's ``rank = 0.15 + 0.85·acc``).

Statement forms (text grammar in frontend/parser.py):

    program pagerank.                          # name
    threshold 0.001.                           # convergence threshold (add)
    input edge(u, v).                          # EDB declaration
    label(v) := id(v).                         # all-vertex initializer
    dist(0) := 0.0.                            # ground fact at key 0
    rank(v) = 0.15 + 0.85 * acc(v).            # view over the agg head
    acc(v) add= rank(u) / deg(u) :- edge(u, v).  # recursive aggregation rule

Everything is a frozen dataclass: programs compare structurally, so
``parse(p.to_text()) == p`` is exact (constants render via ``repr`` which
round-trips floats losslessly).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.frontend import expr as E

AGGREGATORS = ("add", "min", "max")


class FrontendError(ValueError):
    """Invalid or unsupported rule program."""


@dataclasses.dataclass(frozen=True)
class InputDecl:
    name: str
    fields: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Fact:
    """Ground base fact: ``rel(key) := value.``"""

    rel: str
    key: int
    value: float


@dataclasses.dataclass(frozen=True)
class InitRule:
    """All-vertex initializer: ``rel(v) := expr.`` (builtins + consts)."""

    rel: str
    var: str
    expr: E.Expr


@dataclasses.dataclass(frozen=True)
class View:
    """Value view over the aggregation head: ``rel(v) = expr.``"""

    rel: str
    var: str
    expr: E.Expr


@dataclasses.dataclass(frozen=True)
class RecursiveRule:
    """``head(dst) agg= term :- edge(src, dst).``"""

    head: str
    var: str          # the head/destination variable
    agg: str          # add | min | max
    term: E.Expr      # scalar UDF over src-variable references
    edge: str
    src: str
    dst: str


@dataclasses.dataclass(frozen=True)
class Program:
    name: str = "program"
    threshold: float = 1e-3
    inputs: Tuple[InputDecl, ...] = ()
    inits: Tuple[InitRule, ...] = ()
    facts: Tuple[Fact, ...] = ()
    views: Tuple[View, ...] = ()
    rules: Tuple[RecursiveRule, ...] = ()

    # -- introspection helpers -------------------------------------------
    def input_named(self, name: str) -> Optional[InputDecl]:
        for i in self.inputs:
            if i.name == name:
                return i
        return None

    def view_for(self, rel: str) -> Optional[View]:
        for v in self.views:
            if E.refs(v.expr) and any(r.rel == rel for r in E.refs(v.expr)):
                return v
        return None

    def init_for(self, rel: str) -> Optional[InitRule]:
        for i in self.inits:
            if i.rel == rel:
                return i
        return None

    def facts_for(self, rel: str) -> Tuple[Fact, ...]:
        return tuple(f for f in self.facts if f.rel == rel)

    # -- rendering --------------------------------------------------------
    def to_text(self) -> str:
        lines: List[str] = [f"program {self.name}.",
                            f"threshold {self.threshold!r}."]
        for i in self.inputs:
            lines.append(f"input {i.name}({', '.join(i.fields)}).")
        for r in self.inits:
            lines.append(f"{r.rel}({r.var}) := {E.to_text(r.expr)}.")
        for f in self.facts:
            lines.append(f"{f.rel}({f.key}) := {f.value!r}.")
        for v in self.views:
            lines.append(f"{v.rel}({v.var}) = {E.to_text(v.expr)}.")
        for r in self.rules:
            lines.append(f"{r.head}({r.var}) {r.agg}= {E.to_text(r.term)} "
                         f":- {r.edge}({r.src}, {r.dst}).")
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# Builder.
# ---------------------------------------------------------------------------

def _normalize(expr: E.Expr, default_var: str, context: str) -> E.Expr:
    """Fill in ``var=None`` references and validate variable usage."""
    def fix(r: E.Ref) -> E.Expr:
        var = r.var or default_var
        if var != default_var:
            raise FrontendError(
                f"{context}: reference {r.rel}({var}) uses variable "
                f"{var!r}; only {default_var!r} is in scope")
        return E.Ref(r.rel, var)
    return E.transform(expr, fix)


class ProgramBuilder:
    """Chainable builder mirroring the text grammar one statement per call."""

    def __init__(self, name: str = "program"):
        self._name = name
        self._threshold = 1e-3
        self._inputs: List[InputDecl] = []
        self._inits: List[InitRule] = []
        self._facts: List[Fact] = []
        self._views: List[View] = []
        self._rules: List[RecursiveRule] = []

    def input(self, name: str, *fields: str) -> "ProgramBuilder":
        self._inputs.append(InputDecl(name, tuple(fields)))
        return self

    def threshold(self, value: float) -> "ProgramBuilder":
        self._threshold = float(value)
        return self

    def fact(self, rel: str, key: int, value: float) -> "ProgramBuilder":
        self._facts.append(Fact(rel, int(key), float(value)))
        return self

    def init(self, rel: str, expr, var: str = "v") -> "ProgramBuilder":
        self._inits.append(InitRule(rel, var, E.wrap(expr)))
        return self

    def view(self, rel: str, expr, var: str = "v") -> "ProgramBuilder":
        self._views.append(View(rel, var, E.wrap(expr)))
        return self

    def rule(self, head: str, agg: str, term,
             edge: Optional[Tuple[str, str, str]] = None,
             var: str = "v", src: str = "u") -> "ProgramBuilder":
        if edge is None:
            binary = [i for i in self._inputs if len(i.fields) == 2]
            if not binary:
                raise FrontendError(
                    "rule() needs an edge: declare a binary input first or "
                    "pass edge=(name, src, dst)")
            edge = (binary[0].name, src, var)
        name, esrc, edst = edge
        self._rules.append(RecursiveRule(
            head=head, var=edst, agg=agg, term=E.wrap(term),
            edge=name, src=esrc, dst=edst))
        return self

    def build(self) -> Program:
        if self._threshold <= 0:
            raise FrontendError("threshold must be positive")
        inits = tuple(InitRule(r.rel, r.var,
                               _normalize(r.expr, r.var, f"init {r.rel}"))
                      for r in self._inits)
        views = tuple(View(v.rel, v.var,
                           _normalize(v.expr, v.var, f"view {v.rel}"))
                      for v in self._views)
        rules = []
        for r in self._rules:
            if r.agg not in AGGREGATORS:
                raise FrontendError(
                    f"unknown aggregator {r.agg!r} (use one of "
                    f"{'/'.join(AGGREGATORS)})")
            decl = None
            for i in self._inputs:
                if i.name == r.edge:
                    decl = i
            if decl is None or len(decl.fields) != 2:
                raise FrontendError(
                    f"rule over {r.edge!r}: no binary input of that name "
                    "is declared")
            rules.append(RecursiveRule(
                head=r.head, var=r.var, agg=r.agg,
                term=_normalize(r.term, r.src, f"rule {r.head}"),
                edge=r.edge, src=r.src, dst=r.dst))
        seen: Dict[str, str] = {}
        for kind, rels in (("init", [i.rel for i in inits]),
                           ("view", [v.rel for v in views])):
            for rel in rels:
                if rel in seen:
                    raise FrontendError(
                        f"{rel!r} defined by both {seen[rel]} and {kind}")
                seen[rel] = kind
        return Program(name=self._name, threshold=self._threshold,
                       inputs=tuple(self._inputs), inits=inits,
                       facts=tuple(self._facts), views=views,
                       rules=tuple(rules))
