"""Declarative recursive-rule frontend (Datalog-ish programs → engine).

Pipeline: rules (builder API or text) → typed logical-plan IR
(core/plan.py) → optimizer rewrites (core/optimizer.py) → lowering to
``DeltaAlgorithm`` callables (frontend/lower.py) executed by the unchanged
``ShardedExecutor``.
"""
from repro.frontend.expr import BinOp, Const, Expr, Ref, deg, ref, vid
from repro.frontend.lower import (CompiledProgram, LoweredSpec,
                                  compile_program)
from repro.frontend.parser import ParseError, parse_program
from repro.frontend.planner import GraphStats, plan_program
from repro.frontend.programs import (CC_TEXT, PAGERANK_TEXT,
                                     REACHABILITY_TEXT, SSSP_TEXT,
                                     cc_program, pagerank_program,
                                     reachability_program, sssp_program)
from repro.frontend.rules import (AGGREGATORS, Fact, FrontendError, InitRule,
                                  InputDecl, Program, ProgramBuilder,
                                  RecursiveRule, View)

__all__ = [
    "AGGREGATORS", "BinOp", "CC_TEXT", "CompiledProgram", "Const", "Expr",
    "Fact", "FrontendError", "GraphStats", "InitRule", "InputDecl",
    "LoweredSpec", "PAGERANK_TEXT", "ParseError", "Program",
    "ProgramBuilder", "REACHABILITY_TEXT", "RecursiveRule", "Ref",
    "SSSP_TEXT", "View", "cc_program", "compile_program", "deg",
    "pagerank_program", "parse_program", "plan_program",
    "reachability_program", "ref", "sssp_program", "vid",
]
