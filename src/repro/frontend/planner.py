"""Planner: rule :class:`Program` → typed logical-plan IR (core/plan.py).

One recursive aggregation rule becomes the canonical REX plan shape:

    fixpoint[combiner]
    ├── scan(head)                                  # base facts / inits
    └── group_aggregate[combiner, by dst]           # fold into head state
        └── rehash(dst)                             # ship deltas to owners
            └── project(dst, val)
                └── udf[term]                       # scalar rule term
                    └── join(Δhead ⋈ edge)          # key–fk, fan-out = deg
                        ├── udf[view]               # optional value view
                        │   └── select[active]      # |Δ| under threshold gate
                        │       └── scan(Δhead)
                        └── scan(edge)

The frontend-semantic UDF nodes (``view:*`` and ``term``) are *pinned*: the
optimizer's rank-based interleaving must not float them across the join —
the view feeds the term, and both define what the program computes.  The
optimizer still rewrites everything else: pre-aggregation pushes below the
rehash (sender-side combining, paper §5.2), and the fixpoint estimate picks
the delta-retraction path for idempotent combiners (§6).

Statistics come from :class:`GraphStats` (defaults model the paper's mid-size
graphs) and the cost coefficients from ``optimizer.CostModel`` — pass one
built via ``CostModel.from_route_table`` to cost plans with *measured*
per-tuple route costs (obs/calibrate.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import plan as P
from repro.core.optimizer import DEFAULT_COST_MODEL, CostModel
from repro.frontend.rules import FrontendError, Program

#: CPU seconds per tuple for a scalar arithmetic UDF (a handful of flops).
_SCALAR_UDF_COST = 2e-9


@dataclasses.dataclass(frozen=True)
class GraphStats:
    """Planner statistics for the (single) edge input."""

    n_vertices: float = 1e5
    avg_degree: float = 16.0
    #: expected fraction of vertices active per stratum (|Δ| / |V|).
    delta_fraction: float = 0.25


def plan_program(program: Program, stats: Optional[GraphStats] = None,
                 cost_model: Optional[CostModel] = None) -> P.Fixpoint:
    """Build the logical plan for ``program`` (one recursive rule)."""
    stats = stats or GraphStats()
    cm = cost_model or DEFAULT_COST_MODEL
    if len(program.rules) != 1:
        raise NotImplementedError(
            f"planner supports exactly one recursive rule, got "
            f"{len(program.rules)} (multi-rule stratification is not "
            "implemented)")
    rule = program.rules[0]
    view = program.view_for(rule.head)

    V = stats.n_vertices
    E = V * stats.avg_degree

    base = P.scan(rule.head, V, disk_per_tuple=cm.scan_disk_per_tuple,
                  schema=(rule.dst, "val"))

    delta = P.scan(f"delta:{rule.head}", V,
                   disk_per_tuple=cm.scan_disk_per_tuple,
                   schema=(rule.src, "val"))
    active = P.select(delta, name="active",
                      selectivity=stats.delta_fraction,
                      expr=program.threshold)
    probe: P.PlanNode = active
    if view is not None:
        probe = P.udf(probe, name=f"view:{view.rel}",
                      cost_per_tuple=_SCALAR_UDF_COST, expr=view.expr,
                      pinned=True, schema=(rule.src, "val"))
    edges = P.scan(rule.edge, E, disk_per_tuple=cm.scan_disk_per_tuple,
                   schema=(rule.src, rule.dst))
    joined = P.join(probe, edges, selectivity=stats.avg_degree, key_fk=True,
                    cpu_per_tuple=cm.join_cpu_per_tuple)
    termed = P.udf(joined, name="term", cost_per_tuple=_SCALAR_UDF_COST,
                   expr=rule.term, pinned=True)
    shaped = P.project(termed, (rule.dst, "val"))
    shipped = P.rehash(shaped, net_per_tuple=cm.rehash_net_per_tuple)
    folded = P.group_aggregate(shipped, key=rule.dst, combiner=rule.agg,
                               n_groups=V,
                               cpu_per_tuple=cm.agg_cpu_per_tuple)
    return P.fixpoint(base, folded, max_iters=64, combiner=rule.agg)
