"""Canonical rule programs: the paper's benchmark algorithms as rules.

Each program comes in two equivalent forms — a builder-API function and a
text constant — and compiles (plan → optimize → lower) to a DeltaAlgorithm
property-tested *bit-identical* to the handwritten ``algorithms/`` version.

PageRank needs the two-relation formulation: the aggregation head ``acc``
accumulates pure mass and the damping constants live in a *view*, keeping
the add-rule term homogeneous-linear so the delta rewrite is sound (and the
lowered arithmetic token-identical to ``algorithms/pagerank.py``).

Reachability has NO handwritten counterpart — it exists purely as rules and
exercises the whole pipeline with zero engine changes.
"""
from __future__ import annotations

from repro.frontend import expr as E
from repro.frontend.rules import Program, ProgramBuilder

PAGERANK_TEXT = """\
program pagerank.
threshold 0.001.
input edge(u, v).
rank(v) = 0.15 + 0.85 * acc(v).
acc(v) add= rank(u) / deg(u) :- edge(u, v).
"""

SSSP_TEXT = """\
program sssp.
input edge(u, v).
dist(0) := 0.0.
dist(v) min= dist(u) + 1.0 :- edge(u, v).
"""

CC_TEXT = """\
program cc.
input edge(u, v).
label(v) := id(v).
label(v) min= label(u) :- edge(u, v).
"""

REACHABILITY_TEXT = """\
program reachability.
input edge(u, v).
reach(0) := 1.0.
reach(v) max= reach(u) :- edge(u, v).
"""


def pagerank_program(threshold: float = 1e-3) -> Program:
    return (ProgramBuilder("pagerank")
            .threshold(threshold)
            .input("edge", "u", "v")
            .view("rank", 0.15 + 0.85 * E.ref("acc"), var="v")
            .rule("acc", "add", E.ref("rank") / E.deg(), var="v", src="u")
            .build())


def sssp_program(source: int = 0) -> Program:
    return (ProgramBuilder("sssp")
            .input("edge", "u", "v")
            .fact("dist", source, 0.0)
            .rule("dist", "min", E.ref("dist") + 1.0, var="v", src="u")
            .build())


def cc_program() -> Program:
    return (ProgramBuilder("cc")
            .input("edge", "u", "v")
            .init("label", E.vid(), var="v")
            .rule("label", "min", E.ref("label"), var="v", src="u")
            .build())


def reachability_program(source: int = 0) -> Program:
    return (ProgramBuilder("reachability")
            .input("edge", "u", "v")
            .fact("reach", source, 1.0)
            .rule("reach", "max", E.ref("reach"), var="v", src="u")
            .build())
