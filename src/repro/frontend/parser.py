"""Compact text parser for rule programs (grammar in rules.py docstring).

Tokenizer + recursive-descent expression parser (precedence climbing, all
operators left-associative).  Statements terminate with ``.``; ``#`` starts
a line comment.  ``parse_program`` assembles through :class:`ProgramBuilder`
so text and builder programs normalize (and compare) identically.
"""
from __future__ import annotations

import re
from typing import List, Optional, Tuple

from repro.frontend import expr as E
from repro.frontend.rules import FrontendError, Program, ProgramBuilder

_TOKEN_RE = re.compile(r"""
      (?P<skip>\s+|\#[^\n]*)
    | (?P<num>\d+(?:\.\d+)?(?:[eE][+-]?\d+)?)
    | (?P<name>[A-Za-z_]\w*)
    | (?P<sym>:-|:=|[().,=+\-*/])
""", re.VERBOSE)


class ParseError(FrontendError):
    pass


def _tokenize(text: str) -> List[Tuple[str, str]]:
    tokens: List[Tuple[str, str]] = []
    pos = 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if m is None:
            snippet = text[pos:pos + 20]
            raise ParseError(f"cannot tokenize at: {snippet!r}")
        pos = m.end()
        if m.lastgroup != "skip":
            tokens.append((m.lastgroup, m.group()))
    return tokens


class _Parser:
    def __init__(self, tokens: List[Tuple[str, str]]):
        self.tokens = tokens
        self.pos = 0

    # -- token plumbing ---------------------------------------------------
    def peek(self, offset: int = 0) -> Tuple[str, str]:
        i = self.pos + offset
        return self.tokens[i] if i < len(self.tokens) else ("eof", "")

    def next(self) -> Tuple[str, str]:
        tok = self.peek()
        self.pos += 1
        return tok

    def expect(self, kind: str, text: Optional[str] = None) -> str:
        k, t = self.next()
        if k != kind or (text is not None and t != text):
            want = text or kind
            raise ParseError(f"expected {want!r}, got {t!r}")
        return t

    def accept(self, kind: str, text: Optional[str] = None) -> bool:
        k, t = self.peek()
        if k == kind and (text is None or t == text):
            self.pos += 1
            return True
        return False

    # -- expressions ------------------------------------------------------
    def expr(self) -> E.Expr:
        node = self.term()
        while self.peek() in (("sym", "+"), ("sym", "-")):
            op = self.next()[1]
            node = E.BinOp(op, node, self.term())
        return node

    def term(self) -> E.Expr:
        node = self.factor()
        while self.peek() in (("sym", "*"), ("sym", "/")):
            op = self.next()[1]
            node = E.BinOp(op, node, self.factor())
        return node

    def factor(self) -> E.Expr:
        if self.accept("sym", "-"):
            inner = self.factor()
            if isinstance(inner, E.Const):
                return E.Const(-inner.value)
            return E.BinOp("-", E.Const(0.0), inner)
        return self.primary()

    def primary(self) -> E.Expr:
        kind, text = self.peek()
        if kind == "num":
            self.next()
            return E.Const(float(text))
        if kind == "name":
            self.next()
            self.expect("sym", "(")
            var = self.expect("name")
            self.expect("sym", ")")
            return E.Ref(text, var)
        if self.accept("sym", "("):
            node = self.expr()
            self.expect("sym", ")")
            return node
        raise ParseError(f"expected an expression, got {text!r}")

    # -- statements -------------------------------------------------------
    def program(self) -> Program:
        builder = ProgramBuilder()
        while self.peek()[0] != "eof":
            self.statement(builder)
        return builder.build()

    def statement(self, b: ProgramBuilder) -> None:
        kind, text = self.peek()
        if kind != "name":
            raise ParseError(f"expected a statement, got {text!r}")
        if text == "program":
            self.next()
            b._name = self.expect("name")
            self.expect("sym", ".")
            return
        if text == "threshold":
            self.next()
            neg = self.accept("sym", "-")
            val = float(self.expect("num"))
            b.threshold(-val if neg else val)
            self.expect("sym", ".")
            return
        if text == "input":
            self.next()
            name = self.expect("name")
            self.expect("sym", "(")
            fields = [self.expect("name")]
            while self.accept("sym", ","):
                fields.append(self.expect("name"))
            self.expect("sym", ")")
            self.expect("sym", ".")
            b.input(name, *fields)
            return
        self.head_statement(b)

    def head_statement(self, b: ProgramBuilder) -> None:
        rel = self.expect("name")
        self.expect("sym", "(")
        arg_kind, arg = self.next()
        if arg_kind not in ("name", "num"):
            raise ParseError(f"expected a variable or key, got {arg!r}")
        self.expect("sym", ")")

        if self.accept("sym", ":="):
            body = self.expr()
            self.expect("sym", ".")
            if arg_kind == "num":            # ground fact at an integer key
                if not isinstance(body, E.Const):
                    raise ParseError(
                        f"fact {rel}({arg}) needs a constant value")
                if "." in arg or "e" in arg or "E" in arg:
                    raise ParseError(f"fact key must be an integer: {arg!r}")
                b.fact(rel, int(arg), body.value)
            else:                            # all-vertex initializer
                b.init(rel, body, var=arg)
            return

        kind, text = self.peek()
        if kind == "name" and text in ("add", "min", "max") \
                and self.peek(1) == ("sym", "="):
            self.next()                      # aggregator
            self.next()                      # '='
            term = self.expr()
            self.expect("sym", ":-")
            edge = self.expect("name")
            self.expect("sym", "(")
            src = self.expect("name")
            self.expect("sym", ",")
            dst = self.expect("name")
            self.expect("sym", ")")
            self.expect("sym", ".")
            if arg_kind != "name":
                raise ParseError("rule head takes a variable, not a key")
            if dst != arg:
                raise ParseError(
                    f"rule head variable {arg!r} must be the edge "
                    f"destination (got {dst!r})")
            b.rule(rel, text, term, edge=(edge, src, dst), var=dst, src=src)
            return

        if self.accept("sym", "="):          # view
            if arg_kind != "name":
                raise ParseError("view head takes a variable, not a key")
            body = self.expr()
            self.expect("sym", ".")
            b.view(rel, body, var=arg)
            return

        raise ParseError(f"malformed statement for {rel!r}")


def parse_program(text: str) -> Program:
    return _Parser(_tokenize(text)).program()
