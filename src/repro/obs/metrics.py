"""Metrics registry: counters, gauges, histograms, snapshots.

A tiny in-process metrics layer sized for the engine's needs: per-run
counters (strata executed, deltas emitted, bytes rehashed, recovery
events), gauges (journal depth, live count), and histograms (per-stratum
wall time, refresh latency).  No external dependency, no background
thread — instruments update under a lock, :meth:`MetricsRegistry.snapshot`
returns a plain JSON-serializable dict that ``benchmarks/run.py`` embeds
into ``BENCH_*.json`` artifacts and ``obs/export.py`` dumps standalone.

A process-wide default registry (:func:`default_registry`) serves code
paths that have no natural place to thread a registry through; tests and
benchmarks reset it between runs (:func:`reset_default_registry`).
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional


class Counter:
    """Monotonically-increasing value (events, bytes, deltas)."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        self.value += amount

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Point-in-time value (journal depth, live delta count)."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self.value}


# Default histogram buckets: wall-clock seconds from 100µs to ~100s in
# half-decade steps — wide enough for a stratum on any backend.
_DEFAULT_BUCKETS = tuple(10.0 ** (e / 2) for e in range(-8, 5))


class Histogram:
    """Fixed-bucket histogram with running sum/count/min/max.

    Buckets are upper bounds (le); one overflow bucket catches the rest.
    """

    def __init__(self, name: str, buckets: Optional[tuple] = None):
        self.name = name
        self.buckets = tuple(sorted(buckets or _DEFAULT_BUCKETS))
        self.counts = [0] * (len(self.buckets) + 1)
        self.total = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        value = float(value)
        lo, hi = 0, len(self.buckets)
        while lo < hi:                       # first bucket with le >= value
            mid = (lo + hi) // 2
            if self.buckets[mid] < value:
                lo = mid + 1
            else:
                hi = mid
        self.counts[lo] += 1
        self.total += value
        self.count += 1
        self.min = min(self.min, value)
        self.max = max(self.max, value)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        out = {"type": "histogram", "count": self.count,
               "sum": self.total, "mean": self.mean}
        if self.count:
            out["min"] = self.min
            out["max"] = self.max
            out["buckets"] = {
                ("+inf" if i == len(self.buckets)
                 else f"{self.buckets[i]:g}"): c
                for i, c in enumerate(self.counts) if c}
        return out


class MetricsRegistry:
    """Named instruments with get-or-create semantics and one snapshot API.

    ``registry.counter("engine.strata").inc()`` — instruments are created
    on first use; asking for an existing name with a different kind
    raises (a counter silently read as a gauge is a bug, not a feature).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: Dict[str, object] = {}

    def _get(self, name: str, cls, **kw):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, **kw)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} is a {type(inst).__name__}, "
                    f"not a {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Optional[tuple] = None) -> Histogram:
        return self._get(name, Histogram, buckets=buckets)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._instruments)

    def __len__(self) -> int:
        return len(self._instruments)

    def snapshot(self) -> dict:
        """JSON-serializable {name: instrument snapshot} (sorted)."""
        with self._lock:
            return {name: inst.snapshot()
                    for name, inst in sorted(self._instruments.items())}

    def reset(self) -> None:
        with self._lock:
            self._instruments.clear()


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """Process-wide registry (benchmarks embed its snapshot per suite)."""
    return _DEFAULT


def reset_default_registry() -> None:
    _DEFAULT.reset()
