"""Measured route-cost calibration for ``route_strategy="measured"``.

The PR 4 ``auto`` dispatcher chooses sort- vs scatter-based combine-route
per capacity rung from a *static* cost model (``C·log₂C`` vs
``weight·(C + slab)``) whose single weight was hand-calibrated on XLA
CPU.  This module replaces the model with measurement: time BOTH
physical implementations at each rung capacity on the *current* backend
(the per-backend calibration ROADMAP item 1 called for) and record the
result in a :class:`RouteCostTable` the executor consults at trace time.

Two ways to build a table:

  * :func:`calibrate_route_table` — run the microbenchmark directly
    (seconds per call, jitted, median of ``reps``).  Must be called
    eagerly (it executes real computations; calling it while tracing an
    enclosing ``jit`` would trace the timing loop into the caller).
  * :func:`RouteCostTable.from_bench_records` — reuse the committed
    ``BENCH_rehash.json`` sweep records, so a CI artifact doubles as a
    calibration source.

Lookup interpolates in log-capacity space between measured rungs; an
exact match is exact.  The table is backend-stamped so a table measured
on CPU is visibly wrong to apply on TPU (``pick`` warns via ValueError
when backends mismatch unless ``strict=False``).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.delta import (ANN_ADJUST, DeltaBuffer, combine_route,
                              combine_route_scatter)
from repro.core.partition import PartitionSnapshot


@dataclasses.dataclass(frozen=True)
class RouteCostTable:
    """Measured per-rung costs: capacity -> (sort_s, scatter_s)."""

    backend: str
    combiner: str
    entries: Dict[int, Tuple[float, float]]

    def __post_init__(self):
        if not self.entries:
            raise ValueError("empty route cost table")

    def costs(self, edge_capacity: int) -> Tuple[float, float]:
        """(sort_s, scatter_s) at ``edge_capacity``, log-interpolated
        between the nearest measured rungs (clamped at the ends)."""
        caps = sorted(self.entries)
        c = max(int(edge_capacity), 1)
        if c <= caps[0]:
            return self.entries[caps[0]]
        if c >= caps[-1]:
            return self.entries[caps[-1]]
        for lo, hi in zip(caps, caps[1:]):
            if lo <= c <= hi:
                if c == lo:
                    return self.entries[lo]
                if c == hi:
                    return self.entries[hi]
                f = ((math.log2(c) - math.log2(lo))
                     / (math.log2(hi) - math.log2(lo)))
                slo, plo = self.entries[lo]
                shi, phi = self.entries[hi]
                return (slo + f * (shi - slo), plo + f * (phi - plo))
        raise AssertionError("unreachable")

    def per_tuple_cost(self, edge_capacity: int) -> float:
        """Measured seconds per routed tuple at ``edge_capacity``: the
        cheaper physical strategy's cost amortized over the rung.  This is
        the calibration hook ``core/optimizer.py:CostModel.from_route_table``
        consumes, so plan costing and rung dispatch share one source."""
        sort_s, scatter_s = self.costs(edge_capacity)
        return min(sort_s, scatter_s) / max(int(edge_capacity), 1)

    def median_per_tuple(self) -> float:
        """Median per-tuple routed cost across all measured rungs."""
        vals = sorted(self.per_tuple_cost(c) for c in self.entries)
        return vals[len(vals) // 2]

    def pick(self, edge_capacity: int, strict: bool = True) -> str:
        """Cheaper measured strategy for a rung of ``edge_capacity``."""
        if strict and self.backend != jax.default_backend():
            raise ValueError(
                f"route cost table was measured on {self.backend!r} but "
                f"the current backend is {jax.default_backend()!r}; "
                "recalibrate (or pass strict=False to override)")
        sort_s, scatter_s = self.costs(edge_capacity)
        return "scatter" if scatter_s < sort_s else "sort"

    # ---- construction ----------------------------------------------------
    @classmethod
    def from_bench_records(cls, records: Iterable[dict], shards: int,
                           combiner: str = "add",
                           backend: Optional[str] = None
                           ) -> "RouteCostTable":
        """Build a table from ``bench_rehash`` emission records (the
        dicts inside ``BENCH_rehash.json``): matching ``S`` and
        ``combiner``, one (sort, scatter) pair per ``C``."""
        acc: Dict[int, Dict[str, float]] = {}
        for rec in records:
            if rec.get("unit") != "s" or rec.get("combiner") != combiner \
                    or int(rec.get("S", -1)) != shards:
                continue
            strat = rec.get("strategy")
            if strat not in ("sort", "scatter"):
                continue
            acc.setdefault(int(rec["C"]), {})[strat] = float(rec["value"])
        entries = {c: (v["sort"], v["scatter"])
                   for c, v in acc.items() if len(v) == 2}
        if not entries:
            raise ValueError(
                f"no (sort, scatter) record pairs for S={shards}, "
                f"combiner={combiner!r}")
        return cls(backend=backend or jax.default_backend(),
                   combiner=combiner, entries=entries)


def _timed(fn, *args, warmup: int = 1, reps: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _probe_buffer(rng: np.random.Generator, capacity: int, n_keys: int,
                  fill: float = 0.75) -> DeltaBuffer:
    count = int(capacity * fill)
    keys = np.full(capacity, -1, np.int32)
    keys[:count] = rng.integers(0, n_keys, count)
    pay = rng.normal(size=(capacity, 1)).astype(np.float32)
    pay[count:] = 0
    return DeltaBuffer(
        keys=jnp.asarray(keys), payload=jnp.asarray(pay),
        ann=jnp.full(capacity, ANN_ADJUST, jnp.int8),
        count=jnp.asarray(count, jnp.int32),
        overflowed=jnp.asarray(False))


def calibrate_route_table(snapshot: PartitionSnapshot,
                          capacities: Iterable[int],
                          combiner: str = "add", reps: int = 3,
                          warmup: int = 1, seed: int = 0
                          ) -> RouteCostTable:
    """Measure sort vs scatter combine-route at each capacity under the
    given partition snapshot (slab size and owner scheme come from it) on
    the CURRENT jax backend.  Call eagerly, before any enclosing jit."""
    rng = np.random.default_rng(seed)
    S = snapshot.num_shards
    entries: Dict[int, Tuple[float, float]] = {}
    for cap in sorted({max(int(c), 2) for c in capacities}):
        db = _probe_buffer(rng, cap, snapshot.n_keys)
        owners = snapshot.owner_of(db.keys)
        sort_fn = jax.jit(lambda d, o, cap=cap: combine_route(
            d, o, S, cap, combiner))
        scatter_fn = jax.jit(lambda d, o, cap=cap: combine_route_scatter(
            d, o, S, cap, combiner, snapshot=snapshot))
        entries[cap] = (_timed(sort_fn, db, owners, warmup=warmup,
                               reps=reps),
                        _timed(scatter_fn, db, owners, warmup=warmup,
                               reps=reps))
    return RouteCostTable(backend=jax.default_backend(),
                          combiner=combiner, entries=entries)


def calibrate_executor_table(executor, algo,
                             combiner: Optional[str] = None,
                             **kw) -> RouteCostTable:
    """Calibrate exactly the capacity rungs ``executor`` would dispatch
    over for ``algo`` (its ladder's per-rung edge budgets)."""
    caps = {t.edge for t in executor.capacity_tiers(algo)}
    comb = combiner or (algo.combiner
                        if algo.combiner in ("add", "min", "max") else "add")
    return calibrate_route_table(executor.snapshot, caps, combiner=comb,
                                 **kw)
