"""Fixpoint observability: tracing, metrics, exporters, calibration.

``obs`` is the measurement layer the rest of the engine reports into —
and reads back from.  A :class:`~repro.obs.trace.Tracer` threaded into
``ShardedExecutor`` records per-stratum spans from inside
``lax.while_loop``/``shard_map`` (via ``jax.debug.callback``); a
:class:`~repro.obs.metrics.MetricsRegistry` accumulates counters, gauges
and histograms; ``obs.export`` renders Perfetto-loadable timelines and
flat metric dumps; and ``obs.calibrate`` turns recorded route timings
into the measured dispatch table behind ``route_strategy="measured"``.

Everything is opt-in: with no tracer/registry attached (the default) the
instrumented code paths compile to exactly the pre-observability
computation — bit-identical outputs, no callbacks, no overhead.
"""
from repro.obs.calibrate import (RouteCostTable, calibrate_executor_table,
                                 calibrate_route_table)
from repro.obs.export import (metrics_to_json, to_chrome_trace,
                              write_chrome_trace, write_metrics)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               default_registry, reset_default_registry)
from repro.obs.trace import MeasuredLatencies, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_registry", "reset_default_registry",
    "Tracer", "MeasuredLatencies",
    "to_chrome_trace", "write_chrome_trace", "metrics_to_json",
    "write_metrics",
    "RouteCostTable", "calibrate_route_table", "calibrate_executor_table",
]
