"""Span-based tracer with an in-jit recording path.

Two recording surfaces share one event buffer:

  * **Host spans** — ``with tracer.span("view.refresh", view=name):`` for
    driver-side code (the resilient driver's stratum slices, view repairs,
    replica writes).  Durations are real ``perf_counter`` intervals.
  * **In-jit probes** — ``tracer.stratum_probe(...)`` is called at *trace
    time* inside the engine's stratum bodies and inserts a
    ``jax.debug.callback`` whose operands are the stratum's outcome
    scalars.  The callback survives ``lax.while_loop``, ``lax.switch`` and
    ``shard_map``: it fires on the host when the device reaches it, so the
    arrival-time deltas are the measured per-stratum (and, under
    shard_map, per-shard) wall clock.  Probes are data-dependent on the
    outcome, purely observational, and emitted only when a tracer is
    threaded in — ``tracer=None`` leaves the compiled computation
    untouched (bit-identical, zero overhead).

Timestamps are ``perf_counter`` seconds relative to the tracer's epoch;
``obs/export.py`` converts to the Chrome-trace µs timeline.  Probe
ordering: the simulated backend uses ordered callbacks (strict program
order); shard_map uses unordered ones (ordered effects cannot cross a
collective), so events carry their stratum index and the exporter orders
by it, not by arrival.

Measured latencies recorded here close the loop flagged in ROADMAP items
1 and 5: :class:`MeasuredLatencies` is the per-shard timing source the
resilient driver feeds to ``SpeculationPolicy`` when no synthetic
``latency_model`` is supplied, and ``obs/calibrate.py`` turns recorded
per-rung route timings into the ``route_strategy="measured"`` table.
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Dict, List, Optional

import jax

from repro.obs.metrics import MetricsRegistry

# StratumOutcome.tier / .route use -1 for "dense / not applicable".
_DENSE = -1


class Tracer:
    """Append-only event recorder (host spans + in-jit probe arrivals).

    Events are dicts with ``name``, ``ph`` ("X" span / "i" instant),
    ``ts`` (start, seconds since epoch), ``dur`` (spans), ``tid`` (host
    thread or ``shard<k>``), and free-form ``args``.  Thread-safe: jit
    callbacks may arrive from runtime threads.
    """

    def __init__(self, name: str = "rex",
                 metrics: Optional[MetricsRegistry] = None,
                 clock=time.perf_counter):
        self.name = name
        self.metrics = metrics
        self._clock = clock
        self.epoch = clock()
        self.events: List[dict] = []
        self._lock = threading.Lock()
        # Last probe arrival per tid — the previous stratum boundary, used
        # to turn arrival times into per-stratum durations.
        self._last_ts: Dict[str, float] = {}
        # (stratum, shard) -> (start, dur) of the most recent probe, the
        # index MeasuredLatencies / the resilient driver query.
        self._stratum_times: Dict[tuple, tuple] = {}

    # ------------------------------------------------------------------
    # Host-side recording.
    # ------------------------------------------------------------------
    def _now(self) -> float:
        return self._clock() - self.epoch

    def _append(self, ev: dict) -> None:
        with self._lock:
            self.events.append(ev)

    @contextlib.contextmanager
    def span(self, name: str, tid: str = "host", **attrs):
        """Record a complete (ph "X") event around a host-side block.
        Yields the args dict — mutate it to attach results measured
        inside the span."""
        t0 = self._now()
        args = dict(attrs)
        try:
            yield args
        finally:
            self._append({"name": name, "ph": "X", "ts": t0,
                          "dur": self._now() - t0, "tid": tid,
                          "args": args})

    def instant(self, name: str, tid: str = "host", **attrs) -> None:
        """Record a point event (recovery, rescale, speculation verdict)."""
        self._append({"name": name, "ph": "i", "ts": self._now(),
                      "tid": tid, "args": dict(attrs)})

    def mark(self, tid: str = "host") -> None:
        """Reset the duration anchor for ``tid`` — call right before
        dispatching a computation whose probes should not absorb the
        host time spent since the previous probe."""
        with self._lock:
            self._last_ts[tid] = self._now()

    def mark_shards(self, num_shards: int) -> None:
        """Anchor every shard timeline (and the aggregate "shards" row)
        at now — the stratum-dispatch boundary, so the next probe's
        duration measures device work only, not host time in between."""
        now = self._now()
        with self._lock:
            self._last_ts["shards"] = now
            for s in range(num_shards):
                self._last_ts[f"shard{s}"] = now

    # ------------------------------------------------------------------
    # In-jit probes (trace-time insertion, host-side arrival).
    # ------------------------------------------------------------------
    def _on_stratum(self, stratum, emitted, tier, route, rehash_bytes,
                    used_dense, live, shard) -> None:
        now = self._now()
        stratum = int(stratum)
        shard = int(shard)
        tid = "shards" if shard < 0 else f"shard{shard}"
        with self._lock:
            start = self._last_ts.get(tid, self.epoch - self.epoch)
            self._last_ts[tid] = now
        dur = max(now - start, 0.0)
        self._stratum_times[(stratum, shard)] = (start, dur)
        self._append({"name": f"stratum{stratum}", "ph": "X", "ts": start,
                      "dur": dur, "tid": tid,
                      "args": {"stratum": stratum, "emitted": int(emitted),
                               "tier": int(tier), "route": int(route),
                               "rehash_bytes": float(rehash_bytes),
                               "used_dense": bool(used_dense),
                               "live_after": int(live)}})
        if self.metrics is not None:
            m = self.metrics
            m.counter("engine.strata").inc()
            m.counter("engine.deltas_emitted").inc(int(emitted))
            m.counter("engine.rehash_bytes").inc(float(rehash_bytes))
            if bool(used_dense):
                m.counter("engine.dense_fallbacks").inc()
            m.histogram("engine.stratum_seconds").observe(dur)
            m.gauge("engine.live_deltas").set(int(live))

    def stratum_probe(self, stratum_idx, outcome, shard_id=None,
                      ordered: bool = True) -> None:
        """Insert the per-stratum callback into the traced computation.

        Called from the engine's stratum bodies with traced scalars;
        ``shard_id`` is ``lax.axis_index`` under shard_map (per-shard
        arrival times) and None on the simulated backend (one probe per
        stratum, tid "shards").  ``ordered=False`` is required wherever
        ordered effects are unsupported (shard_map bodies).
        """
        import jax.numpy as jnp
        shard = jnp.asarray(-1) if shard_id is None else shard_id
        jax.debug.callback(self._on_stratum, stratum_idx, outcome.emitted,
                           outcome.tier, outcome.route,
                           outcome.rehash_bytes, outcome.used_dense,
                           outcome.live_count, shard, ordered=ordered)

    def _on_fixpoint(self, iterations, max_iters) -> None:
        self.instant("fixpoint_done", iterations=int(iterations),
                     max_iters=int(max_iters))
        if self.metrics is not None:
            self.metrics.counter("engine.fixpoints").inc()
            self.metrics.gauge("engine.last_fixpoint_strata").set(
                int(iterations))

    def fixpoint_probe(self, iterations, max_iters: int) -> None:
        """Fixpoint-complete marker (fires once per ``run``)."""
        jax.debug.callback(self._on_fixpoint, iterations, max_iters,
                           ordered=False)

    # ------------------------------------------------------------------
    # Measured-timing queries.
    # ------------------------------------------------------------------
    def stratum_seconds(self, stratum: int, shard: int = -1
                        ) -> Optional[float]:
        """Measured wall time of a recorded stratum probe (None if that
        (stratum, shard) never fired)."""
        hit = self._stratum_times.get((int(stratum), int(shard)))
        return None if hit is None else hit[1]

    def per_shard_latencies(self, stratum: int, num_shards: int,
                            default: Optional[float] = None
                            ) -> Optional[List[float]]:
        """Per-shard measured latencies for one stratum — the feed for
        ``SpeculationPolicy``.  Under shard_map every shard probes
        individually; on the simulated backend only the aggregate probe
        exists, so ``default`` (typically the driver's host-side stratum
        wall) fills all shards.  Returns None when nothing was measured
        and no default is given."""
        out = []
        for s in range(num_shards):
            t = self.stratum_seconds(stratum, s)
            if t is None:
                t = self.stratum_seconds(stratum, -1)
            if t is None:
                t = default
            if t is None:
                return None
            out.append(float(t))
        return out

    def clear(self) -> None:
        with self._lock:
            self.events.clear()
            self._last_ts.clear()
            self._stratum_times.clear()


class MeasuredLatencies:
    """Recorded per-shard stratum timings, callable like the synthetic
    ``latency_model(stratum) -> [seconds per shard]`` the resilient driver
    consumed before — measurement replacing extrapolation (ROADMAP item 5).

    The driver appends one list per executed stratum (tracer per-shard
    probes when available, host stratum wall otherwise)."""

    def __init__(self):
        self.latencies: List[List[float]] = []

    def observe(self, per_shard: List[float]) -> None:
        self.latencies.append([float(x) for x in per_shard])

    def __len__(self) -> int:
        return len(self.latencies)

    def __call__(self, stratum: int) -> List[float]:
        if not self.latencies:
            raise ValueError("no measured latencies recorded yet")
        # Strata are appended in execution order; a restart re-executes
        # early strata, so index from the END (most recent measurement).
        idx = min(int(stratum), len(self.latencies) - 1)
        return list(self.latencies[idx])
