"""Exporters: Chrome-trace/Perfetto JSON timelines + flat metrics dumps.

``to_chrome_trace`` converts a :class:`~repro.obs.trace.Tracer`'s event
buffer into the Trace Event Format JSON that both ``chrome://tracing``
and https://ui.perfetto.dev load directly: one process, one timeline row
per recorded ``tid`` (host, per-shard rows, views), complete ("X") events
for spans/strata, instant ("i") events for recoveries and verdicts, and
``thread_name`` metadata rows so the UI labels tracks.  Probe events are
ordered by their recorded (stratum, tid) — not arrival order, which
unordered shard_map callbacks do not guarantee.

``metrics_to_json`` flattens a registry snapshot into the structure
``benchmarks/run.py`` embeds into ``BENCH_*.json`` and CI uploads
standalone next to the trace artifact.
"""
from __future__ import annotations

import json
from typing import Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer

_US = 1e6  # trace-event timestamps are microseconds


def to_chrome_trace(tracer: Tracer, pid: int = 1) -> dict:
    """Trace Event Format dict (Perfetto/chrome://tracing loadable)."""
    tids: dict[str, int] = {}
    events = []

    def tid_of(name: str) -> int:
        if name not in tids:
            # Stable, readable ordering: host first, then shards in
            # registration order.
            tids[name] = len(tids) + 1
        return tids[name]

    with tracer._lock:
        recorded = list(tracer.events)
    # Stable ordering for the viewer: by start time, shard_map probe
    # arrival order notwithstanding.
    recorded.sort(key=lambda e: (e.get("ts", 0.0), e.get("tid", "")))
    for ev in recorded:
        out = {
            "name": ev["name"],
            "ph": ev["ph"],
            "ts": round(ev["ts"] * _US, 3),
            "pid": pid,
            "tid": tid_of(ev.get("tid", "host")),
            "args": ev.get("args", {}),
        }
        if ev["ph"] == "X":
            out["dur"] = round(ev.get("dur", 0.0) * _US, 3)
        elif ev["ph"] == "i":
            out["s"] = "t"          # thread-scoped instant marker
        events.append(out)

    meta = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
             "args": {"name": f"rex:{tracer.name}"}}]
    meta += [{"name": "thread_name", "ph": "M", "pid": pid, "tid": t,
              "args": {"name": name}} for name, t in tids.items()]
    return {"traceEvents": meta + events, "displayTimeUnit": "ms",
            "otherData": {"tracer": tracer.name,
                          "events": len(events)}}


def write_chrome_trace(tracer: Tracer, path: str, pid: int = 1) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(tracer, pid=pid), f, indent=1)
        f.write("\n")
    return path


def metrics_to_json(registry: MetricsRegistry,
                    extra: Optional[dict] = None) -> dict:
    """Flat metrics dump: {"metrics": snapshot, **extra}."""
    out = {"metrics": registry.snapshot()}
    if extra:
        out.update(extra)
    return out


def write_metrics(registry: MetricsRegistry, path: str,
                  extra: Optional[dict] = None) -> str:
    with open(path, "w") as f:
        json.dump(metrics_to_json(registry, extra), f, indent=1,
                  sort_keys=True)
        f.write("\n")
    return path
