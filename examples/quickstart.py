"""Quickstart: delta-based PageRank on a power-law graph (paper Ex. 1).

  PYTHONPATH=src python examples/quickstart.py

Runs the same query in REX ``delta`` mode (propagate only Δᵢ) and
``nodelta`` mode (re-derive everything — the MapReduce-style baseline) and
prints per-iteration Δᵢ sizes, bytes moved, and the identical fixpoint.
"""
import numpy as np

import jax.numpy as jnp

from repro.algorithms import pagerank
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import load_dataset

SHARDS = 8

n, graph = load_dataset("dbpedia-small", num_shards=SHARDS)
snap = PartitionSnapshot(n_keys=n, num_shards=SHARDS)
print(f"graph: {n} vertices, {SHARDS} shards "
      f"(block partition, replication={snap.replication})")

results = {}
for mode in ("delta", "nodelta"):
    pr, res = pagerank.run(graph, snap, mode=mode, threshold=1e-5,
                           max_iters=80, edge_capacity=65536,
                           src_capacity=snap.block_size)
    iters = int(res.stats.iterations)
    moved = float(np.sum(res.stats.rehash_bytes))
    results[mode] = pr
    print(f"\n{mode}: converged in {iters} strata, "
          f"rehash moved {moved / 1e6:.2f} MB")
    if mode == "delta":
        counts = np.asarray(res.stats.delta_counts)[:iters]
        print("  |Δᵢ| per stratum:", counts[:10].tolist(), "...",
              counts[-3:].tolist())

diff = float(jnp.max(jnp.abs(results["delta"] - results["nodelta"])))
print(f"\nfixpoint agreement (delta vs dense): max |Δpr| = {diff:.2e}")
top = jnp.argsort(-results["delta"][:n])[:5]
print("top-5 pages by PageRank:", top.tolist())
