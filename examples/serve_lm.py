"""Serving example: prefill + batched greedy decode on every arch family.

  PYTHONPATH=src python examples/serve_lm.py [--arch recurrentgemma-2b]

Decode-as-delta in action: recurrent archs (xlstm, recurrentgemma) carry
O(1) state per step; attention archs append to their KV cache (ring
buffer under sliding windows).
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "xlstm-350m", "--reduced",
                "--batch", "4", "--prompt-len", "16",
                "--new-tokens", "24"] + sys.argv[1:]
    serve.main()
