"""Standing queries: materialized views absorbing live base-data deltas.

The serving-side story of the REX reproduction: a ViewManager keeps three
standing queries (PageRank, SSSP, k-means) converged while the base data
mutates underneath them.  Each tick applies a batch of edge/point
mutations and refreshes; the views repair their warm state through the
per-algorithm rules and resume the sharded fixpoint, falling back to a
cold recompute only when the estimated repair volume crosses the
threshold.  A durable mutation journal (runtime/checkpoint.py delta
checkpoints) makes the whole session recoverable — the final section
restarts from disk and proves the restored views are identical.

  PYTHONPATH=src python examples/standing_queries.py
"""
import tempfile

import numpy as np

from repro.data.graphs import make_powerlaw_graph
from repro.incremental import (EdgeDelete, EdgeInsert, PointInsert,
                               PointRemove, ViewManager)

rng = np.random.default_rng(0)
N = 2_048
TICKS = 5

indptr, indices = make_powerlaw_graph(N, avg_degree=8, seed=0)
points = np.concatenate([
    rng.normal((0, 0), 0.4, (200, 2)),
    rng.normal((5, 5), 0.4, (200, 2)),
    rng.normal((0, 5), 0.4, (200, 2))]).astype(np.float32)

journal_root = tempfile.mkdtemp(prefix="rex_views_")
mgr = ViewManager(journal_root=journal_root, fallback_threshold=0.5)
mgr.create_graph_view("ranks", "pagerank", indptr, indices, N,
                      num_shards=4, threshold=1e-4, max_iters=100)
mgr.create_graph_view("dists", "sssp", indptr, indices, N,
                      num_shards=4, source=0, max_iters=100)
mgr.create_kmeans_view("clusters", points, k=3, num_shards=4, seed=1)

for name, view in mgr.views.items():
    r = view.history[-1]
    print(f"cold-start {name:>8}: {r.strata:3d} strata, "
          f"{r.rehash_bytes / 1e3:8.1f} KB rehashed, {r.wall_s:6.3f}s")

for tick in range(TICKS):
    # Edge churn: a handful of inserts + deletes per graph view.
    store = mgr["ranks"].store
    src, dst = store.edges()
    batch = [EdgeInsert(int(rng.integers(N)), int(rng.integers(N)))
             for _ in range(6)]
    for i in rng.choice(len(src), 6, replace=False):
        batch.append(EdgeDelete(int(src[i]), int(dst[i])))
    mgr.mutate("ranks", *batch)
    mgr.mutate("dists", *batch)

    # Point churn: sensors appear and disappear.
    valid = np.flatnonzero(mgr["clusters"].store.to_arrays()["valid"])
    mgr.mutate("clusters",
               PointInsert(float(rng.normal(5, 0.4)),
                           float(rng.normal(5, 0.4))),
               PointRemove(int(rng.choice(valid))))

    print(f"-- tick {tick}:")
    for name, r in mgr.refresh().items():
        print(f"   {name:>8} v{r.version}: {r.mode:6s} "
              f"touched={r.touched_keys:4d} strata={r.strata:3d} "
              f"rehash={r.rehash_bytes / 1e3:7.1f} KB "
              f"wall={r.wall_s * 1e3:6.1f} ms")

top = np.argsort(mgr.query("ranks"))[-3:][::-1]
print(f"top pages by rank: {list(top)}")
reach = np.isfinite(mgr.query("dists")).sum()
print(f"vertices reachable from 0: {reach}/{N}")
print(f"cluster centroids:\n{np.round(mgr.query('clusters'), 3)}")

# ---- crash, restart, resume from the journal ------------------------------
restored = ViewManager.restore(journal_root)
for name in mgr.views:
    same = np.array_equal(restored.query(name), mgr.query(name),
                          equal_nan=True)
    print(f"restored {name:>8} v{restored[name].version}: "
          f"identical={same}")
