"""Reachability written purely as rules — no handwritten DeltaAlgorithm.

The program below is the whole algorithm: parse it, compile it through the
plan IR + optimizer + lowering, and run it on the sharded engine.  Nothing
in ``algorithms/`` or ``core/`` knows reachability exists.

  PYTHONPATH=src python examples/reachability_rules.py [--quick]
"""
import argparse

import numpy as np

from repro import frontend as F
from repro.algorithms import sssp
from repro.core.partition import PartitionSnapshot
from repro.core.plan import plan_runtime
from repro.data.graphs import DATASETS, load_dataset, make_powerlaw_graph

RULES = """
program reachability.
input edge(u, v).
reach(0) := 1.0.                      # the source vertex is reachable
reach(v) max= reach(u) :- edge(u, v). # reachability propagates over edges
"""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small dataset / fewer shards (CI smoke mode)")
    args = ap.parse_args()
    dataset = "dbpedia-small" if args.quick else "dbpedia"
    shards = 4 if args.quick else 8

    program = F.parse_program(RULES)
    compiled = F.compile_program(program)
    print(f"program {program.name!r}: combiner={compiled.combiner}, "
          f"optimized plan runtime estimate "
          f"{plan_runtime(compiled.optimized):.3g}s")

    n, graph = load_dataset(dataset, num_shards=shards)
    snap = PartitionSnapshot(n_keys=n, num_shards=shards)
    values, res = compiled.run(graph, snap, max_iters=80)

    reached = int(np.sum(np.asarray(values)[:n] == 1.0))
    print(f"{dataset}: {reached}/{n} vertices reachable from 0 "
          f"in {int(res.stats.iterations)} strata")

    # Cross-check against the BFS oracle (same generator parameters).
    nn, avg_deg, alpha = DATASETS[dataset]
    indptr, indices = make_powerlaw_graph(nn, avg_degree=avg_deg,
                                          alpha=alpha, seed=0)
    dist = np.asarray(sssp.reference_sssp(np.asarray(indptr),
                                          np.asarray(indices), n))
    assert np.array_equal(np.asarray(values)[:n] == 1.0, dist < np.inf), \
        "rules-only reachability disagrees with the BFS oracle"
    print("matches BFS oracle: OK")


if __name__ == "__main__":
    main()
