"""Analytics pipeline: relational operators + cost-based optimizer (§3, §5).

A Listing-1-style workload: join an edge relation against per-page
metadata, aggregate with UDAs, and show the optimizer's pre-aggregation
pushdown + UDF rank ordering decisions on the plan.

  PYTHONPATH=src python examples/analytics_pipeline.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core.operators import (Table, apply_function, fk_join, group_by,
                                  select)
from repro.core.optimizer import (best_udf_join_interleaving,
                                  estimate_recursive_cost, optimize)
from repro.core.plan import (PlanNode, groupby, join, plan_runtime, rehash,
                             scan, udf)

rng = np.random.default_rng(0)
N_EDGES, N_PAGES = 100_000, 4_096

# ---- physical execution ---------------------------------------------------
edges = Table.from_columns(
    src=jnp.asarray(rng.integers(0, N_PAGES, N_EDGES).astype(np.int32)),
    dst=jnp.asarray(rng.integers(0, N_PAGES, N_EDGES).astype(np.int32)))
pages = Table.from_columns(
    page=jnp.asarray(np.arange(N_PAGES, dtype=np.int32)),
    quality=jnp.asarray(rng.random(N_PAGES).astype(np.float32)))

t = fk_join(edges, pages, "src", "page", n_keys=N_PAGES)
t = apply_function(t, lambda q: {"w": q * q}, ("quality",))     # UDF
t = select(t, lambda t: t.columns["w"] > 0.25)                  # predicate
out = group_by(t, "dst", {"mass": ("sum", "w"),
                          "fans": ("count", "w")}, n_keys=N_PAGES)
best = int(jnp.argmax(out.columns["mass"]))
print(f"pipeline: {int(t.count())} joined rows pass the filter; "
      f"page {best} has max incoming mass "
      f"{float(out.columns['mass'][best]):.2f}")

# ---- what the optimizer decides (§5) ---------------------------------------
base = scan("edges", N_EDGES)
cheap = PlanNode(op="udf", name="cheap_filter", cost_per_tuple=1e-9,
                 selectivity=0.3)
pricey = PlanNode(op="udf", name="expensive_udf", cost_per_tuple=1e-6,
                  selectivity=0.9)
plan, cost = best_udf_join_interleaving(
    base, [pricey, cheap],
    lambda n: join(n, scan("pages", N_PAGES), key_fk=True), 1)
print(f"§5.1 interleaving: best plan cost {cost:.4f}s "
      "(cheap+selective UDF pushed below the join, expensive one above)")

g = groupby(rehash(udf(scan("edges", N_EDGES), "w", 1e-9)), "sum",
            n_groups=N_PAGES, composable=True)
print(f"§5.2 pre-agg pushdown: {plan_runtime(g):.4f}s -> "
      f"{plan_runtime(optimize(g)):.4f}s")

total, final_card, iters = estimate_recursive_cost(
    base_cost=0.1, base_card=N_PAGES,
    step_cost_fn=lambda c: c * 2e-7, step_card_fn=lambda c: 0.6 * c)
print(f"§5.3 recursive estimate: {iters} strata simulated, "
      f"total {total:.4f}s, final Δ cardinality {final_card:.0f}")
