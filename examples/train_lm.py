"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch olmo-1b]

Uses the full production train loop (launch/train.py): sharded step,
AdamW + cosine schedule, checkpoint/resume, optional REX-delta gradient
compression.  The default config is a width-reduced olmo family member
sized to run on CPU; on a pod, drop --reduced and set --mesh.
"""
import sys

from repro.launch import train

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "olmo-1b", "--reduced",
                "--steps", "300", "--seq-len", "128",
                "--global-batch", "16", "--lr", "3e-3",
                "--ckpt-every", "100",
                "--compression", "delta"] + sys.argv[1:]
    train.main()
