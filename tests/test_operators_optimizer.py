"""Relational operators (§3.2) + cost-based optimizer (§5) tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.operators import (Table, apply_function, fk_join, group_by,
                                  group_by_uda, project, select,
                                  theta_join_counts)
from repro.core.optimizer import (best_udf_join_interleaving,
                                  estimate_recursive_cost, optimize,
                                  order_udfs_by_rank, push_preaggregation,
                                  worst_case_node_cost)
from repro.core.plan import (PlanNode, fixpoint, groupby, join, plan_runtime,
                             rehash, runtime_of, scan, total_resource, udf)


class TestOperators:
    def _table(self):
        return Table.from_columns(
            k=jnp.array([0, 1, 0, 2, 1], jnp.int32),
            v=jnp.array([1.0, 2.0, 3.0, 4.0, 5.0]))

    def test_select_project(self):
        t = select(self._table(), lambda t: t.columns["v"] > 2.0)
        assert int(t.count()) == 3
        t2 = project(t, ("v",))
        assert list(t2.columns) == ["v"]

    def test_apply_function_udf(self):
        t = apply_function(self._table(), lambda v: {"v2": v * 10.0},
                           ("v",))
        assert float(t.columns["v2"][1]) == 20.0

    def test_group_by_builtins(self):
        out = group_by(self._table(), "k",
                       {"s": ("sum", "v"), "m": ("min", "v"),
                        "c": ("count", "v"), "a": ("average", "v")},
                       n_keys=3)
        assert out.columns["s"].tolist() == [4.0, 7.0, 4.0]
        assert out.columns["m"].tolist() == [1.0, 2.0, 4.0]
        assert out.columns["c"].tolist() == [2.0, 2.0, 1.0]
        assert out.columns["a"].tolist() == [2.0, 3.5, 4.0]

    def test_group_by_respects_validity(self):
        t = select(self._table(), lambda t: t.columns["v"] != 3.0)
        out = group_by(t, "k", {"s": ("sum", "v")}, n_keys=3)
        assert out.columns["s"].tolist() == [1.0, 7.0, 4.0]

    def test_group_by_uda_custom(self):
        def agg_apply(state, keys, vals, valid):
            w = jnp.where(valid, vals, 0.0)
            return state.at[keys, 0].add(w * w)

        def agg_result(state):
            return {"ss": state[:, 0]}

        out = group_by_uda(self._table(), "k", ("v",), agg_apply,
                           agg_result, n_keys=3, state_width=1)
        assert out.columns["ss"].tolist() == [10.0, 29.0, 16.0]

    def test_fk_join(self):
        left = self._table()
        right = Table.from_columns(
            k=jnp.array([0, 1, 2], jnp.int32),
            name=jnp.array([10.0, 11.0, 12.0]))
        out = fk_join(left, right, "k", "k", n_keys=3)
        assert int(out.count()) == 5
        assert float(out.columns["name"][0]) == 10.0

    def test_theta_join_counts(self):
        counts = theta_join_counts(self._table(), self._table(), "k", "k",
                                   n_keys=3)
        assert counts.tolist() == [2, 2, 1]


class TestOptimizer:
    def test_rank_ordering(self):
        """§5.1: cheap/selective predicates first (rank = cost/(1−sel))."""
        cheap = PlanNode(op="udf", name="cheap", cost_per_tuple=1e-9,
                         selectivity=0.9)
        pricey_sel = PlanNode(op="udf", name="pricey_selective",
                              cost_per_tuple=1e-6, selectivity=0.01)
        pricey = PlanNode(op="udf", name="pricey", cost_per_tuple=1e-6,
                          selectivity=0.9)
        order = [u.name for u in
                 order_udfs_by_rank([pricey, cheap, pricey_sel])]
        assert order[0] == "cheap" and order[-1] == "pricey"

    def test_udf_join_interleaving_prefers_filter_before_join(self):
        base = scan("R", 1e6)
        selective = PlanNode(op="udf", name="sel", cost_per_tuple=1e-9,
                             selectivity=0.01)
        expensive = PlanNode(op="udf", name="exp", cost_per_tuple=1e-5,
                             selectivity=0.9)

        def join_builder(node):
            return join(node, scan("S", 1e5), selectivity=1e-6)

        plan, cost = best_udf_join_interleaving(
            base, [selective, expensive], join_builder, 1)

        def names_below_join(n):
            if n.op == "join":
                return names_above(n.children[0])
            return names_below_join(n.children[0]) if n.children else []

        def names_above(n):
            out = []
            while n.children:
                if n.op == "udf":
                    out.append(n.name)
                n = n.children[0]
            return out
        below = names_below_join(plan)
        assert "sel" in below          # selective UDF pushed below join
        assert "exp" not in below      # expensive UDF deferred above

    def test_preagg_pushdown_composable(self):
        """§5.2: composable UDA's combiner crosses rehash and join."""
        base = rehash(scan("R", 1e6))
        g = groupby(base, "sum", n_groups=100, composable=True)
        out = push_preaggregation(g, reduction=0.1)

        def has_preagg_below_rehash(n):
            if n.op == "rehash":
                return n.children[0].op == "preagg"
            return any(has_preagg_below_rehash(c) for c in n.children)
        assert has_preagg_below_rehash(out)
        assert plan_runtime(out) < plan_runtime(g)

    def test_preagg_blocked_for_noncomposable_nonfk(self):
        """§5.2: median can't cross a non-FK join."""
        j = join(scan("R", 1e6), scan("S", 1e3), key_fk=False)
        g = groupby(j, "median", n_groups=10, composable=False)
        out = push_preaggregation(g)
        assert out.children[0].op == "join"   # unchanged

    def test_preagg_crosses_fk_join_when_noncomposable(self):
        j = join(scan("R", 1e6), scan("S", 1e3), key_fk=True)
        g = groupby(j, "median", n_groups=10, composable=False)
        out = push_preaggregation(g)
        assert out.children[0].op == "join"
        assert out.children[0].children[0].op == "preagg"

    def test_recursive_estimation_monotone_caps(self):
        """§5.3: diverging hints are capped; estimation terminates."""
        total, card, iters = estimate_recursive_cost(
            base_cost=1.0, base_card=1000.0,
            step_cost_fn=lambda c: c * 1e-3,
            step_card_fn=lambda c: c * 2.0,      # divergent hint!
            max_iters=50)
        assert iters == 50 and card <= 1000.0    # capped, not exploded
        total2, card2, iters2 = estimate_recursive_cost(
            1.0, 1000.0, lambda c: c * 1e-3, lambda c: c * 0.5)
        assert iters2 < 50 and card2 < 1.0       # converging case ends

    def test_resource_vector_overlap(self):
        """§5: pipelined runtime = max lane, not sum."""
        v = (3.0, 1.0, 2.0)
        assert runtime_of(v, pipelined=True) == 3.0
        assert runtime_of(v, pipelined=False) == 6.0

    def test_worst_case_node_cost(self):
        assert worst_case_node_cost([1.0, 5.0, 2.0]) == 5.0

    def test_optimize_whole_plan_improves(self):
        plan = groupby(rehash(udf(scan("R", 1e6), "f", 1e-8)), "sum",
                       n_groups=10)
        assert plan_runtime(optimize(plan)) <= plan_runtime(plan)


@settings(max_examples=30, deadline=None)
@given(costs=st.lists(st.floats(1e-9, 1e-5), min_size=2, max_size=6),
       sels=st.lists(st.floats(0.01, 0.99), min_size=2, max_size=6))
def test_property_rank_order_minimizes_chain_cost(costs, sels):
    """Property (Hellerstein): rank order beats any adjacent swap."""
    n = min(len(costs), len(sels))
    udfs = [PlanNode(op="udf", name=f"u{i}", cost_per_tuple=costs[i],
                     selectivity=sels[i]) for i in range(n)]
    ordered = order_udfs_by_rank(udfs)

    def chain_cost(seq, card=1e6):
        total = 0.0
        for u in seq:
            total += card * u.cost_per_tuple
            card *= u.selectivity
        return total

    best = chain_cost(ordered)
    for i in range(n - 1):
        swapped = list(ordered)
        swapped[i], swapped[i + 1] = swapped[i + 1], swapped[i]
        assert best <= chain_cost(swapped) * (1 + 1e-9)
