"""Sort vs scatter combine-route strategies: equivalence + dispatch.

The contract of the physical rehash strategies is that they change HOW a
stratum's deltas are grouped, never WHAT the stratum computes: the
scatter-based ``combine_route_scatter`` must reproduce the sort-based
``combine_route`` slot for slot — keys, annotations, counts, overflow —
across combiners, overflowing segment capacities, all-padding buffers,
out-of-range owners, and both partition schemes.  Payloads are
bit-identical for min/max/replace (order-free or single-writer merges);
float "add" is compared to addition order (≤1 ulp reassociation).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.algorithms import pagerank, sssp
from repro.core.delta import (ANN_ADJUST, PAD_KEY, DeltaBuffer,
                              combine_route, combine_route_scatter)
from repro.core.engine import ShardedExecutor
from repro.core.fixpoint import ROUTE_SCATTER, ROUTE_SORT
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import make_powerlaw_graph, shard_csr


def _random_buffer(rng, n, keyspace, payload_width=2):
    count = int(rng.integers(0, n + 1))          # 0 = all-padding buffer
    keys = np.full(n, -1, np.int32)
    keys[:count] = rng.integers(0, keyspace, count)
    pay = rng.normal(size=(n, payload_width)).astype(np.float32)
    pay[count:] = 0
    return DeltaBuffer(
        keys=jnp.asarray(keys), payload=jnp.asarray(pay),
        ann=jnp.full(n, ANN_ADJUST, jnp.int8),
        count=jnp.asarray(count),
        overflowed=jnp.asarray(bool(rng.integers(0, 2))))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 9999),
       shards=st.sampled_from([1, 2, 4, 5, 8]),
       combiner=st.sampled_from(["add", "min", "max", "replace"]))
def test_scatter_equals_sort_strategy(seed, shards, combiner):
    """Property: the scatter-slab combine-route is element-wise identical
    to the fused-sort combine_route — keys/ann/count/overflow exact for
    every combiner, payload bits exact for min/max/replace — over small
    caps (overflow), all-padding buffers, out-of-range owners, and both
    block and hash partition schemes."""
    rng = np.random.default_rng(seed)
    n, keyspace = 48, 24
    cap = int(rng.integers(1, n + 2))            # small caps overflow
    db = _random_buffer(rng, n, keyspace)
    snap = PartitionSnapshot(n_keys=keyspace, num_shards=shards,
                             scheme=("block", "hash")[seed % 2])
    owners = snap.owner_of(db.keys)
    # Out-of-range owners drop the whole key — corrupt per key VALUE so
    # the assignment stays a function of the key (the scatter contract).
    owners = jnp.where((db.keys % 5 == 0) & (db.keys >= 0),
                       shards + 3, owners)
    ref = combine_route(db, owners, shards, cap, combiner)
    got = combine_route_scatter(db, owners, shards, cap, combiner,
                                snapshot=snap)
    np.testing.assert_array_equal(np.asarray(ref.keys),
                                  np.asarray(got.keys))
    np.testing.assert_array_equal(np.asarray(ref.ann), np.asarray(got.ann))
    assert int(ref.count) == int(got.count)
    assert bool(ref.overflowed) == bool(got.overflowed)
    if combiner == "add":
        np.testing.assert_allclose(np.asarray(ref.payload),
                                   np.asarray(got.payload),
                                   rtol=1e-6, atol=1e-6)
    else:
        np.testing.assert_array_equal(np.asarray(ref.payload),
                                      np.asarray(got.payload))


def test_scatter_all_padding():
    db = DeltaBuffer.empty(16, 1)
    snap = PartitionSnapshot(n_keys=32, num_shards=4)
    out = combine_route_scatter(db, jnp.full((16,), -1, jnp.int32), 4, 8,
                                "add", snapshot=snap)
    assert int(out.count) == 0 and not bool(out.overflowed)
    assert bool(jnp.all(out.keys == PAD_KEY))


def test_to_dense_replace_combiner():
    """DeltaBuffer.to_dense("replace"): last live slot of a key wins
    (parity with combine_route's replace semantics)."""
    keys = jnp.array([2, 1, 2, PAD_KEY], jnp.int32)
    pay = jnp.array([[5.0], [7.0], [9.0], [99.0]])
    db = DeltaBuffer(keys=keys, payload=pay, ann=jnp.zeros(4, jnp.int8),
                     count=jnp.asarray(3), overflowed=jnp.asarray(False))
    out = db.to_dense(4, "replace")
    assert out.tolist() == [0.0, 7.0, 9.0, 0.0]


class TestAutoDispatch:
    def _exec(self, snap, **kw):
        return ShardedExecutor(snapshot=snap, seg_capacity=16384,
                               edge_capacity=16384, src_capacity=1024, **kw)

    def test_cost_model_crossover(self):
        """Auto picks scatter when the slab is small next to C·log₂C and
        keeps the sort for tiny rungs on huge key spaces."""
        small = PartitionSnapshot(n_keys=4096, num_shards=8)
        ex = self._exec(small, route_strategy="auto")
        assert ex.pick_route_strategy(65536, "add") == "scatter"
        huge = PartitionSnapshot(n_keys=1 << 22, num_shards=8)
        ex2 = self._exec(huge, route_strategy="auto")
        assert ex2.pick_route_strategy(256, "add") == "sort"

    def test_non_composable_combiner_forces_sort(self):
        snap = PartitionSnapshot(n_keys=4096, num_shards=8)
        ex = self._exec(snap, route_strategy="auto")
        assert ex.pick_route_strategy(65536, None) == "sort"
        ex2 = self._exec(snap, route_strategy="scatter")
        assert ex2.pick_route_strategy(65536, None) == "sort"

    def test_invalid_strategy_rejected(self):
        snap = PartitionSnapshot(n_keys=64, num_shards=4)
        with pytest.raises(ValueError):
            self._exec(snap, route_strategy="quantum").pick_route_strategy(
                256, "add")


@pytest.fixture(scope="module")
def graph():
    n, S = 1024, 4
    indptr, indices = make_powerlaw_graph(n, avg_degree=8.0, seed=0)
    snap = PartitionSnapshot(n_keys=n, num_shards=S)
    return snap, shard_csr(indptr, indices, S)


def test_strategy_invariant_end_to_end(graph):
    """Full PageRank fixpoint under sort / scatter / auto: identical delta
    counts, rehash bytes, tier dispatch, and (on XLA CPU, where scatter
    updates apply in slot order) bit-identical float state."""
    snap, g = graph
    caps = dict(edge_capacity=16384, src_capacity=snap.block_size)
    runs = {}
    for strat in ("sort", "scatter", "auto"):
        runs[strat] = pagerank.run(g, snap, mode="delta", ladder_tiers=4,
                                   route_strategy=strat, **caps)
    pr0, r0 = runs["sort"]
    for strat in ("scatter", "auto"):
        pr, r = runs[strat]
        for field in ("delta_counts", "rehash_bytes", "used_dense",
                      "tiers"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r.stats, field)),
                np.asarray(getattr(r0.stats, field)),
                err_msg=f"{strat}:{field}")
        np.testing.assert_allclose(np.asarray(pr), np.asarray(pr0),
                                   rtol=1e-6, atol=1e-7, err_msg=strat)

    iters = int(r0.stats.iterations)
    assert np.all(np.asarray(r0.stats.routes)[:iters] == ROUTE_SORT)
    routes_scatter = np.asarray(runs["scatter"][1].stats.routes)[:iters]
    assert np.all(routes_scatter == ROUTE_SCATTER)


def test_sssp_scatter_bit_identical(graph):
    """min-combiner merges are order-free: the scatter strategy must be
    bit-identical to the sort strategy, not merely close."""
    snap, g = graph
    caps = dict(edge_capacity=16384, src_capacity=snap.block_size)
    d0, r0 = sssp.run(g, snap, mode="delta", source=0,
                      route_strategy="sort", **caps)
    d1, r1 = sssp.run(g, snap, mode="delta", source=0,
                      route_strategy="scatter", **caps)
    assert bool(jnp.all(d0 == d1))
    np.testing.assert_array_equal(np.asarray(r0.stats.delta_counts),
                                  np.asarray(r1.stats.delta_counts))


def test_pallas_route_in_loop_matches_jnp(graph):
    """use_pallas_route dispatches the delta_route / scatter_route kernels
    inside the stratum body (interpret mode on CPU); SSSP's min combiner
    makes both kernel paths bit-exact against the jnp engine."""
    snap, g = graph
    caps = dict(edge_capacity=2048, src_capacity=snap.block_size)

    def ex(**kw):
        return ShardedExecutor(snapshot=snap, seg_capacity=2048,
                               edge_capacity=2048,
                               src_capacity=snap.block_size, **kw)

    d0, r0 = sssp.run(g, snap, mode="delta", source=0, executor=ex(),
                      **caps)
    for strat in ("sort", "scatter"):
        d1, r1 = sssp.run(g, snap, mode="delta", source=0,
                          executor=ex(route_strategy=strat,
                                      use_pallas_route=True), **caps)
        assert bool(jnp.all(d0 == d1)), strat
        np.testing.assert_array_equal(
            np.asarray(r0.stats.delta_counts),
            np.asarray(r1.stats.delta_counts), err_msg=strat)
        np.testing.assert_array_equal(
            np.asarray(r0.stats.rehash_bytes),
            np.asarray(r1.stats.rehash_bytes), err_msg=strat)
