"""Paper-algorithm correctness: delta vs dense vs oracle (§6 validation).

The central REX invariant (property-tested): for converging jobs, delta
execution and dense execution reach the same fixpoint (within a
threshold-scaled tolerance for value algorithms; exactly for the
monotone-discrete ones), while the delta mode's per-stratum work shrinks.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.algorithms import (adsorption, connected_components as cc,
                              kmeans, pagerank, sssp)
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import make_powerlaw_graph, shard_csr
from repro.data.points import make_geo_points, sample_initial_centroids

N, S = 512, 4
CAP = dict(edge_capacity=8192, src_capacity=512)


@pytest.fixture(scope="module")
def graph():
    indptr, indices = make_powerlaw_graph(N, avg_degree=8.0, seed=0)
    snap = PartitionSnapshot(n_keys=N, num_shards=S)
    return indptr, indices, snap, shard_csr(indptr, indices, S)


class TestPageRank:
    def test_delta_close_to_oracle(self, graph):
        indptr, indices, snap, g = graph
        pr, res = pagerank.run(g, snap, mode="delta", threshold=1e-5,
                               max_iters=120, **CAP)
        ref = pagerank.reference_pagerank(indptr, indices, N, iters=300)
        assert float(jnp.max(jnp.abs(pr[:N] - ref))) < 5e-3

    def test_delta_dense_same_fixpoint(self, graph):
        _, _, snap, g = graph
        pr_d, _ = pagerank.run(g, snap, mode="delta", threshold=1e-5,
                               max_iters=120, **CAP)
        pr_n, _ = pagerank.run(g, snap, mode="nodelta", threshold=1e-5,
                               max_iters=120, **CAP)
        assert float(jnp.max(jnp.abs(pr_d - pr_n))) < 5e-3

    def test_delta_counts_shrink(self, graph):
        """Fig 2: the Δᵢ set decreases as PageRank converges."""
        _, _, snap, g = graph
        _, res = pagerank.run(g, snap, mode="delta", threshold=1e-4,
                              max_iters=100, **CAP)
        counts = np.asarray(res.stats.delta_counts)
        iters = int(res.stats.iterations)
        assert counts[iters - 1] < counts[0]
        # late-phase mean well below early-phase mean
        assert counts[iters // 2:iters].mean() < counts[:iters // 2].mean()

    def test_tighter_threshold_more_accurate(self, graph):
        indptr, indices, snap, g = graph
        ref = pagerank.reference_pagerank(indptr, indices, N, iters=300)
        errs = []
        for thr in (1e-2, 1e-4):
            pr, _ = pagerank.run(g, snap, mode="delta", threshold=thr,
                                 max_iters=200, **CAP)
            errs.append(float(jnp.max(jnp.abs(pr[:N] - ref))))
        assert errs[1] < errs[0]

    def test_bandwidth_delta_below_dense(self, graph):
        """Fig 11: delta moves fewer bytes than dense re-derivation."""
        _, _, snap, g = graph
        _, rd = pagerank.run(g, snap, mode="delta", threshold=1e-3,
                             max_iters=60, **CAP)
        _, rn = pagerank.run(g, snap, mode="nodelta", threshold=1e-3,
                             max_iters=60, **CAP)
        assert (float(jnp.sum(rd.stats.rehash_bytes))
                < float(jnp.sum(rn.stats.rehash_bytes)))


class TestSSSP:
    def test_exact_vs_bfs_oracle(self, graph):
        indptr, indices, snap, g = graph
        d, _ = sssp.run(g, snap, source=0, mode="delta", max_iters=80,
                        **CAP)
        ref = sssp.reference_sssp(indptr, indices, N, 0)
        finite = jnp.isfinite(ref)
        assert bool(jnp.all(jnp.where(finite, d[:N] == ref,
                                      ~jnp.isfinite(d[:N]))))

    def test_delta_equals_dense_exactly(self, graph):
        _, _, snap, g = graph
        d1, _ = sssp.run(g, snap, source=0, mode="delta", max_iters=80,
                         **CAP)
        d2, _ = sssp.run(g, snap, source=0, mode="nodelta", max_iters=80,
                         **CAP)
        both = jnp.isfinite(d1) | jnp.isfinite(d2)
        assert bool(jnp.all(jnp.where(both, d1 == d2, True)))

    def test_frontier_is_delta_set(self, graph):
        """Paper §6.3: Δᵢ for SSSP = the BFS frontier — emitted counts
        rise with the frontier expansion then collapse at convergence."""
        indptr, indices, snap, g = graph
        _, res = sssp.run(g, snap, source=0, mode="delta", max_iters=80,
                          **CAP)
        counts = np.asarray(res.stats.delta_counts)
        iters = int(res.stats.iterations)
        assert iters < 80                       # converged (implicit term.)
        assert counts[:iters].max() > counts[iters - 1]
        assert counts[iters:].sum() == 0        # nothing after fixpoint


class TestKMeans:
    def test_delta_matches_lloyd(self):
        pts = make_geo_points(1024, n_true_clusters=8, seed=0)
        init = sample_initial_centroids(pts, 8, seed=1)
        c, _ = kmeans.run(pts.reshape(4, 256, 2), init, mode="delta")
        ref = kmeans.reference_kmeans(pts, init)
        assert float(jnp.max(jnp.abs(c - ref))) < 1e-3

    def test_delta_equals_dense(self):
        pts = make_geo_points(512, n_true_clusters=4, seed=2)
        init = sample_initial_centroids(pts, 4, seed=3)
        cd, rd = kmeans.run(pts.reshape(4, 128, 2), init, mode="delta")
        cn, rn = kmeans.run(pts.reshape(4, 128, 2), init, mode="nodelta")
        assert float(jnp.max(jnp.abs(cd - cn))) < 1e-5
        assert int(rd.stats.iterations) == int(rn.stats.iterations)

    def test_switch_counts_shrink(self):
        pts = make_geo_points(2048, n_true_clusters=16, seed=4)
        init = sample_initial_centroids(pts, 16, seed=5)
        _, res = kmeans.run(pts.reshape(4, 512, 2), init, mode="delta")
        counts = np.asarray(res.stats.delta_counts)
        iters = int(res.stats.iterations)
        assert counts[iters - 1] <= counts[0]


class TestCCAndAdsorption:
    def test_cc_matches_oracle(self, graph):
        indptr, indices, snap, g = graph
        lab, _ = cc.run(g, snap, mode="delta", max_iters=100, **CAP)
        ref = cc.reference_components(indptr, indices, N)
        assert bool(jnp.all(lab[:N] == ref))

    def test_adsorption_delta_close_to_dense(self, graph):
        _, _, snap, g = graph
        seeds = np.zeros((snap.padded_keys, 4), np.float32)
        seeds[np.arange(16), np.arange(16) % 4] = 1.0
        v_d, _ = adsorption.run(g, snap, jnp.asarray(seeds), mode="delta",
                                threshold=1e-4, max_iters=60, **CAP)
        v_n, _ = adsorption.run(g, snap, jnp.asarray(seeds),
                                mode="nodelta", threshold=1e-4,
                                max_iters=60, **CAP)
        assert float(jnp.max(jnp.abs(v_d - v_n))) < 5e-2


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 99), nshards=st.sampled_from([2, 4, 8]))
def test_property_sssp_shard_invariance(seed, nshards):
    """Property: the fixpoint is invariant to the partition snapshot."""
    n = 256
    indptr, indices = make_powerlaw_graph(n, avg_degree=6.0, seed=seed)
    snap = PartitionSnapshot(n_keys=n, num_shards=nshards)
    g = shard_csr(indptr, indices, nshards)
    d, _ = sssp.run(g, snap, source=0, mode="delta", max_iters=60,
                    edge_capacity=4096, src_capacity=256)
    ref = sssp.reference_sssp(indptr, indices, n, 0)
    finite = jnp.isfinite(ref)
    assert bool(jnp.all(jnp.where(finite, d[:n] == ref,
                                  ~jnp.isfinite(d[:n]))))


def test_overflow_falls_back_densely_and_stays_correct():
    """Tiny capacities force dense fallback strata; result is unchanged
    (the bounded-sparsity adaptation is lossless)."""
    n = 256
    indptr, indices = make_powerlaw_graph(n, avg_degree=6.0, seed=7)
    snap = PartitionSnapshot(n_keys=n, num_shards=4)
    g = shard_csr(indptr, indices, 4)
    d, res = sssp.run(g, snap, source=0, mode="delta", max_iters=60,
                      edge_capacity=64, src_capacity=16)
    assert bool(jnp.any(res.stats.used_dense))  # fallback actually hit
    ref = sssp.reference_sssp(indptr, indices, n, 0)
    finite = jnp.isfinite(ref)
    assert bool(jnp.all(jnp.where(finite, d[:n] == ref,
                                  ~jnp.isfinite(d[:n]))))
