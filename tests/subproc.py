"""Shared subprocess harness for tests that need their own jax device
world (virtual multi-device via XLA_FLAGS, or a real multi-process
bring-up) — the main pytest process must keep seeing 1 device.

``run_sub`` was originally copied across test modules; it lives here so
every subprocess test shares one failure-reporting contract:

  * env overrides are an explicit dict (applied LAST, so a caller can
    override XLA_FLAGS / PYTHONPATH when it needs to),
  * the timeout comes from ``REPRO_SUBPROC_TIMEOUT`` (seconds, default
    900) instead of a hard-coded constant — slow CI boxes raise it,
    laptops lower it,
  * a failing subprocess reports BOTH stream tails plus the exact
    reproducible command (mesh/backend failures often print the real
    cause to stdout: jax warnings, our own asserts).
"""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def default_timeout() -> float:
    return float(os.environ.get("REPRO_SUBPROC_TIMEOUT", "900"))


def run_sub(code: str, devices: int = 8, env: dict | None = None,
            timeout: float | None = None) -> str:
    """Run ``code`` in a fresh interpreter with ``devices`` virtual CPU
    devices and repro on PYTHONPATH; returns its stdout, asserts rc 0."""
    e = dict(os.environ)
    e["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    e["PYTHONPATH"] = SRC
    if env:
        e.update(env)
    if timeout is None:
        timeout = default_timeout()
    cmd = [sys.executable, "-c", code]
    out = subprocess.run(cmd, env=e, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, (
        f"subprocess exited {out.returncode}\n"
        f"command: XLA_FLAGS={e['XLA_FLAGS']!r} "
        f"PYTHONPATH={e['PYTHONPATH']!r} {' '.join(cmd[:-1])} <code below>\n"
        f"--- stderr (tail) ---\n{out.stderr[-3000:]}\n"
        f"--- stdout (tail) ---\n{out.stdout[-2000:]}\n"
        f"--- code ---\n{code}")
    return out.stdout
