"""Unit + property tests for the delta core (paper §3.3 semantics)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.delta import (ANN_ADJUST, ANN_DELETE, ANN_INSERT,
                              ANN_REPLACE, PAD_KEY, DeltaBuffer,
                              combine_route, concat, recount,
                              route_by_owner)
from repro.core.handlers import (BUILTIN_UDAS, apply_annotated,
                                 pre_aggregate)
from repro.core.partition import (PartitionSnapshot, shard_dense_state,
                                  unshard_dense_state)


class TestDeltaBuffer:
    def test_empty(self):
        db = DeltaBuffer.empty(8, 2)
        assert db.capacity == 8 and db.payload_width == 2
        assert int(db.count) == 0 and not bool(db.overflowed)

    def test_from_dense_mask_compaction(self):
        mask = jnp.array([True, False, True, False, True])
        keys = jnp.arange(5, dtype=jnp.int32)
        pay = jnp.arange(5, dtype=jnp.float32)[:, None]
        db = DeltaBuffer.from_dense_mask(mask, keys, pay, capacity=4)
        assert int(db.count) == 3
        assert db.keys[:3].tolist() == [0, 2, 4]
        assert not bool(db.overflowed)

    def test_overflow_flagged(self):
        mask = jnp.ones(5, jnp.bool_)
        keys = jnp.arange(5, dtype=jnp.int32)
        pay = jnp.ones((5, 1), jnp.float32)
        db = DeltaBuffer.from_dense_mask(mask, keys, pay, capacity=3)
        assert bool(db.overflowed) and int(db.count) == 3

    def test_to_dense_combiners(self):
        keys = jnp.array([1, 1, 2, PAD_KEY], jnp.int32)
        pay = jnp.array([[2.0], [3.0], [5.0], [99.0]])
        db = DeltaBuffer(keys=keys, payload=pay,
                         ann=jnp.zeros(4, jnp.int8),
                         count=jnp.asarray(3), overflowed=jnp.asarray(False))
        assert db.to_dense(4, "add").tolist() == [0.0, 5.0, 5.0, 0.0]
        assert db.to_dense(4, "min")[1] == 2.0

    def test_concat(self):
        a = DeltaBuffer.from_dense_mask(
            jnp.array([True]), jnp.array([3], jnp.int32),
            jnp.array([[1.0]]), 2)
        b = DeltaBuffer.from_dense_mask(
            jnp.array([True]), jnp.array([5], jnp.int32),
            jnp.array([[2.0]]), 2)
        c = concat(a, b)
        assert int(c.count) == 2
        assert sorted(c.keys[:2].tolist()) == [3, 5]

    def test_concat_preserves_annotations(self):
        """Regression: concat used to rebuild via from_dense_mask and stamp
        every slot ANN_ADJUST, corrupting insert/delete/replace deltas."""
        a = DeltaBuffer(
            keys=jnp.array([3, PAD_KEY, 7], jnp.int32),
            payload=jnp.array([[1.0], [0.0], [2.0]]),
            ann=jnp.array([ANN_INSERT, ANN_ADJUST, ANN_DELETE], jnp.int8),
            count=jnp.asarray(2), overflowed=jnp.asarray(False))
        b = DeltaBuffer(
            keys=jnp.array([9, 4], jnp.int32),
            payload=jnp.array([[3.0], [4.0]]),
            ann=jnp.array([ANN_REPLACE, ANN_ADJUST], jnp.int8),
            count=jnp.asarray(2), overflowed=jnp.asarray(False))
        c = concat(a, b)
        got = {int(k): int(an) for k, an in
               zip(c.keys.tolist(), c.ann.tolist()) if k != -1}
        assert got == {3: ANN_INSERT, 7: ANN_DELETE, 9: ANN_REPLACE,
                       4: ANN_ADJUST}
        assert int(c.count) == 4


@settings(max_examples=30, deadline=None)
@given(n=st.integers(4, 64), shards=st.integers(1, 8),
       seed=st.integers(0, 999))
def test_route_by_owner_preserves_deltas(n, shards, seed):
    """Property: routing by owner is a permutation of live deltas (none
    lost, none duplicated) when capacity suffices."""
    rng = np.random.default_rng(seed)
    count = rng.integers(0, n + 1)
    keys = np.full(n, -1, np.int32)
    keys[:count] = rng.integers(0, 100, count)
    pay = rng.normal(size=(n, 1)).astype(np.float32)
    pay[count:] = 0
    db = DeltaBuffer(keys=jnp.asarray(keys), payload=jnp.asarray(pay),
                     ann=jnp.zeros(n, jnp.int8),
                     count=jnp.asarray(count),
                     overflowed=jnp.asarray(False))
    snap = PartitionSnapshot(n_keys=100, num_shards=shards)
    owners = snap.owner_of(db.keys)
    routed = route_by_owner(db, owners, shards, per_shard_capacity=n)
    live_in = sorted(zip(keys[:count].tolist(),
                         pay[:count, 0].tolist()))
    out_keys = np.asarray(routed.keys)
    out_pay = np.asarray(routed.payload)
    live_out = sorted((int(k), float(p)) for k, p in
                      zip(out_keys, out_pay[:, 0]) if k != -1)
    assert live_in == live_out
    assert not bool(routed.overflowed)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 999), combiner=st.sampled_from(["add", "min"]))
def test_pre_aggregate_equiv_dense(seed, combiner):
    """Property: pre-aggregation (the §5.2 combiner) never changes the
    dense materialization of a delta buffer."""
    rng = np.random.default_rng(seed)
    n, keyspace = 32, 8
    count = rng.integers(1, n)
    keys = np.full(n, -1, np.int32)
    keys[:count] = rng.integers(0, keyspace, count)
    pay = rng.normal(size=(n, 1)).astype(np.float32)
    pay[count:] = 0
    db = DeltaBuffer(keys=jnp.asarray(keys), payload=jnp.asarray(pay),
                     ann=jnp.full(n, ANN_ADJUST, jnp.int8),
                     count=jnp.asarray(count),
                     overflowed=jnp.asarray(False))
    agg = pre_aggregate(db, combiner)
    assert int(agg.count) <= int(db.count)
    np.testing.assert_allclose(
        np.asarray(db.to_dense(keyspace, combiner)),
        np.asarray(agg.to_dense(keyspace, combiner)), rtol=1e-5,
        atol=1e-5)


def _compose_reference(db, snap, shards, cap, combiner):
    """The two-pass pipeline the fused operator replaces."""
    agg = pre_aggregate(db, combiner)
    owners = snap.owner_of(agg.keys)
    return route_by_owner(agg, owners, shards, cap)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 9999), shards=st.integers(1, 8),
       combiner=st.sampled_from(["add", "min", "max", "replace"]))
def test_combine_route_equals_composition(seed, shards, combiner):
    """Property: the fused single-sort combine-route is element-wise
    identical (keys, payload bits, ann, count, overflow) to
    pre_aggregate ∘ route_by_owner — across combiners, overflowing
    segment capacities, and all-padding buffers."""
    rng = np.random.default_rng(seed)
    n, keyspace = 48, 24
    count = int(rng.integers(0, n + 1))          # 0 = all-padding buffer
    cap = int(rng.integers(1, n + 2))            # small caps overflow
    keys = np.full(n, -1, np.int32)
    keys[:count] = rng.integers(0, keyspace, count)
    pay = rng.normal(size=(n, 2)).astype(np.float32)
    pay[count:] = 0
    db = DeltaBuffer(keys=jnp.asarray(keys), payload=jnp.asarray(pay),
                     ann=jnp.full(n, ANN_ADJUST, jnp.int8),
                     count=jnp.asarray(count),
                     overflowed=jnp.asarray(bool(rng.integers(0, 2))))
    snap = PartitionSnapshot(n_keys=keyspace, num_shards=shards,
                             scheme=("block", "hash")[seed % 2])
    ref = _compose_reference(db, snap, shards, cap, combiner)
    got = combine_route(db, snap.owner_of(db.keys), shards, cap, combiner)
    assert np.array_equal(np.asarray(ref.keys), np.asarray(got.keys))
    np.testing.assert_array_equal(np.asarray(ref.payload),
                                  np.asarray(got.payload))
    assert np.array_equal(np.asarray(ref.ann), np.asarray(got.ann))
    assert int(ref.count) == int(got.count)
    assert bool(ref.overflowed) == bool(got.overflowed)


def test_combine_route_all_padding():
    db = DeltaBuffer.empty(16, 1)
    out = combine_route(db, jnp.full((16,), -1, jnp.int32), 4, 8, "add")
    assert int(out.count) == 0 and not bool(out.overflowed)
    assert bool(jnp.all(out.keys == PAD_KEY))


class TestAnnotations:
    def test_insert_delete_replace_adjust(self):
        state = jnp.zeros(4)
        exists = jnp.zeros(4, jnp.bool_)
        db = DeltaBuffer(
            keys=jnp.array([0, 1, 0, 2], jnp.int32),
            payload=jnp.array([[5.0], [7.0], [0.0], [3.0]]),
            ann=jnp.array([ANN_INSERT, ANN_INSERT, ANN_DELETE,
                           ANN_ADJUST], jnp.int8),
            count=jnp.asarray(4), overflowed=jnp.asarray(False))
        state, exists = apply_annotated(state, exists, db)
        assert not bool(exists[0])          # inserted then deleted
        assert bool(exists[1]) and float(state[1]) == 7.0
        assert bool(exists[2]) and float(state[2]) == 3.0


class TestPartition:
    def test_block_owner_local_roundtrip(self):
        snap = PartitionSnapshot(n_keys=100, num_shards=8)
        keys = jnp.arange(100, dtype=jnp.int32)
        owner = snap.owner_of(keys)
        local = snap.local_index(keys)
        recon = owner * snap.block_size + local
        assert jnp.all(recon == keys)

    def test_replica_chain(self):
        snap = PartitionSnapshot(n_keys=10, num_shards=4, replication=3)
        assert snap.replicas_of(3) == [0, 1]

    def test_shard_unshard_roundtrip(self):
        snap = PartitionSnapshot(n_keys=10, num_shards=4)
        x = jnp.arange(10.0)
        assert jnp.all(unshard_dense_state(
            snap, shard_dense_state(snap, x)) == x)

    def test_hash_scheme_in_range(self):
        snap = PartitionSnapshot(n_keys=1000, num_shards=7, scheme="hash")
        owners = snap.owner_of(jnp.arange(1000, dtype=jnp.int32))
        assert int(owners.min()) >= 0 and int(owners.max()) < 7


def test_builtin_udas_cover_paper_set():
    for name in ("sum", "count", "min", "max", "average", "median"):
        assert name in BUILTIN_UDAS
    assert not BUILTIN_UDAS["median"].composable   # §5.2 non-composable
    assert BUILTIN_UDAS["sum"].composable
