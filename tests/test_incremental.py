"""Incremental view maintenance: warm delta repair == cold recompute.

The strong property for the graph views is *structural*: after a refresh,
the resident state must be a converged state of the MUTATED base data —
for SSSP/CC the fixpoint is unique so warm equals cold exactly; for
PageRank both are τ-residual states, so we assert the acc invariant and
residual tightly and the warm/cold gap loosely (the ∞-norm gap between two
τ-converged states is amplified by in-degree mass).

Long-lived module fixtures intentionally accumulate mutations across
property examples: that is exactly the standing-query regime, and it
keeps every example on the already-traced fixpoint.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.algorithms.pagerank import reference_pagerank
from repro.algorithms.sssp import reference_sssp
from repro.core.delta import ANN_ADJUST, ANN_DELETE, ANN_REPLACE
from repro.core.fixpoint import empty_stats, merge_stats
from repro.data.graphs import edges_to_csr, make_powerlaw_graph
from repro.incremental import (EdgeDelete, EdgeInsert, EdgeReweight,
                               GraphStore, MutationLog, PointInsert,
                               PointRemove, ViewManager)

N = 128
SHARDS = 4


def random_edge_batch(store: GraphStore, rng, n_ins: int, n_del: int):
    muts = [EdgeInsert(int(rng.integers(store.n)), int(rng.integers(store.n)))
            for _ in range(n_ins)]
    src, dst = store.edges()
    if n_del and len(src):
        for i in rng.choice(len(src), min(n_del, len(src)), replace=False):
            muts.append(EdgeDelete(int(src[i]), int(dst[i])))
    return muts


def assert_finite_equal(warm, cold, atol=0.0):
    assert np.array_equal(np.isfinite(warm), np.isfinite(cold))
    m = np.isfinite(cold)
    np.testing.assert_allclose(warm[m], cold[m], atol=atol, rtol=0)


# ---------------------------------------------------------------------------
# PageRank
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pr_view():
    indptr, indices = make_powerlaw_graph(N, avg_degree=5, seed=11)
    mgr = ViewManager(fallback_threshold=1.0)
    view = mgr.create_graph_view("pr", "pagerank", indptr, indices, N,
                                 num_shards=SHARDS, threshold=1e-4,
                                 max_iters=120)
    return mgr, view


def pr_invariant_errors(view):
    """(acc-invariant error, convergence residual) of the resident state."""
    sent = np.asarray(view.state.sent, np.float64).reshape(-1)
    acc = np.asarray(view.state.acc, np.float64).reshape(-1)
    src, dst = view.store.edges()
    deg = view.store.out_degree_of(np.arange(view.store.n))
    expect = np.zeros_like(acc)
    np.add.at(expect, dst, sent[src] / np.maximum(deg[src], 1))
    inv = np.abs(acc - expect).max()
    res = np.abs(0.15 + 0.85 * acc - sent).max()
    return inv, res


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 9999))
def test_pagerank_warm_repair_matches_cold(pr_view, seed):
    mgr, view = pr_view
    rng = np.random.default_rng(seed)
    mgr.mutate("pr", *random_edge_batch(view.store, rng, 4, 3))
    report = mgr.refresh("pr")["pr"]
    assert report.mode in ("repair", "cold")

    inv, res = pr_invariant_errors(view)
    assert inv < 2e-3          # acc == Σ sent/deg on the NEW graph (f32)
    assert res < 1.5e-4        # τ-converged

    warm = mgr.query("pr")
    state, _ = view.rule.cold(view)
    cold = view.rule.extract(view, state)
    np.testing.assert_allclose(warm, cold, atol=0.05, rtol=0)

    src, dst = view.store.edges()
    indptr, indices = edges_to_csr(src, dst, N)
    oracle = np.asarray(reference_pagerank(indptr, indices, N, iters=300))
    np.testing.assert_allclose(warm, oracle, atol=0.05, rtol=0)


# ---------------------------------------------------------------------------
# SSSP (unique fixpoint: exact equality, including deletion repair)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sp_view():
    indptr, indices = make_powerlaw_graph(N, avg_degree=3, seed=5)
    mgr = ViewManager(fallback_threshold=1.0)
    view = mgr.create_graph_view("sp", "sssp", indptr, indices, N,
                                 num_shards=SHARDS, source=0, max_iters=100)
    return mgr, view


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 9999))
def test_sssp_warm_repair_matches_cold(sp_view, seed):
    mgr, view = sp_view
    rng = np.random.default_rng(seed)
    mgr.mutate("sp", *random_edge_batch(view.store, rng, 3, 3))
    mgr.refresh("sp")
    warm = mgr.query("sp")
    src, dst = view.store.edges()
    indptr, indices = edges_to_csr(src, dst, N)
    oracle = np.asarray(reference_sssp(indptr, indices, N, source=0))
    assert_finite_equal(warm, oracle)


def test_sssp_bridge_deletion_exercises_closure_and_fallback():
    # Path graph: deleting one early edge invalidates everything downstream.
    n = 64
    src = np.arange(n - 1)
    dst = np.arange(1, n)
    indptr, indices = edges_to_csr(src, dst, n)

    # Tight threshold: the big closure must trigger the cold fallback.
    mgr = ViewManager(fallback_threshold=0.15)
    view = mgr.create_graph_view("sp", "sssp", indptr, indices, n,
                                 num_shards=SHARDS, source=0, max_iters=100)
    mgr.mutate("sp", EdgeDelete(3, 4))
    report = mgr.refresh("sp")["sp"]
    assert report.mode == "cold"
    assert report.touched_keys >= n - 4      # the whole downstream closure
    warm = mgr.query("sp")
    assert np.array_equal(warm[:4], np.arange(4, dtype=np.float32))
    assert not np.isfinite(warm[4:]).any()

    # Permissive threshold: same deletion must repair in place, exactly.
    mgr2 = ViewManager(fallback_threshold=2.0)
    view2 = mgr2.create_graph_view("sp", "sssp", indptr, indices, n,
                                   num_shards=SHARDS, source=0,
                                   max_iters=100)
    mgr2.mutate("sp", EdgeDelete(3, 4))
    report2 = mgr2.refresh("sp")["sp"]
    assert report2.mode == "repair"
    assert "invalidate" in view2.last_plan.seeds
    assert int(view2.last_plan.seeds["invalidate"].ann[0]) == ANN_DELETE
    assert_finite_equal(mgr2.query("sp"), warm)

    # Re-insert the bridge: monotone relax seed, distances fully restored.
    mgr2.mutate("sp", EdgeInsert(3, 4))
    report3 = mgr2.refresh("sp")["sp"]
    assert report3.mode == "repair"
    assert "relax" in view2.last_plan.seeds
    assert int(view2.last_plan.seeds["relax"].ann[0]) == ANN_REPLACE
    assert np.array_equal(mgr2.query("sp"),
                          np.arange(n, dtype=np.float32))


# ---------------------------------------------------------------------------
# Connected components (unique fixpoint: exact equality; merge + split)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cc_view():
    indptr, indices = make_powerlaw_graph(N, avg_degree=1.5, seed=3)
    mgr = ViewManager(fallback_threshold=1.0)
    view = mgr.create_graph_view("cc", "connected_components", indptr,
                                 indices, N, num_shards=SHARDS,
                                 max_iters=100)
    return mgr, view


def cc_oracle(store):
    src, dst = store.edges()
    indptr, indices = edges_to_csr(src, dst, store.n)
    from repro.algorithms.connected_components import reference_components
    return np.asarray(reference_components(indptr, indices, store.n))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 9999))
def test_cc_warm_repair_matches_cold(cc_view, seed):
    mgr, view = cc_view
    rng = np.random.default_rng(seed)
    mgr.mutate("cc", *random_edge_batch(view.store, rng, 2, 2))
    mgr.refresh("cc")
    assert np.array_equal(mgr.query("cc"), cc_oracle(view.store))


def test_cc_split_and_merge_deterministic():
    # Two chains; cutting 1->2 splits the first component mid-way.
    src = np.array([0, 1, 2, 4, 5])
    dst = np.array([1, 2, 3, 5, 6])
    n = 8
    indptr, indices = edges_to_csr(src, dst, n)
    mgr = ViewManager(fallback_threshold=1.0)
    view = mgr.create_graph_view("cc", "connected_components", indptr,
                                 indices, n, num_shards=2, max_iters=50)
    assert np.array_equal(mgr.query("cc"),
                          np.array([0, 0, 0, 0, 4, 4, 4, 7], np.float32))

    mgr.mutate("cc", EdgeDelete(1, 2))
    report = mgr.refresh("cc")["cc"]
    assert report.mode == "repair"
    assert "invalidate" in view.last_plan.seeds     # split handling ran
    assert np.array_equal(mgr.query("cc"),
                          np.array([0, 0, 2, 2, 4, 4, 4, 7], np.float32))

    mgr.mutate("cc", EdgeInsert(1, 4))              # merge 0's into 4-chain
    report = mgr.refresh("cc")["cc"]
    assert report.mode == "repair"
    assert "merge" in view.last_plan.seeds
    assert np.array_equal(mgr.query("cc"),
                          np.array([0, 0, 2, 2, 0, 0, 0, 7], np.float32))


# ---------------------------------------------------------------------------
# k-means: aggregate invariant under point churn
# ---------------------------------------------------------------------------

def test_kmeans_centroid_nudge_consistency():
    rng = np.random.default_rng(0)
    pts = np.concatenate([
        rng.normal((0, 0), 0.2, (30, 2)),
        rng.normal((4, 4), 0.2, (30, 2)),
        rng.normal((0, 4), 0.2, (30, 2))]).astype(np.float32)
    mgr = ViewManager(fallback_threshold=1.0)
    view = mgr.create_kmeans_view("km", pts, k=3, num_shards=SHARDS, seed=1)

    for t in range(3):
        slots = np.flatnonzero(view.store.to_arrays()["valid"])
        mgr.mutate("km",
                   PointInsert(float(rng.normal(4)), float(rng.normal(4))),
                   PointInsert(float(rng.normal()), float(rng.normal())),
                   PointRemove(int(rng.choice(slots))))
        report = mgr.refresh("km")["km"]
        assert report.mode == "repair"
        assert int(view.last_plan.seeds["centroid_nudge"].ann[0]) == \
            ANN_ADJUST

        # KMAgg invariant: (sums, counts) == recomputation from assignment.
        arrays = view.store.to_arrays()
        assign = np.asarray(view.state.assign).reshape(-1)
        for c in range(3):
            sel = arrays["valid"] & (assign == c)
            np.testing.assert_allclose(
                np.asarray(view.state.sums)[c],
                arrays["points"][sel].sum(axis=0), atol=1e-3)
            assert int(np.asarray(view.state.counts)[c]) == int(sel.sum())

        # Converged: every valid point sits with a (near-)nearest centroid
        # (tolerance absorbs the MXU-form vs np distance float gap).
        cents = mgr.query("km")
        p = arrays["points"][arrays["valid"]]
        d2 = ((p[:, None, :] - cents[None]) ** 2).sum(-1)
        chosen = d2[np.arange(len(p)), assign[arrays["valid"]]]
        assert (chosen <= d2.min(axis=1) + 1e-3).all()


# ---------------------------------------------------------------------------
# Session layer: versioning, caching, fallback forcing, capacity growth
# ---------------------------------------------------------------------------

def test_mutation_log_versioning_and_query_cache():
    log = MutationLog()
    assert log.append(EdgeInsert(0, 1), EdgeInsert(1, 2)) == 0
    assert log.append(EdgeDelete(0, 1)) == 2
    batch = log.seal(version=1)
    assert (batch.version, batch.first_seq, len(batch)) == (1, 0, 3)
    assert log.pending_count == 0

    indptr, indices = make_powerlaw_graph(64, avg_degree=3, seed=0)
    mgr = ViewManager(fallback_threshold=1.0)
    view = mgr.create_graph_view("pr", "pagerank", indptr, indices, 64,
                                 num_shards=2, max_iters=80)
    q0 = mgr.query("pr")
    assert mgr.query("pr") is q0                 # cached by version
    assert mgr.refresh("pr")["pr"].mode == "noop"
    assert view.version == 0
    assert mgr.query("pr") is q0                 # noop keeps the cache

    mgr.mutate("pr", EdgeInsert(1, 2))
    assert mgr.refresh("pr")["pr"].version == 1
    assert mgr.query("pr") is not q0             # version bump invalidates


def test_force_modes_and_reweight():
    indptr, indices = make_powerlaw_graph(64, avg_degree=3, seed=2)
    mgr = ViewManager(fallback_threshold=0.0)    # policy always says cold
    view = mgr.create_graph_view("pr", "pagerank", indptr, indices, 64,
                                 num_shards=2, max_iters=80)
    mgr.mutate("pr", EdgeReweight(3, 7, 4))
    assert mgr.refresh("pr")["pr"].mode == "cold"
    assert view.store.multiplicity(3, 7) == 4

    mgr.mutate("pr", EdgeReweight(3, 7, 1))      # force overrides policy
    assert mgr.refresh("pr", force="repair")["pr"].mode == "repair"
    assert view.store.multiplicity(3, 7) == 1

    state, _ = view.rule.cold(view)
    np.testing.assert_allclose(mgr.query("pr"),
                               view.rule.extract(view, state), atol=0.05)


def test_graph_store_multiset_semantics():
    indptr, indices = edges_to_csr(np.array([0, 0]), np.array([1, 1]), 4)
    store = GraphStore(indptr, indices, 4, num_shards=2)
    assert store.multiplicity(0, 1) == 2
    store.apply_batch([EdgeDelete(0, 1)])
    assert store.multiplicity(0, 1) == 1
    with pytest.raises(KeyError):
        store.apply_batch([EdgeDelete(0, 2)])
    with pytest.raises(IndexError):
        store.apply_batch([EdgeInsert(0, 99)])
    effect = store.apply_batch([EdgeInsert(2, 3), EdgeInsert(2, 0)])
    assert np.array_equal(effect.changed_src, [2])
    assert effect.old_deg[0] == 0 and effect.new_deg[0] == 2


def test_intra_batch_netting():
    # Delete may consume an insert earlier in the SAME batch...
    indptr, indices = edges_to_csr(np.array([0]), np.array([1]), 4)
    store = GraphStore(indptr, indices, 4, num_shards=2)
    effect = store.apply_batch([EdgeInsert(2, 3), EdgeDelete(2, 3),
                                EdgeInsert(1, 2)])
    assert store.multiplicity(2, 3) == 0
    assert len(effect.inserted[0]) == 1          # only the net insert
    assert len(effect.deleted[0]) == 0
    # ...but never a later one.
    with pytest.raises(KeyError):
        store.apply_batch([EdgeDelete(3, 0), EdgeInsert(3, 0)])

    # Point insert+remove of the same slot in one batch nets to nothing.
    from repro.incremental import PointStore
    pstore = PointStore(np.zeros((4, 2), np.float32), num_shards=2,
                        capacity=8)
    free = int(np.flatnonzero(~pstore.to_arrays()["valid"])[0])
    peffect = pstore.apply_batch([PointInsert(1.0, 2.0),
                                  PointRemove(free),
                                  PointRemove(0)])
    assert len(peffect.inserted_slots) == 0
    assert np.array_equal(peffect.removed_slots, [0])
    assert pstore.n_points == 3


def test_failed_refresh_is_atomic_and_preserves_batch():
    indptr, indices = edges_to_csr(np.array([0]), np.array([1]), 8)
    mgr = ViewManager(fallback_threshold=1.0)
    view = mgr.create_graph_view("sp", "sssp", indptr, indices, 8,
                                 num_shards=2, source=0, max_iters=40)
    mgr.mutate("sp", EdgeInsert(1, 2), EdgeDelete(5, 6))  # second is bad
    with pytest.raises(KeyError):
        mgr.refresh("sp")
    assert view.version == 0                 # nothing took effect
    assert view.store.n_edges == 1           # store untouched
    assert view.log.pending_count == 2       # batch preserved, not lost
    # Drop the bad mutation and retry: the good one still applies.
    view.log._pending = [m for m in view.log._pending
                         if not isinstance(m, EdgeDelete)]
    assert mgr.refresh("sp")["sp"].version == 1
    assert np.array_equal(mgr.query("sp")[:3], [0, 1, 2])


def test_capacity_growth_retraces_and_stays_correct():
    n = 32
    indptr, indices = make_powerlaw_graph(n, avg_degree=2, seed=4)
    mgr = ViewManager(fallback_threshold=1.0)
    view = mgr.create_graph_view("sp", "sssp", indptr, indices, n,
                                 num_shards=2, source=0, max_iters=60)
    cap0 = view.store.nnz_capacity
    rng = np.random.default_rng(0)
    muts = [EdgeInsert(0, int(rng.integers(n))) for _ in range(4 * cap0)]
    mgr.mutate("sp", *muts)
    mgr.refresh("sp")
    assert view.store.nnz_capacity > cap0        # pin doubled, view rebound
    src, dst = view.store.edges()
    ip, ix = edges_to_csr(src, dst, n)
    assert_finite_equal(mgr.query("sp"),
                        np.asarray(reference_sssp(ip, ix, n, source=0)))


@pytest.mark.slow
def test_resume_shard_map_bit_identical_to_simulated():
    """Warm resumes are no longer pinned to the simulated backend:
    ``backend``/``mesh``/``axis_name`` view params flow through to both
    of the rule's ShardedExecutors, and a shard_map view's cold + warm
    repair trajectory must be bit-identical to the simulated one."""
    import os
    import subprocess
    import sys
    code = """
import numpy as np, jax
from repro.data.graphs import make_powerlaw_graph
from repro.incremental import EdgeInsert, EdgeDelete, ViewManager

n, S = 256, 4
indptr, indices = make_powerlaw_graph(n, avg_degree=4, seed=3)
mesh = jax.make_mesh((S,), ('shards',))
views = {}
for tag, params in (('sim', {}),
                    ('smap', dict(backend='shard_map', mesh=mesh,
                                  axis_name='shards'))):
    mgr = ViewManager(fallback_threshold=1.0)
    views[tag] = mgr.create_graph_view(
        'pr_' + tag, 'pagerank', indptr.copy(), indices.copy(), n,
        num_shards=S, threshold=1e-4, max_iters=120, **params)
rng = np.random.default_rng(0)
muts = [EdgeInsert(int(rng.integers(n)), int(rng.integers(n)))
        for _ in range(6)]
for tag, v in views.items():
    v.apply(*muts)
    rep = v.refresh(force='repair')
    assert rep.mode == 'repair', (tag, rep.mode)
a, b = views['sim'].query(), views['smap'].query()
assert np.array_equal(a, b), np.abs(a - b).max()
ra, rb = views['sim'].last_result, views['smap'].last_result
assert int(ra.stats.iterations) == int(rb.stats.iterations)
assert np.array_equal(np.asarray(ra.stats.delta_counts),
                      np.asarray(rb.stats.delta_counts))
print('RESUME_SHARD_MAP_OK')
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "RESUME_SHARD_MAP_OK" in out.stdout


def test_engine_resume_on_converged_state_is_noop():
    indptr, indices = make_powerlaw_graph(64, avg_degree=3, seed=9)
    mgr = ViewManager()
    view = mgr.create_graph_view("pr", "pagerank", indptr, indices, 64,
                                 num_shards=2, max_iters=80)
    _, res = view.rule.resume(view, view.state)
    assert int(res.stats.iterations) == 0        # Δ₀ empty: zero strata


def test_stats_merge_helpers():
    s0 = empty_stats(4)
    assert int(s0.iterations) == 0
    indptr, indices = make_powerlaw_graph(32, avg_degree=2, seed=1)
    mgr = ViewManager()
    view = mgr.create_graph_view("cc", "connected_components", indptr,
                                 indices, 32, num_shards=2, max_iters=40)
    stats = view.last_result.stats
    merged = merge_stats(stats, stats)
    assert int(merged.iterations) == 2 * int(stats.iterations)
    n = int(stats.iterations)
    assert np.array_equal(np.asarray(merged.delta_counts)[:n],
                          np.asarray(stats.delta_counts)[:n])


# ---------------------------------------------------------------------------
# Durable journal: restore == live, bit for bit
# ---------------------------------------------------------------------------

def test_journal_recovery_resumes_views(tmp_path):
    rng = np.random.default_rng(0)
    pts = np.concatenate([rng.normal((0, 0), .3, (30, 2)),
                          rng.normal((3, 3), .3, (30, 2))]).astype(np.float32)
    indptr, indices = make_powerlaw_graph(N, avg_degree=3, seed=6)

    root = str(tmp_path / "journal")
    mgr = ViewManager(journal_root=root, fallback_threshold=1.0)
    km = mgr.create_kmeans_view("km", pts, k=2, num_shards=2, seed=3)
    mgr.create_graph_view("sp", "sssp", indptr, indices, N,
                          num_shards=SHARDS, source=0, max_iters=100)

    for _ in range(3):
        slots = np.flatnonzero(km.store.to_arrays()["valid"])
        mgr.mutate("km", PointInsert(float(rng.normal(3)),
                                     float(rng.normal(3))),
                   PointRemove(int(rng.choice(slots))))
        mgr.mutate("sp", *random_edge_batch(mgr["sp"].store, rng, 2, 2))
        mgr.refresh()

    restored = ViewManager.restore(root)
    for name in ("km", "sp"):
        assert restored[name].version == mgr[name].version == 3
        assert np.array_equal(restored.query(name), mgr.query(name),
                              equal_nan=True)

    # checkpoint() truncates the replay: restore again from the new base.
    mgr.checkpoint()
    restored2 = ViewManager.restore(root)
    for name in ("km", "sp"):
        assert restored2[name].version == 3
        assert np.array_equal(restored2.query(name), mgr.query(name),
                              equal_nan=True)

    # A FORCED cold refresh must replay as cold too (k-means re-seeds its
    # centroids on a cold start, so replaying under the default policy
    # would settle elsewhere).
    slots = np.flatnonzero(km.store.to_arrays()["valid"])
    mgr.mutate("km", PointRemove(int(slots[0])))
    assert mgr.refresh("km", force="cold")["km"].mode == "cold"
    restored3 = ViewManager.restore(root)
    assert np.array_equal(restored3.query("km"), mgr.query("km"))

    # drop() purges the journal: the view must not resurrect on restore.
    mgr.drop("sp")
    assert "sp" not in ViewManager.restore(root).views
    assert "km" in ViewManager.restore(root).views
