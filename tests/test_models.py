"""Per-arch smoke tests (reduced configs) + train/decode consistency.

Every assigned architecture instantiates its reduced() config and runs
one forward + one train step on CPU, asserting output shapes and no NaNs
(deliverable f).  Consistency tests pin decode == teacher-forced forward
and prefill cache == step-by-step cache.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, all_archs, cells, get_arch
from repro.data.tokens import TokenPipeline
from repro.models import (decode_step, encode, forward, init_cache,
                          init_params, param_count)
from repro.models.transformer import prefill_forward
from repro.train import TrainConfig, init_train_state, make_train_step
from repro.train.serve_step import fill_cross_kv

ARCHS = list(all_archs())


def _setup(name, key=0):
    cfg = get_arch(name).reduced()
    p = init_params(cfg, jax.random.PRNGKey(key))
    return cfg, p


def _enc_out(cfg, p, b):
    frames = jax.random.normal(jax.random.PRNGKey(9),
                               (b, cfg.encoder_seq, cfg.d_model),
                               jnp.float32)
    return encode(cfg, p, frames)


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_forward(name):
    cfg, p = _setup(name)
    B, T = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    kw = {"enc_out": _enc_out(cfg, p, B)} if cfg.encoder_layers else {}
    logits, aux = forward(cfg, p, toks, **kw)
    assert logits.shape == (B, T, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert param_count(p) > 0


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_train_step(name):
    cfg, _ = _setup(name)
    tcfg = TrainConfig()
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=16, global_batch=2)
    batch = pipe.batch_at(0)
    if cfg.frontend == "audio_stub":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (2, cfg.encoder_seq, cfg.d_model),
            jnp.float32)
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(state.params),
                                jax.tree.leaves(state2.params)))
    assert delta > 0


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_forward(name):
    cfg, p = _setup(name)
    if name == "arctic-480b":     # avoid MoE capacity drops in the check
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
        p = init_params(cfg, jax.random.PRNGKey(0))
    B, T = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    kw = {"enc_out": _enc_out(cfg, p, B)} if cfg.encoder_layers else {}
    ref, _ = forward(cfg, p, toks, **kw)
    cache = init_cache(cfg, B, T)
    if cfg.encoder_layers:
        cache = fill_cross_kv(cfg, p, cache, kw["enc_out"])
    errs = []
    for t in range(T):
        lg, cache = decode_step(cfg, p, toks[:, t:t + 1], cache,
                                jnp.asarray(t, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - ref[:, t]))))
    assert max(errs) < 1e-3, f"{name}: decode diverges {max(errs)}"


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_cache_equals_stepwise(name):
    cfg, p = _setup(name)
    if name == "arctic-480b":
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
        p = init_params(cfg, jax.random.PRNGKey(0))
    B, T, ML = 2, 12, 20
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    kw = {"enc_out": _enc_out(cfg, p, B)} if cfg.encoder_layers else {}
    logits_pf, cache_pf = prefill_forward(cfg, p, toks, ML, **kw)
    cache = init_cache(cfg, B, ML)
    if cfg.encoder_layers:
        cache = fill_cross_kv(cfg, p, cache, kw["enc_out"])
    for t in range(T):
        lg, cache = decode_step(cfg, p, toks[:, t:t + 1], cache,
                                jnp.asarray(t, jnp.int32))
    assert float(jnp.max(jnp.abs(logits_pf[:, 0] - lg[:, 0]))) < 1e-3
    nxt = jnp.argmax(lg[:, 0], -1).astype(jnp.int32)[:, None]
    l1, _ = decode_step(cfg, p, nxt, cache, jnp.asarray(T, jnp.int32))
    l2, _ = decode_step(cfg, p, nxt, cache_pf, jnp.asarray(T, jnp.int32))
    assert float(jnp.max(jnp.abs(l1 - l2))) < 1e-3


def test_moe_dispatch_strategies_agree():
    cfg = dataclasses.replace(get_arch("mixtral-8x22b").reduced(),
                              capacity_factor=16.0)
    p = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab)
    l1, _ = forward(cfg, p, toks, moe_strategy="sort")
    l2, _ = forward(cfg, p, toks, moe_strategy="onehot")
    assert float(jnp.max(jnp.abs(l1 - l2))) < 1e-4


def test_unroll_equals_scan():
    cfg, p = _setup("llama3-8b")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    l1, _ = forward(cfg, p, toks, unroll=False)
    l2, _ = forward(cfg, p, toks, unroll=True)
    assert float(jnp.max(jnp.abs(l1 - l2))) < 1e-5


def test_exact_layer_counts():
    """Configs carry the EXACT assigned layer counts (unit·U + tail)."""
    expect = {"qwen2-vl-2b": 28, "arctic-480b": 35, "mixtral-8x22b": 56,
              "xlstm-350m": 24, "llama3-8b": 32, "minicpm3-4b": 62,
              "starcoder2-3b": 30, "olmo-1b": 16, "whisper-large-v3": 32,
              "recurrentgemma-2b": 26}
    for name, n in expect.items():
        cfg = get_arch(name)
        assert cfg.n_layers == n
        assert len(cfg.unit) * cfg.n_units + len(cfg.tail) == n


def test_cells_inventory():
    """40 assigned cells; skips match DESIGN.md §Arch-applicability."""
    cs = cells()
    assert len(cs) == 40
    skipped = {(a, s) for a, s, skip in cs if skip}
    long_runners = {"xlstm-350m", "recurrentgemma-2b", "mixtral-8x22b"}
    for arch in all_archs():
        if arch in long_runners:
            assert (arch, "long_500k") not in skipped
        else:
            assert (arch, "long_500k") in skipped


def test_param_counts_match_billing():
    """Full-config param counts are in the advertised ballpark."""
    from repro.launch.roofline import model_params
    # Bands allow for the framework's uniform-SwiGLU MLP accounting
    # (3·d·ff): archs that really use 2-matrix MLPs (starcoder2, whisper)
    # bill ~d·ff·L higher than their nameplate.
    expect_b = {"llama3-8b": (7.0, 9.0), "arctic-480b": (420, 520),
                "mixtral-8x22b": (120, 150), "olmo-1b": (0.9, 1.4),
                "minicpm3-4b": (3.0, 5.0), "starcoder2-3b": (2.5, 4.6),
                "qwen2-vl-2b": (1.2, 2.3), "whisper-large-v3": (1.2, 2.2),
                "xlstm-350m": (0.2, 0.5),
                "recurrentgemma-2b": (2.0, 3.6)}
    for name, (lo, hi) in expect_b.items():
        total, _ = model_params(get_arch(name))
        assert lo <= total / 1e9 <= hi, f"{name}: {total/1e9:.2f}B"
