"""Fault tolerance: checkpoint/restore, recovery strategies, elastic
re-scaling, straggler mitigation, gradient compression, optimizer rules."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.algorithms import sssp
from repro.core.engine import ShardedExecutor
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import make_powerlaw_graph, shard_csr
from repro.runtime import (CheckpointManager, SpeculationPolicy,
                           StragglerMitigator, StratumRunner, grow,
                           remap_state, run_with_failure)
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   compress_tree, ef_int8, ef_topk_delta,
                                   zero_residuals)

N, S = 512, 4


@pytest.fixture()
def sssp_setup():
    indptr, indices = make_powerlaw_graph(N, avg_degree=8.0, seed=0)
    snap = PartitionSnapshot(n_keys=N, num_shards=S)
    g = shard_csr(indptr, indices, S)
    algo = sssp.make_algorithm(snap, src_capacity=512, edge_capacity=8192)
    ex = ShardedExecutor(snapshot=snap, seg_capacity=8192,
                         edge_capacity=8192, src_capacity=512)
    sfn = ex.make_stratum_fn(algo, g, "delta")
    ref = sssp.reference_sssp(indptr, indices, N, 0)

    def make_runner():
        return StratumRunner(stratum_fn=sfn,
                             state=sssp.initial_state(snap, 0), live=1)

    def mutable_of(state):
        st = sssp.SPState(*state)
        return np.stack([np.asarray(st.dist), np.asarray(st.sent)], -1)

    def restore(state, shard, node):
        st = sssp.SPState(*state)
        return sssp.SPState(
            dist=st.dist.at[node].set(jnp.asarray(shard[:, 0])),
            sent=st.sent.at[node].set(jnp.asarray(shard[:, 1])))

    return make_runner, mutable_of, restore, ref


def _check(ref, state):
    dist = sssp.SPState(*state).dist.reshape(-1)[:N]
    finite = jnp.isfinite(ref)
    return bool(jnp.all(jnp.where(finite, dist == ref,
                                  ~jnp.isfinite(dist))))


class TestCheckpoint:
    def test_full_roundtrip(self, tmp_path):
        ck = CheckpointManager(str(tmp_path), num_nodes=4, replication=3)
        tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3))}}
        ck.save_full(1, 7, tree)
        got, step = ck.load_full(1, tree)
        assert step == 7
        assert jnp.all(got["a"] == tree["a"])

    def test_restore_from_replica_after_disk_loss(self, tmp_path):
        ck = CheckpointManager(str(tmp_path), num_nodes=4, replication=3)
        tree = {"a": jnp.arange(5.0)}
        ck.save_full(1, 3, tree)
        ck.wipe_node(1)
        with pytest.raises(FileNotFoundError):
            ck.load_full(1, tree)
        got, step = ck.load_full(1, tree, from_replica=True)
        assert step == 3 and jnp.all(got["a"] == tree["a"])

    def test_delta_replay_order(self, tmp_path):
        ck = CheckpointManager(str(tmp_path), num_nodes=2, replication=2)
        ck.save_delta(0, 1, np.array([0, 1]), np.array([[1.], [2.]]))
        ck.save_delta(0, 2, np.array([1]), np.array([[5.]]))
        steps = [s for s, _, _ in ck.replay_deltas(0, since_step=-1)]
        assert steps == [1, 2]

    def test_gc_keeps_latest(self, tmp_path):
        ck = CheckpointManager(str(tmp_path), num_nodes=1, replication=1,
                               keep=2)
        tree = {"a": jnp.zeros(3)}
        for s in (1, 2, 3, 4):
            ck.save_full(0, s, tree)
        _, step = ck.load_full(0, tree)
        assert step == 4


class TestRecovery:
    @pytest.mark.parametrize("strategy", ["incremental", "restart"])
    @pytest.mark.parametrize("fail_at", [1, 4])
    def test_correct_after_failure(self, tmp_path, sssp_setup, strategy,
                                   fail_at):
        make_runner, mutable_of, restore, ref = sssp_setup
        ck = CheckpointManager(str(tmp_path / strategy), num_nodes=S,
                               replication=3)
        res = run_with_failure(make_runner, ck, mutable_of, restore,
                               fail_at=fail_at, failed_node=1,
                               strategy=strategy)
        assert res["converged"]
        assert _check(ref, res["final_state"])

    def test_incremental_beats_restart_on_late_failure(self, tmp_path,
                                                       sssp_setup):
        """Fig 12: the later the failure, the bigger incremental's win."""
        make_runner, mutable_of, restore, ref = sssp_setup
        work = {}
        for strategy in ("incremental", "restart"):
            ck = CheckpointManager(str(tmp_path / strategy), num_nodes=S,
                                   replication=3)
            res = run_with_failure(make_runner, ck, mutable_of, restore,
                                   fail_at=5, failed_node=2,
                                   strategy=strategy)
            work[strategy] = res["total_work_units"]
        assert work["incremental"] <= work["restart"]

    def test_repeated_failures_make_progress(self, tmp_path, sssp_setup):
        """Forward progress under repeated failures (paper §4.3)."""
        make_runner, mutable_of, restore, ref = sssp_setup
        ck = CheckpointManager(str(tmp_path), num_nodes=S, replication=3)
        res = run_with_failure(make_runner, ck, mutable_of, restore,
                               fail_at=2, failed_node=1,
                               strategy="incremental")
        # inject a second failure by re-running from the survivor state
        assert res["converged"] and _check(ref, res["final_state"])


class TestElastic:
    def test_remap_preserves_keys(self):
        old = PartitionSnapshot(n_keys=100, num_shards=4)
        new = PartitionSnapshot(n_keys=100, num_shards=8)
        from repro.core.partition import shard_dense_state
        x = jnp.arange(100.0)
        st = shard_dense_state(old, x)
        st2 = remap_state(old, new, st)
        from repro.core.partition import unshard_dense_state
        assert jnp.all(unshard_dense_state(new, st2) == x)

    def test_grow_and_shrink(self):
        snap = PartitionSnapshot(n_keys=64, num_shards=4)
        from repro.core.partition import shard_dense_state
        x = shard_dense_state(snap, jnp.arange(64.0))
        snap8, (x8,) = grow(snap, 8, x)
        assert snap8.num_shards == 8 and x8.shape[0] == 8
        snap2, (x2,) = grow(snap8, 2, x8)
        from repro.core.partition import unshard_dense_state
        assert jnp.all(unshard_dense_state(snap2, x2)
                       == jnp.arange(64.0))


class TestStraggler:
    def test_speculation_cuts_barrier(self):
        mit = StragglerMitigator(4, SpeculationPolicy(threshold=2.0,
                                                      min_history=0))
        out = None
        for _ in range(3):
            out = mit.observe_stratum([1.0, 1.0, 1.0, 10.0])
        assert out["barrier_with"] < out["barrier_without"]
        assert mit.saved_time > 0

    def test_no_speculation_when_uniform(self):
        mit = StragglerMitigator(4)
        for _ in range(5):
            out = mit.observe_stratum([1.0, 1.1, 0.9, 1.0])
        assert out["speculations"] == []


class TestCompression:
    def test_int8_error_feedback_converges(self):
        g = jnp.asarray(np.random.default_rng(0).normal(size=257)
                        .astype(np.float32))
        res = jnp.zeros_like(g)
        acc = jnp.zeros_like(g)
        for _ in range(50):
            ghat, res, _ = ef_int8(g, res)
            acc = acc + ghat
        # error feedback: accumulated transmitted ≈ accumulated true
        np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g),
                                   atol=1e-2)

    def test_topk_delta_error_feedback(self):
        g = jnp.asarray(np.random.default_rng(1).normal(size=128)
                        .astype(np.float32))
        res = jnp.zeros_like(g)
        acc = jnp.zeros_like(g)
        for _ in range(100):
            ghat, res, bytes_ = ef_topk_delta(g, res, k=16)
            acc = acc + ghat
        assert float(bytes_) == 8.0 * 16
        np.testing.assert_allclose(np.asarray(acc / 100), np.asarray(g),
                                   atol=0.15)

    def test_compress_tree_bytes(self):
        params = {"w": jnp.ones((64, 64))}
        res = zero_residuals(params)
        _, _, b_none = compress_tree(params, res, "none")
        _, _, b_int8 = compress_tree(params, res, "int8")
        _, _, b_delta = compress_tree(params, res, "delta",
                                      topk_frac=0.01)
        assert float(b_int8) < float(b_none)
        assert float(b_delta) < float(b_int8)


class TestOptimizer:
    def test_adamw_descends_quadratic(self):
        cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                          weight_decay=0.0)
        params = {"x": jnp.asarray([5.0, -3.0])}
        state = adamw_init(params)
        for _ in range(200):
            grads = {"x": 2 * params["x"]}
            params, state, _ = adamw_update(cfg, state, params, grads)
        assert float(jnp.max(jnp.abs(params["x"]))) < 0.5

    def test_clip_norm(self):
        cfg = AdamWConfig(clip_norm=1.0, warmup_steps=1)
        params = {"x": jnp.zeros(4)}
        state = adamw_init(params)
        _, _, metrics = adamw_update(cfg, state, params,
                                     {"x": jnp.full(4, 100.0)})
        assert float(metrics["grad_norm"]) == pytest.approx(200.0)
