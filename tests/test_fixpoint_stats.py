"""StratumStats assembly/merging edge cases (core/fixpoint.py).

These paths are exercised implicitly by the recovery driver and the
incremental views; here they are pinned directly: zero-iteration runs,
restart truncation past max_iters, and merging runs whose max_iters
differ.  The consumer invariant under test everywhere:
``stats.field[:iterations]`` is always in bounds and meaningful.
"""
import numpy as np

import jax.numpy as jnp

from repro.core.fixpoint import (StratumOutcome, StratumStats, empty_stats,
                                 merge_stats, stats_from_outcomes)


def outcome(emitted, dense=False, rehash=0.0, tier=0, route=0,
            live=0) -> StratumOutcome:
    return StratumOutcome(
        live_count=jnp.asarray(live, jnp.int32),
        used_dense=jnp.asarray(dense),
        rehash_bytes=jnp.asarray(rehash, jnp.float32),
        emitted=jnp.asarray(emitted, jnp.int32),
        tier=jnp.asarray(tier, jnp.int32),
        route=jnp.asarray(route, jnp.int32))


def fields(stats: StratumStats) -> dict:
    return {f: np.asarray(getattr(stats, f))
            for f in ("delta_counts", "used_dense", "rehash_bytes",
                      "tiers", "routes")}


class TestStatsFromOutcomes:
    def test_zero_iterations(self):
        stats = stats_from_outcomes([], max_iters=5)
        assert int(stats.iterations) == 0
        f = fields(stats)
        assert all(v.shape == (5,) for v in f.values())
        np.testing.assert_array_equal(f["delta_counts"], 0)
        np.testing.assert_array_equal(f["tiers"], -1)
        np.testing.assert_array_equal(f["routes"], -1)
        # matches the canonical empty-stats shape exactly
        e = fields(empty_stats(5))
        for k in f:
            np.testing.assert_array_equal(f[k], e[k], err_msg=k)

    def test_fill_and_padding(self):
        outs = [outcome(7, tier=2, route=1, rehash=3.5),
                outcome(3, dense=True, tier=-1, route=-1)]
        stats = stats_from_outcomes(outs, max_iters=4)
        assert int(stats.iterations) == 2
        f = fields(stats)
        np.testing.assert_array_equal(f["delta_counts"], [7, 3, 0, 0])
        np.testing.assert_array_equal(f["used_dense"],
                                      [False, True, False, False])
        np.testing.assert_array_equal(f["tiers"], [2, -1, -1, -1])
        np.testing.assert_array_equal(f["routes"], [1, -1, -1, -1])
        np.testing.assert_allclose(f["rehash_bytes"], [3.5, 0, 0, 0])

    def test_restart_truncation_keeps_last_max_iters(self):
        # A restart mid-fixpoint re-executes early strata: the outcome
        # list grows past max_iters and the stats must keep the LAST
        # max_iters (the surviving pass), clipping iterations.
        outs = [outcome(10 + k) for k in range(7)]
        stats = stats_from_outcomes(outs, max_iters=4)
        assert int(stats.iterations) == 4
        np.testing.assert_array_equal(
            np.asarray(stats.delta_counts), [13, 14, 15, 16])

    def test_truncation_mid_stratum_exact_boundary(self):
        outs = [outcome(k) for k in range(4)]
        stats = stats_from_outcomes(outs, max_iters=4)
        assert int(stats.iterations) == 4
        np.testing.assert_array_equal(
            np.asarray(stats.delta_counts), [0, 1, 2, 3])


class TestMergeStats:
    def test_merge_differing_max_iters(self):
        # cold run recorded at max_iters=5, warm resume at max_iters=3:
        # merge concatenates only the EXECUTED prefixes.
        a = stats_from_outcomes([outcome(5, tier=1), outcome(6, tier=0)],
                                max_iters=5)
        b = stats_from_outcomes([outcome(2, tier=0, route=1)], max_iters=3)
        m = merge_stats(a, b)
        assert int(m.iterations) == 3
        np.testing.assert_array_equal(np.asarray(m.delta_counts),
                                      [5, 6, 2])
        np.testing.assert_array_equal(np.asarray(m.tiers), [1, 0, 0])
        np.testing.assert_array_equal(np.asarray(m.routes), [0, 0, 1])
        # arrays are sized to executed strata, not either max_iters
        assert m.delta_counts.shape == (3,)

    def test_merge_with_empty_either_side(self):
        a = stats_from_outcomes([outcome(4)], max_iters=2)
        e = empty_stats(6)
        left = merge_stats(e, a)
        right = merge_stats(a, e)
        for m in (left, right):
            assert int(m.iterations) == 1
            np.testing.assert_array_equal(np.asarray(m.delta_counts), [4])

    def test_merge_both_empty(self):
        m = merge_stats(empty_stats(3), empty_stats(8))
        assert int(m.iterations) == 0
        assert m.delta_counts.shape == (0,)

    def test_merge_associative_on_counts(self):
        a = stats_from_outcomes([outcome(1)], max_iters=2)
        b = stats_from_outcomes([outcome(2)], max_iters=2)
        c = stats_from_outcomes([outcome(3)], max_iters=2)
        ab_c = merge_stats(merge_stats(a, b), c)
        a_bc = merge_stats(a, merge_stats(b, c))
        np.testing.assert_array_equal(np.asarray(ab_c.delta_counts),
                                      np.asarray(a_bc.delta_counts))
        assert int(ab_c.iterations) == int(a_bc.iterations) == 3
