"""Heartbeat/lease failure detection (``runtime/health.py``) and the
process-aware flat-mesh ownership helpers (``launch/mesh.py``).

All monitor tests drive a FAKE clock through both the writer and the
monitor — no sleeps, no subprocesses; the real multi-process integration
lives in ``test_distributed.py``.
"""
import jax
import numpy as np
import pytest

from repro.launch.channel import read_json, write_json
from repro.launch.mesh import (flat_mesh, local_shards, mesh_devices,
                               shard_process_indices)
from repro.runtime.health import (HealthConfig, HealthMonitor,
                                  heartbeat_path, lease_path,
                                  write_heartbeat)

CFG = HealthConfig(lease_ttl=1.5, straggle_after=0.4,
                   heartbeat_interval=0.1)


class FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


def _monitor(root, ownership, clock, **kw):
    return HealthMonitor(root, ownership, CFG, clock=clock, **kw)


def _beat(root, wid, seq, clock):
    write_heartbeat(root, wid, seq, clock=clock)


class TestHealthConfig:
    def test_ordering_validated(self):
        with pytest.raises(ValueError, match="heartbeat_interval"):
            HealthConfig(lease_ttl=0.1, straggle_after=0.4,
                         heartbeat_interval=0.2)
        with pytest.raises(ValueError):
            HealthConfig(heartbeat_interval=0.0)

    def test_defaults_give_many_beats_before_death(self):
        c = HealthConfig()
        assert c.lease_ttl / c.heartbeat_interval >= 10


class TestChannel:
    def test_atomic_roundtrip(self, tmp_path):
        p = str(tmp_path / "sub" / "x.json")
        write_json(p, {"a": 1})
        assert read_json(p) == {"a": 1}
        assert read_json(str(tmp_path / "missing.json")) is None

    def test_heartbeat_carries_lease_echo(self, tmp_path):
        clk = FakeClock()
        write_heartbeat(str(tmp_path), 3, 7, shards=(1, 5), clock=clk)
        hb = read_json(heartbeat_path(str(tmp_path), 3))
        assert hb["worker_id"] == 3 and hb["seq"] == 7
        assert hb["shards"] == [1, 5] and hb["t"] == clk.t


class TestHealthMonitor:
    def test_leases_granted_at_construction(self, tmp_path):
        root = str(tmp_path)
        clk = FakeClock()
        _monitor(root, {0: [0, 2], 1: [1, 3]}, clk)
        lease = read_json(lease_path(root, 1))
        assert lease["shards"] == [1, 3]
        assert lease["ttl_s"] == CFG.lease_ttl

    def test_ok_late_dead_transitions(self, tmp_path):
        root = str(tmp_path)
        clk = FakeClock()
        mon = _monitor(root, {0: [0], 1: [1]}, clk)
        for w in (0, 1):
            _beat(root, w, 0, clk)
        rep = mon.observe(0)
        assert [s.state for s in rep.statuses] == ["ok", "ok"]
        assert rep.alive == 2 and not rep.dead_workers

        # Worker 1 goes quiet past the straggle threshold: late, with a
        # straggle signal per leased shard — never a fail event.
        clk.t += CFG.straggle_after + 0.1
        _beat(root, 0, 1, clk)
        rep = mon.observe(3)
        assert [s.state for s in rep.statuses] == ["ok", "late"]
        assert rep.straggles == [(1, pytest.approx(clk.t - 100.0))]
        assert not rep.fail_events

        # Past the lease TTL: dead, one fail event per leased shard,
        # stamped with the observing stratum.
        clk.t = 100.0 + CFG.lease_ttl + 0.01
        _beat(root, 0, 2, clk)
        rep = mon.observe(5)
        assert rep.dead_workers == [1]
        assert [(e.kind, e.at, e.shard) for e in rep.fail_events] \
            == [("fail", 5, 1)]

    def test_never_heartbeat_is_dead_with_infinite_age(self, tmp_path):
        clk = FakeClock()
        mon = _monitor(str(tmp_path), {0: [0]}, clk)
        rep = mon.observe(0)
        assert rep.dead_workers == [0]
        assert rep.statuses[0].age == float("inf")

    def test_dead_reported_once_until_reinstated(self, tmp_path):
        root = str(tmp_path)
        clk = FakeClock()
        mon = _monitor(root, {0: [0, 1]}, clk)
        rep = mon.observe(2)
        assert len(rep.fail_events) == 2
        # Second barrier: still dead, but not re-reported.
        assert mon.observe(3).dead_workers == []
        assert mon.observe(3).fail_events == []
        # Replacement takes the lease: reportable anew.
        mon.reinstate(0)
        _beat(root, 0, 0, clk)
        assert mon.observe(4).statuses[0].state == "ok"
        clk.t += CFG.lease_ttl + 1
        rep = mon.observe(9)
        assert rep.dead_workers == [0] and len(rep.fail_events) == 2

    def test_proc_alive_fast_path_beats_the_ttl(self, tmp_path):
        root = str(tmp_path)
        clk = FakeClock()
        mon = _monitor(root, {0: [0], 1: [1]}, clk,
                       proc_alive=lambda w: w != 0)
        for w in (0, 1):
            _beat(root, w, 0, clk)
        # Heartbeat fresh, but the process is observably gone: dead NOW.
        rep = mon.observe(1)
        assert rep.dead_workers == [0]
        assert rep.statuses[1].state == "ok"

    def test_proc_alive_none_falls_back_to_lease(self, tmp_path):
        root = str(tmp_path)
        clk = FakeClock()
        mon = _monitor(root, {0: [0]}, clk, proc_alive=lambda w: None)
        _beat(root, 0, 0, clk)
        assert mon.observe(0).dead_workers == []
        clk.t += CFG.lease_ttl + 0.1
        assert mon.observe(1).dead_workers == [0]

    def test_observability_mirrors(self, tmp_path):
        from repro.obs.metrics import MetricsRegistry
        from repro.obs.trace import Tracer
        root = str(tmp_path)
        clk = FakeClock()
        tracer, reg = Tracer(), MetricsRegistry()
        mon = _monitor(root, {0: [0], 1: [1]}, clk, tracer=tracer,
                       metrics=reg)
        _beat(root, 0, 0, clk)
        _beat(root, 1, 0, clk)
        clk.t += CFG.straggle_after + 0.05
        _beat(root, 0, 1, clk)
        mon.observe(1)                      # worker 1 late
        clk.t += CFG.lease_ttl
        _beat(root, 0, 2, clk)
        mon.observe(2)                      # worker 1 dead
        names = [e["name"] for e in tracer.events]
        assert "heartbeat_late" in names and "lease_expired" in names
        late = next(e for e in tracer.events
                    if e["name"] == "heartbeat_late")
        assert late["tid"] == "worker1"     # per-worker timeline row
        assert reg.counter("health.straggle_signals").value == 1
        assert reg.counter("health.lease_expiries").value == 1
        assert reg.gauge("health.workers_alive").value == 1

    def test_set_ownership_regrants_leases(self, tmp_path):
        root = str(tmp_path)
        clk = FakeClock()
        mon = _monitor(root, {0: [0], 1: [1]}, clk)
        mon.set_ownership({0: [0, 1], 1: []})
        assert read_json(lease_path(root, 0))["shards"] == [0, 1]
        _beat(root, 0, 0, clk)
        _beat(root, 1, 0, clk)
        rep = mon.observe(0)
        assert rep.statuses[0].shards == (0, 1)
        assert rep.statuses[1].shards == ()

    def test_wait_ready_names_silent_workers(self, tmp_path):
        root = str(tmp_path)
        clk = FakeClock()
        mon = _monitor(root, {0: [0], 1: [1]}, clk)
        _beat(root, 0, 0, clk)

        def tick(_):
            clk.t += 1.0
        with pytest.raises(TimeoutError, match=r"\[1\]"):
            mon.wait_ready(timeout=3.0, sleep=tick)
        _beat(root, 1, 0, clk)
        mon.wait_ready(timeout=1.0, sleep=tick)


class TestFlatMeshOwnership:
    def test_explicit_device_list(self):
        devs = jax.devices()
        mesh = flat_mesh(devices=devs)
        assert mesh_devices(mesh) == list(devs)
        assert mesh.axis_names == ("shards",)

    def test_legacy_signature_still_works(self):
        mesh = flat_mesh(1)
        assert int(np.prod(mesh.devices.shape)) == 1

    def test_num_devices_contradiction_raises(self):
        with pytest.raises(ValueError, match="contradicts"):
            flat_mesh(3, devices=jax.devices())
        # Consistent num_devices + devices is accepted.
        flat_mesh(len(jax.devices()), devices=jax.devices())

    def test_empty_device_list_raises(self):
        with pytest.raises(ValueError, match="empty"):
            flat_mesh(devices=[])

    def test_single_process_owns_every_shard(self):
        mesh = flat_mesh(devices=jax.devices())
        n = len(jax.devices())
        assert shard_process_indices(mesh) == [0] * n
        assert local_shards(mesh) == list(range(n))
        assert local_shards(mesh, process_index=1) == []
