"""Density ladder: tier construction, dispatch, and bit-identity.

The contract of the capacity ladder is that it changes WHERE work happens
(which rung a stratum runs at), never WHAT is computed: ladder runs must be
bit-identical to fixed-capacity runs — state trajectory, per-stratum delta
counts, dense fallbacks, and rehash bytes — on both backends."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.algorithms import pagerank, sssp
from repro.core.engine import CapacityTier, ShardedExecutor
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import make_powerlaw_graph, shard_csr

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def graph():
    n, S = 1024, 4
    indptr, indices = make_powerlaw_graph(n, avg_degree=8.0, seed=0)
    snap = PartitionSnapshot(n_keys=n, num_shards=S)
    return snap, shard_csr(indptr, indices, S)


class TestCapacityTiers:
    def _exec(self, snap, **kw):
        return ShardedExecutor(snapshot=snap, seg_capacity=16384,
                               edge_capacity=16384, src_capacity=1024, **kw)

    def test_ladder_off_single_rung(self, graph):
        snap, _ = graph
        algo = pagerank.make_algorithm(snap)
        tiers = self._exec(snap, ladder_tiers=1).capacity_tiers(algo)
        assert tiers == [CapacityTier(1024, 16384, 16384)]

    def test_no_emit_factory_single_rung(self, graph):
        snap, _ = graph
        import dataclasses
        algo = dataclasses.replace(pagerank.make_algorithm(snap),
                                   emit_factory=None)
        tiers = self._exec(snap, ladder_tiers=4).capacity_tiers(algo)
        assert len(tiers) == 1

    def test_rungs_ascend_to_configured_top(self, graph):
        snap, _ = graph
        algo = pagerank.make_algorithm(snap)
        tiers = self._exec(snap, ladder_tiers=4).capacity_tiers(algo)
        assert tiers[-1] == CapacityTier(1024, 16384, 16384)
        for lo, hi in zip(tiers, tiers[1:]):
            assert lo.src <= hi.src and lo.edge < hi.edge
        assert tiers[0].edge == 16384 // 4 ** 3

    def test_floors_collapse_duplicate_rungs(self, graph):
        snap, _ = graph
        algo = pagerank.make_algorithm(snap)
        ex = ShardedExecutor(snapshot=snap, seg_capacity=256,
                             edge_capacity=256, src_capacity=64,
                             ladder_tiers=4)
        # Every sub-rung hits the floors == top; only the top rung remains.
        assert ex.capacity_tiers(algo) == [CapacityTier(64, 256, 256)]


@pytest.mark.parametrize("algo_mod,kw", [
    (pagerank, dict(threshold=1e-3)),
    (sssp, dict(source=0)),
])
def test_ladder_bit_identical_simulated(graph, algo_mod, kw):
    snap, g = graph
    caps = dict(edge_capacity=16384, src_capacity=snap.block_size)
    a, ra = algo_mod.run(g, snap, mode="delta", ladder_tiers=1, **kw, **caps)
    b, rb = algo_mod.run(g, snap, mode="delta", ladder_tiers=4, **kw, **caps)
    assert bool(jnp.all(a == b))
    assert int(ra.stats.iterations) == int(rb.stats.iterations)
    for field in ("delta_counts", "used_dense", "rehash_bytes"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ra.stats, field)),
            np.asarray(getattr(rb.stats, field)), err_msg=field)


def test_ladder_dispatch_uses_small_tail_rungs(graph):
    """The point of the ladder: tail strata (shrinking |Δᵢ|) must land on
    strictly smaller rungs than the early flood."""
    snap, g = graph
    _, res = pagerank.run(g, snap, mode="delta", ladder_tiers=4,
                          threshold=1e-3, edge_capacity=16384,
                          src_capacity=snap.block_size)
    iters = int(res.stats.iterations)
    tiers = np.asarray(res.stats.tiers)[:iters]
    assert tiers.min() >= 0                    # never fell back dense
    assert tiers[-1] < tiers[0]                # tail rung below the flood
    assert tiers[-1] == 0                      # converged onto the smallest


def test_ladder_never_overflows_on_exact_prediction(graph):
    """Rung budgets are checked against EXACT predicted sizes, so a ladder
    run can never hit more dense fallbacks than the fixed-capacity run."""
    snap, g = graph
    # Tight budget: forces dense fallbacks in the flood phase.
    _, r1 = pagerank.run(g, snap, mode="delta", ladder_tiers=1,
                         edge_capacity=2048, src_capacity=snap.block_size)
    _, r4 = pagerank.run(g, snap, mode="delta", ladder_tiers=4,
                         edge_capacity=2048, src_capacity=snap.block_size)
    assert (np.asarray(r1.stats.used_dense)
            == np.asarray(r4.stats.used_dense)).all()
    assert int(np.sum(r1.stats.used_dense)) > 0   # the fallback really hit


@pytest.mark.slow
def test_ladder_bit_identical_shard_map():
    """Ladder dispatch on the real-SPMD backend: every shard must pick the
    same rung (the decision is pmax-reduced) and results must match the
    fixed-capacity simulated run exactly."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.data.graphs import make_powerlaw_graph, shard_csr
from repro.core.partition import PartitionSnapshot
from repro.core.engine import ShardedExecutor
from repro.algorithms import pagerank, sssp
n, S = 512, 8
indptr, indices = make_powerlaw_graph(n, avg_degree=8.0, seed=0)
snap = PartitionSnapshot(n_keys=n, num_shards=S)
g = shard_csr(indptr, indices, S)
mesh = jax.make_mesh((S,), ('shards',))
ex = ShardedExecutor(snapshot=snap, seg_capacity=8192, edge_capacity=8192,
                     src_capacity=512, backend='shard_map',
                     axis_name='shards', mesh=mesh, ladder_tiers=4)
for tag, runner, kw in (('pr', pagerank, {}), ('sp', sssp, dict(source=0))):
    caps = dict(edge_capacity=8192, src_capacity=512)
    a, ra = runner.run(g, snap, mode='delta', executor=ex, **kw, **caps)
    b, rb = runner.run(g, snap, mode='delta', **kw, **caps)
    assert bool(jnp.all(a == b)), tag
    assert np.array_equal(np.asarray(ra.stats.delta_counts),
                          np.asarray(rb.stats.delta_counts)), tag
print('LADDER_SHARD_MAP_OK')
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "LADDER_SHARD_MAP_OK" in out.stdout
