"""Distributed execution: shard_map == simulated, sharding rules, dry-run
cell machinery — under 8 virtual devices via subprocess (the main test
process must keep seeing 1 device)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = SRC
    cmd = [sys.executable, "-c", code]
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=900)
    # Mesh/backend failures often print the real cause to stdout (jax
    # warnings, our own asserts) — a truncated stderr alone makes them
    # undiagnosable from CI logs, so the failure message carries both
    # streams plus the exact reproducible command.
    assert out.returncode == 0, (
        f"subprocess exited {out.returncode}\n"
        f"command: XLA_FLAGS={env['XLA_FLAGS']!r} PYTHONPATH={SRC!r} "
        f"{' '.join(cmd[:-1])} <code below>\n"
        f"--- stderr (tail) ---\n{out.stderr[-3000:]}\n"
        f"--- stdout (tail) ---\n{out.stdout[-2000:]}\n"
        f"--- code ---\n{code}")
    return out.stdout


@pytest.mark.slow
def test_shard_map_identical_to_simulated():
    out = run_sub("""
import jax, jax.numpy as jnp
from repro.data.graphs import make_powerlaw_graph, shard_csr
from repro.core.partition import PartitionSnapshot
from repro.core.engine import ShardedExecutor
from repro.launch.mesh import flat_mesh
from repro.algorithms import pagerank, sssp
n, S = 512, 8
indptr, indices = make_powerlaw_graph(n, avg_degree=8.0, seed=0)
snap = PartitionSnapshot(n_keys=n, num_shards=S)
g = shard_csr(indptr, indices, S)
mesh = flat_mesh(S, 'shards')
ex = ShardedExecutor(snapshot=snap, seg_capacity=4096, edge_capacity=8192,
                     src_capacity=512, backend='shard_map',
                     axis_name='shards', mesh=mesh)
for algo, runner in (('pr', pagerank), ('sp', sssp)):
    kw = dict(edge_capacity=8192, src_capacity=512)
    a, _ = runner.run(g, snap, mode='delta', executor=ex, **kw)
    b, _ = runner.run(g, snap, mode='delta', **kw)
    assert bool(jnp.all(a == b)), algo
print('IDENTICAL')
""")
    assert "IDENTICAL" in out


@pytest.mark.slow
def test_sharding_rules_produce_valid_jit():
    out = run_sub("""
import jax, jax.numpy as jnp
from functools import partial
from repro.configs import get_arch
from repro.launch.mesh import make_mesh
from repro.launch.sharding import tree_specs, batch_spec, to_shardings
from repro.models import transformer
cfg = get_arch('llama3-8b')   # full config, abstract only
mesh = make_mesh((2, 4), ('data', 'model'))
params_a = jax.eval_shape(partial(transformer.init_params, cfg),
                          jax.random.PRNGKey(0))
specs = tree_specs(params_a, mesh, 'params')
toks = jax.ShapeDtypeStruct((8, 128), jnp.int32)
with mesh:
    lowered = jax.jit(
        lambda p, t: transformer.forward(cfg, p, t)[0],
        in_shardings=to_shardings(
            (specs, batch_spec(toks.shape, mesh)), mesh)
        ).lower(params_a, toks)
    compiled = lowered.compile()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):   # jax <= 0.4.x: one dict per computation
    ca = ca[0]
print('COMPILED', ca.get('flops', 0) > 0)
""")
    assert "COMPILED True" in out


@pytest.mark.slow
def test_dryrun_single_cell_entrypoint():
    """The dry-run driver end-to-end on the smallest cell (512 devs)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "olmo-1b",
         "--shape", "decode_32k"], env=env, capture_output=True,
        text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.splitlines()[-1])
    assert rec["devices"] == 256
    assert rec["flops"] > 0
    assert rec["collective_bytes"]["total"] > 0


@pytest.mark.slow
def test_elastic_rescale_under_devices():
    out = run_sub("""
import jax, jax.numpy as jnp
from repro.core.partition import PartitionSnapshot, shard_dense_state, \
    unshard_dense_state
from repro.runtime.elastic import grow
snap = PartitionSnapshot(n_keys=4096, num_shards=8)
x = shard_dense_state(snap, jnp.arange(4096.0))
snap2, (x2,) = grow(snap, 4, x)
assert jnp.all(unshard_dense_state(snap2, x2) == jnp.arange(4096.0))
print('ELASTIC_OK')
""")
    assert "ELASTIC_OK" in out


def test_gradient_compression_wire_math():
    """int8 ≈ N bytes + scales; delta = 8·k·leaves — pure accounting."""
    import jax.numpy as jnp
    from repro.train.optimizer import compress_tree, zero_residuals
    params = {"a": jnp.zeros((512,)), "b": jnp.zeros((256, 4))}
    res = zero_residuals(params)
    _, _, b_int8 = compress_tree(params, res, "int8")
    n = 512 + 1024
    assert float(b_int8) == n + (n // 256) * 4
    _, _, b_delta = compress_tree(params, res, "delta", topk_frac=0.01)
    assert float(b_delta) == 8 * (max(1, int(512 * .01))
                                  + max(1, int(1024 * .01)))
