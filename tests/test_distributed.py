"""Distributed execution: shard_map == simulated, sharding rules, dry-run
cell machinery — under 8 virtual devices via subprocess (the main test
process must keep seeing 1 device) — plus the real multi-process launch
path (jax.distributed bring-up, process-aware flat_mesh ownership, and
SIGKILL-driven recovery parity via ``repro.runtime.chaos --real``)."""
import json
import os
import subprocess
import sys

import pytest

from subproc import SRC, default_timeout, run_sub


@pytest.mark.slow
def test_shard_map_identical_to_simulated():
    out = run_sub("""
import jax, jax.numpy as jnp
from repro.data.graphs import make_powerlaw_graph, shard_csr
from repro.core.partition import PartitionSnapshot
from repro.core.engine import ShardedExecutor
from repro.launch.mesh import flat_mesh
from repro.algorithms import pagerank, sssp
n, S = 512, 8
indptr, indices = make_powerlaw_graph(n, avg_degree=8.0, seed=0)
snap = PartitionSnapshot(n_keys=n, num_shards=S)
g = shard_csr(indptr, indices, S)
mesh = flat_mesh(S, 'shards')
ex = ShardedExecutor(snapshot=snap, seg_capacity=4096, edge_capacity=8192,
                     src_capacity=512, backend='shard_map',
                     axis_name='shards', mesh=mesh)
for algo, runner in (('pr', pagerank), ('sp', sssp)):
    kw = dict(edge_capacity=8192, src_capacity=512)
    a, _ = runner.run(g, snap, mode='delta', executor=ex, **kw)
    b, _ = runner.run(g, snap, mode='delta', **kw)
    assert bool(jnp.all(a == b)), algo
print('IDENTICAL')
""")
    assert "IDENTICAL" in out


@pytest.mark.slow
def test_sharding_rules_produce_valid_jit():
    out = run_sub("""
import jax, jax.numpy as jnp
from functools import partial
from repro.configs import get_arch
from repro.launch.mesh import make_mesh
from repro.launch.sharding import tree_specs, batch_spec, to_shardings
from repro.models import transformer
cfg = get_arch('llama3-8b')   # full config, abstract only
mesh = make_mesh((2, 4), ('data', 'model'))
params_a = jax.eval_shape(partial(transformer.init_params, cfg),
                          jax.random.PRNGKey(0))
specs = tree_specs(params_a, mesh, 'params')
toks = jax.ShapeDtypeStruct((8, 128), jnp.int32)
with mesh:
    lowered = jax.jit(
        lambda p, t: transformer.forward(cfg, p, t)[0],
        in_shardings=to_shardings(
            (specs, batch_spec(toks.shape, mesh)), mesh)
        ).lower(params_a, toks)
    compiled = lowered.compile()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):   # jax <= 0.4.x: one dict per computation
    ca = ca[0]
print('COMPILED', ca.get('flops', 0) > 0)
""")
    assert "COMPILED True" in out


@pytest.mark.slow
def test_dryrun_single_cell_entrypoint():
    """The dry-run driver end-to-end on the smallest cell (512 devs)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "olmo-1b",
         "--shape", "decode_32k"], env=env, capture_output=True,
        text=True, timeout=default_timeout())
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.splitlines()[-1])
    assert rec["devices"] == 256
    assert rec["flops"] > 0
    assert rec["collective_bytes"]["total"] > 0


@pytest.mark.slow
def test_elastic_rescale_under_devices():
    out = run_sub("""
import jax, jax.numpy as jnp
from repro.core.partition import PartitionSnapshot, shard_dense_state, \
    unshard_dense_state
from repro.runtime.elastic import grow
snap = PartitionSnapshot(n_keys=4096, num_shards=8)
x = shard_dense_state(snap, jnp.arange(4096.0))
snap2, (x2,) = grow(snap, 4, x)
assert jnp.all(unshard_dense_state(snap2, x2) == jnp.arange(4096.0))
print('ELASTIC_OK')
""")
    assert "ELASTIC_OK" in out


def test_gradient_compression_wire_math():
    """int8 ≈ N bytes + scales; delta = 8·k·leaves — pure accounting."""
    import jax.numpy as jnp
    from repro.train.optimizer import compress_tree, zero_residuals
    params = {"a": jnp.zeros((512,)), "b": jnp.zeros((256, 4))}
    res = zero_residuals(params)
    _, _, b_int8 = compress_tree(params, res, "int8")
    n = 512 + 1024
    assert float(b_int8) == n + (n // 256) * 4
    _, _, b_delta = compress_tree(params, res, "delta", topk_frac=0.01)
    assert float(b_delta) == 8 * (max(1, int(512 * .01))
                                  + max(1, int(1024 * .01)))


# ---------------------------------------------------------------------------
# Real multi-process launch path: jax.distributed bring-up, heartbeat/
# lease failure detection, and SIGKILL-driven recovery parity.
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_jax_distributed_bringup_selftest():
    """2 REAL jax.distributed processes x 2 devices: global device view,
    disjoint process-aware flat-mesh ownership, one cross-process
    collective — via the CLI the CI distributed-smoke job runs."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.distributed", "--selftest",
         "--workers", "2", "--devices-per-worker", "2"],
        env=env, capture_output=True, text=True,
        timeout=default_timeout())
    assert out.returncode == 0, out.stderr[-3000:] + out.stdout[-2000:]
    rep = json.loads(out.stdout)
    assert rep["global_devices"] == 4
    assert rep["collective_ok"] is True
    owned = sorted(s for shards in rep["ownership"].values()
                   for s in shards)
    assert owned == [0, 1, 2, 3]


@pytest.mark.slow
def test_real_sigkill_recovery_parity():
    """A REAL worker SIGKILL mid-fixpoint: the lease table detects the
    loss, the queue-driven recovery rebuilds from replicas, a
    replacement worker reseeds the ring — and the final state is
    bit-identical to the failure-free single-process run, with the
    detection + real ack latencies recorded."""
    import tempfile

    import jax.numpy as jnp
    import numpy as np

    from repro.algorithms import sssp
    from repro.core.engine import ShardedExecutor
    from repro.core.partition import PartitionSnapshot, unshard_dense_state
    from repro.data.graphs import make_powerlaw_graph, shard_csr
    from repro.launch.distributed import Cluster, DistributedResilientDriver
    from repro.runtime.health import HealthConfig

    S, n = 4, 1024
    indptr, indices = make_powerlaw_graph(n, 8.0, 2.1, 0)
    snap = PartitionSnapshot(n_keys=n, num_shards=S)
    cap = max(16384, 4 * n)

    def remake(new_snap):
        a = sssp.make_algorithm(new_snap, src_capacity=new_snap.block_size,
                                edge_capacity=cap)
        e = ShardedExecutor(snapshot=new_snap, seg_capacity=cap,
                            edge_capacity=cap,
                            src_capacity=new_snap.block_size,
                            ladder_tiers=4, route_strategy="auto")
        return e, a, shard_csr(indptr, indices, new_snap.num_shards)

    g = shard_csr(indptr, indices, S)
    ex, algo, _ = remake(snap)
    state0 = sssp.initial_state(snap, 0)
    ref = ex.run(algo, state0, 1, g, 80)

    tmp = tempfile.mkdtemp(prefix="dist_parity_")
    cfg = HealthConfig(lease_ttl=1.0, straggle_after=0.3,
                       heartbeat_interval=0.05, ack_timeout=0.5)
    cluster = Cluster(f"{tmp}/cluster", S, num_shards=S, config=cfg,
                      detect="lease")
    cluster.start()
    killed = []

    def hook(drv):
        if not killed and drv.stratum >= 2:
            killed.append(drv.stratum)
            cluster.kill(1)

    ex2, algo2, _ = remake(snap)
    drv = DistributedResilientDriver(
        ex2, algo2, state0, 1, g, 80, ckpt_root=f"{tmp}/chain",
        cluster=cluster, remake=remake, chaos_hook=hook)
    res = drv.run()
    cluster.shutdown()

    ref_flat = np.asarray(unshard_dense_state(snap,
                                              jnp.stack(ref.state, -1)))
    got_flat = np.asarray(unshard_dense_state(
        snap.resnapshot(res.metrics["final_num_shards"]),
        jnp.stack(res.result.state, -1)))
    assert np.array_equal(ref_flat, got_flat)
    assert killed, "fixpoint converged before the kill stratum"
    # The kill was DETECTED (lease deadline), not announced.
    dets = res.metrics["worker_detections"]
    assert [d["worker"] for d in dets] == [1]
    assert dets[0]["detection_s"] > 0
    names = [e["event"] for e in res.metrics["events"]]
    assert "worker_dead" in names and "failure" in names
    assert "worker_replaced" in names and "recovery" in names
    assert res.metrics["recoveries"] >= 1
    # Real ack arrival walls replaced the measured per-shard latencies.
    assert res.metrics["acks_collected"] > 0
    assert all(len(row) >= 1 for row in drv.measured.latencies)


@pytest.mark.slow
def test_chaos_real_cli_parity():
    """The chaos CLI in --real mode: a seeded schedule delivered as
    actual SIGKILLs must still bit-match the failure-free reference
    (exit 0, identical=true in the summary)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-m", "repro.runtime.chaos", "--seed", "0",
         "--events", "2", "--quick", "--nodes", "1024", "--real"],
        env=env, capture_output=True, text=True,
        timeout=default_timeout())
    assert out.returncode == 0, out.stderr[-3000:] + out.stdout[-2000:]
    summary = json.loads(out.stdout)
    assert summary["mode"] == "real"
    assert summary["identical"] is True
    assert summary["signals_fired"], "no real signals were delivered"
