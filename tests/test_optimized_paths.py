"""The §Perf optimized paths vs their baselines — numerical equivalence
under real multi-device meshes (subprocess, 8 virtual devices)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_a2a_moe_dispatch_matches_sort():
    """EP mode (E % model == 0), TP mode (E < model), and gradients."""
    out = run_sub("""
import jax, jax.numpy as jnp, dataclasses
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs import get_arch
from repro.models.moe import init_moe, moe_ffn

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = dataclasses.replace(get_arch("arctic-480b").reduced(),
                          capacity_factor=32.0, n_experts=4)
p = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
jax.sharding.set_mesh(mesh)
with mesh:
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    y_sort, _ = jax.jit(lambda p, x: moe_ffn(cfg, p, x, "sort"))(p, xs)
    y_a2a, _ = jax.jit(lambda p, x: moe_ffn(cfg, p, x, "a2a"))(p, xs)
    assert float(jnp.max(jnp.abs(y_sort - y_a2a))) < 1e-4, "EP mode"
    cfg2 = dataclasses.replace(cfg, n_experts=3)
    p2 = init_moe(jax.random.PRNGKey(2), cfg2)
    y_s, _ = jax.jit(lambda p, x: moe_ffn(cfg2, p, x, "sort"))(p2, xs)
    y_a, _ = jax.jit(lambda p, x: moe_ffn(cfg2, p, x, "a2a"))(p2, xs)
    assert float(jnp.max(jnp.abs(y_s - y_a))) < 1e-4, "TP mode"
    def loss(p, x, strat):
        y, aux = moe_ffn(cfg, p, x, strat)
        return jnp.sum(y * y) + aux
    g1 = jax.jit(jax.grad(loss), static_argnums=2)(p, xs, "sort")
    g2 = jax.jit(jax.grad(loss), static_argnums=2)(p, xs, "a2a")
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in
              zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
    assert err < 1e-3, f"grads {err}"
print("A2A_OK")
""")
    assert "A2A_OK" in out


@pytest.mark.slow
def test_flash_decode_matches_full_under_sharded_cache():
    out = run_sub("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs import get_arch
from repro.models import attention as attn

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = get_arch("llama3-8b").reduced()
params = attn.init_gqa(jax.random.PRNGKey(0), cfg)
B, S = 4, 32
cache = attn.init_gqa_cache(cfg, B, S, jnp.float32)
# fill a prefix of the cache
k = jax.random.normal(jax.random.PRNGKey(1),
                      (B, cfg.n_kv_heads, S, cfg.hd))
pos = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
cache = {"k": k, "v": k * 0.5,
         "pos": jnp.where(pos < 20, pos, -1)}
x = jax.random.normal(jax.random.PRNGKey(2), (B, 1, cfg.d_model))
jax.sharding.set_mesh(mesh)
with mesh:
    c_sh = jax.device_put(cache, {
        "k": NamedSharding(mesh, P("data", None, "model", None)),
        "v": NamedSharding(mesh, P("data", None, "model", None)),
        "pos": NamedSharding(mesh, P("data", "model"))})
    f_full = jax.jit(lambda x, c: attn.gqa_decode(
        cfg, params, x, c, jnp.asarray(20), flash=False)[0])
    f_flash = jax.jit(lambda x, c: attn.gqa_decode(
        cfg, params, x, c, jnp.asarray(20), flash=True)[0])
    y1, y2 = f_full(x, c_sh), f_flash(x, c_sh)
    err = float(jnp.max(jnp.abs(y1 - y2)))
    assert err < 1e-4, err
print("FLASH_DECODE_OK")
""")
    assert "FLASH_DECODE_OK" in out


@pytest.mark.slow
def test_gather_fn_preserves_train_semantics():
    """ZeRO-3 gathering is a layout change only: loss is identical."""
    out = run_sub("""
import jax, jax.numpy as jnp
from functools import partial
from repro.configs import get_arch
from repro.launch.mesh import make_mesh
from repro.launch.sharding import make_gather_fn, tree_specs, batch_spec, \
    to_shardings
from repro.train.train_step import TrainConfig, init_train_state, \
    make_train_step

mesh = make_mesh((2, 4), ("data", "model"))
cfg = get_arch("olmo-1b").reduced()
batch = {"tokens": jnp.zeros((8, 32), jnp.int32) + 3,
         "labels": jnp.ones((8, 32), jnp.int32)}
losses = {}
jax.sharding.set_mesh(mesh)
with mesh:
    for name, gf in (("plain", None), ("zero3", make_gather_fn(mesh))):
        tcfg = TrainConfig(gather_fn=gf)
        state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, tcfg))
        _, m = step(state, batch)
        losses[name] = float(m["loss"])
assert abs(losses["plain"] - losses["zero3"]) < 1e-4, losses
print("GATHER_OK", losses)
""")
    assert "GATHER_OK" in out
