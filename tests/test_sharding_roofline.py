"""Sharding rules + roofline analysis: pure-function unit tests."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_arch
from repro.launch.mesh import make_mesh
from repro.launch.roofline import (analyse, model_flops, model_params,
                                   what_would_help, xlstm_correction)
from repro.launch.sharding import (batch_spec, cache_spec, drop_data,
                                   param_spec, tree_specs)


@pytest.fixture(scope="module")
def mesh():
    # Shape-only mesh usage: rules read axis names/sizes, not devices.
    return make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Axis metadata stand-in at production sizes (no devices needed)."""
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


class FakeMeshPod(FakeMesh):
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


class TestParamSpecs:
    def test_embed_vocab_sharded_when_divisible(self):
        s = param_spec("params/embed", (128256, 4096), FakeMesh())
        assert s == P("model", "data")

    def test_embed_fallback_when_vocab_indivisible(self):
        # minicpm3 vocab 73448 % 16 != 0 -> shard d_model on model instead
        s = param_spec("params/embed", (73448, 2560), FakeMesh())
        assert s == P(None, "model")

    def test_projection_2d_sharding(self):
        s = param_spec("params/units/b0_dense/attn/wq",
                       (32, 4096, 4096), FakeMesh())
        assert s == P(None, "data", "model")   # lead unit axis unsharded

    def test_wo_transposed(self):
        s = param_spec("params/units/b0_dense/attn/wo",
                       (32, 4096, 4096), FakeMesh())
        assert s == P(None, "model", "data")

    def test_experts_ep_when_divisible(self):
        s = param_spec("params/units/b0_moe/ffn/w_gate",
                       (35, 128, 7168, 4864), FakeMesh())
        assert s[1] == "model"                 # EP over experts

    def test_experts_tp_fallback_small_e(self):
        s = param_spec("params/units/b0_moe/ffn/w_gate",
                       (56, 8, 6144, 16384), FakeMesh())
        assert s[1] is None                    # 8 % 16 != 0 -> no EP

    def test_norm_scale_replicated(self):
        s = param_spec("params/units/b0_dense/ln1/scale", (32, 4096),
                       FakeMesh())
        assert tuple(s) == (None, None) or s == P(None, "model")

    def test_drop_data(self):
        assert drop_data(P("data", "model")) == P(None, "model")
        assert drop_data(P(("pod", "data"), None)) == P(None, None)
        assert drop_data(P("model", "data")) == P("model", None)


class TestBatchCacheSpecs:
    def test_batch_sharded_over_dp(self):
        assert batch_spec((256, 4096), FakeMesh()) == P(("data",), None)
        assert batch_spec((256, 4096), FakeMeshPod()) == \
            P(("pod", "data"), None)

    def test_batch_replicated_when_indivisible(self):
        assert batch_spec((1, 524288), FakeMesh()) == P(None, None)

    def test_kv_cache_context_parallel(self):
        # [B, Hkv, S, hd]: B over dp, S (largest divisible) over model
        s = cache_spec("cache/units/x/k", (32, 128, 8, 32768, 128),
                       FakeMesh())
        assert s == P(None, ("data",), None, "model", None)

    def test_tiny_state_replicated(self):
        s = cache_spec("cache/units/x/m", (12, 1, 4), FakeMesh())
        assert s == P(None, None, None)


class TestRoofline:
    def test_model_flops_ordering(self):
        """train > prefill > decode for the same arch."""
        t = model_flops("llama3-8b", "train_4k")
        p = model_flops("llama3-8b", "prefill_32k")
        d = model_flops("llama3-8b", "decode_32k")
        assert t > p > d > 0

    def test_moe_active_lt_total(self):
        total, active = model_params(get_arch("arctic-480b"))
        assert active < 0.1 * total            # top-2 of 128

    def test_swa_caps_attention_flops(self):
        """mixtral's window bounds decode attention vs a full-attn twin."""
        d_mix = model_flops("mixtral-8x22b", "long_500k")
        assert d_mix > 0

    def test_analyse_identifies_dominant(self):
        cell = {"arch": "olmo-1b", "shape": "train_4k", "devices": 256,
                "flops": 1e13, "bytes_accessed": 1e12,
                "collective_bytes": {"total": 1e13}}
        row = analyse(cell)
        assert row["dominant"] == "collective"
        assert "overlap" in what_would_help(row) or "pre-aggregate" in \
            what_would_help(row)

    def test_xlstm_correction_only_xlstm(self):
        assert xlstm_correction("llama3-8b", "train_4k") == 0.0
        assert xlstm_correction("xlstm-350m", "train_4k") > 0.0
        assert xlstm_correction("xlstm-350m", "decode_32k") == 0.0


class TestTreeSpecs:
    def test_full_param_tree_has_valid_specs(self, mesh):
        from functools import partial

        from repro.models import transformer
        cfg = get_arch("llama3-8b")
        params_a = jax.eval_shape(partial(transformer.init_params, cfg),
                                  jax.random.PRNGKey(0))
        specs = tree_specs(params_a, FakeMesh(), "params")
        leaves = jax.tree.leaves(specs,
                                 is_leaf=lambda x: isinstance(x, P))
        arrs = jax.tree.leaves(params_a)
        assert len(leaves) == len(arrs)
        for spec, arr in zip(leaves, arrs):
            assert len(spec) <= arr.ndim
            for i, ax in enumerate(spec):
                if ax in ("data", "model"):
                    assert arr.shape[i] % 16 == 0, (spec, arr.shape)
