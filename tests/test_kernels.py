"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref oracle."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.data.graphs import make_powerlaw_graph
from repro.kernels.delta_route import (delta_route, delta_route_ref,
                                       route_deltas)
from repro.kernels.scatter_route import (scatter_route, scatter_route_ref,
                                         scatter_route_deltas)
from repro.kernels.delta_scatter import (apply_delta, delta_scatter,
                                         delta_scatter_ref)
from repro.kernels.edge_propagate import (build_tiled_csc, edge_propagate,
                                          edge_propagate_ref, propagate)
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.kmeans_assign import assign, kmeans_assign_ref
from repro.core.delta import ANN_ADJUST, DeltaBuffer


class TestDeltaScatter:
    @pytest.mark.parametrize("n,w,c", [(512, 1, 256), (1024, 4, 512),
                                       (2048, 8, 256), (512, 1, 1024)])
    @pytest.mark.parametrize("combiner", ["add", "min", "max"])
    def test_sweep(self, n, w, c, combiner):
        if combiner in ("min", "max") and w != 1:
            pytest.skip("min/max kernels are W=1")
        rng = np.random.default_rng(n + c)
        state = jnp.asarray(rng.normal(size=(n, w)).astype(np.float32))
        idx = jnp.asarray(rng.integers(-1, n, size=c).astype(np.int32))
        pay = jnp.asarray(rng.normal(size=(c, w)).astype(np.float32))
        out_k = delta_scatter(state, idx, pay, combiner, tile_n=256,
                              chunk=256)
        out_r = delta_scatter_ref(state, idx, pay, combiner)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-5, atol=1e-5)

    def test_collisions_accumulate(self):
        state = jnp.zeros((512, 1))
        idx = jnp.zeros(256, jnp.int32)          # all hit key 0
        pay = jnp.ones((256, 1))
        out = delta_scatter(state, idx, pay, "add")
        assert float(out[0, 0]) == 256.0

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 999))
    def test_property_apply_delta_buffer(self, seed):
        rng = np.random.default_rng(seed)
        n = 512
        cnt = rng.integers(0, 64)
        keys = np.full(64, -1, np.int32)
        keys[:cnt] = rng.integers(0, n, cnt)
        pay = rng.normal(size=(64, 1)).astype(np.float32)
        db = DeltaBuffer(keys=jnp.asarray(keys), payload=jnp.asarray(pay),
                         ann=jnp.full(64, ANN_ADJUST, jnp.int8),
                         count=jnp.asarray(cnt),
                         overflowed=jnp.asarray(False))
        state = jnp.asarray(rng.normal(size=n).astype(np.float32))
        out_k = apply_delta(state, db, "add", use_kernel=True)
        out_r = apply_delta(state, db, "add", use_kernel=False)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-5, atol=1e-5)


class TestDeltaRoute:
    @pytest.mark.parametrize("c,w,shards,cap", [
        (256, 1, 4, 64), (512, 2, 8, 32), (256, 4, 1, 256), (1024, 1, 7, 8)])
    def test_sweep(self, c, w, shards, cap):
        rng = np.random.default_rng(c + shards)
        keys = rng.integers(-1, 1000, size=c).astype(np.int32)
        pay = rng.normal(size=(c, w)).astype(np.float32)
        ann = rng.integers(0, 4, size=c).astype(np.int32)
        owners = np.where(keys >= 0, keys % shards, shards).astype(np.int32)
        args = (jnp.asarray(keys), jnp.asarray(pay), jnp.asarray(ann),
                jnp.asarray(owners), shards, cap)
        out_k = delta_route(*args)
        out_r = delta_route_ref(*args)
        for a, b in zip(out_k, out_r):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_matches_route_by_owner(self):
        """ops-level dispatch == the engine's jnp routing, slot for slot."""
        from repro.core.delta import DeltaBuffer, route_by_owner
        rng = np.random.default_rng(0)
        n, shards, cap = 300, 6, 40
        count = 250
        keys = np.full(n, -1, np.int32)
        keys[:count] = rng.integers(0, 500, count)
        pay = rng.normal(size=(n, 2)).astype(np.float32)
        db = DeltaBuffer(keys=jnp.asarray(keys), payload=jnp.asarray(pay),
                         ann=jnp.asarray(rng.integers(0, 4, n), jnp.int8),
                         count=jnp.asarray(count),
                         overflowed=jnp.asarray(False))
        owners = jnp.where(db.keys >= 0, db.keys % shards, shards)
        ref = route_by_owner(db, owners, shards, cap)
        for use_kernel in (False, True):
            got = route_deltas(db, owners, shards, cap,
                               use_kernel=use_kernel)
            np.testing.assert_array_equal(np.asarray(ref.keys),
                                          np.asarray(got.keys))
            np.testing.assert_array_equal(np.asarray(ref.payload),
                                          np.asarray(got.payload))
            np.testing.assert_array_equal(np.asarray(ref.ann),
                                          np.asarray(got.ann))
            assert int(ref.count) == int(got.count)
            assert bool(ref.overflowed) == bool(got.overflowed)

    def test_overflowing_segment_sets_flag(self):
        from repro.core.delta import DeltaBuffer
        keys = jnp.arange(8, dtype=jnp.int32)          # all owner 0
        db = DeltaBuffer(keys=keys, payload=jnp.ones((8, 1)),
                         ann=jnp.zeros(8, jnp.int8), count=jnp.asarray(8),
                         overflowed=jnp.asarray(False))
        out = route_deltas(db, jnp.zeros(8, jnp.int32), 2, 4)
        assert bool(out.overflowed) and int(out.count) == 4


class TestScatterRoute:
    @pytest.mark.parametrize("c,w,shards,block,cap", [
        (256, 1, 4, 64, 32), (512, 2, 8, 32, 32), (256, 4, 1, 256, 128),
        (512, 1, 7, 40, 8)])
    def test_sweep_kernel_vs_ref(self, c, w, shards, block, cap):
        rng = np.random.default_rng(c + shards)
        n_keys = shards * block
        keys = rng.integers(-1, n_keys, size=c).astype(np.int32)
        pay = rng.normal(size=(c, w)).astype(np.float32)
        owners = np.where(keys >= 0, keys // block, shards).astype(np.int32)
        local = np.where(keys >= 0, keys % block, -1).astype(np.int32)
        args = (jnp.asarray(keys), jnp.asarray(pay), jnp.asarray(local),
                jnp.asarray(owners), shards, block, cap)
        out_k = scatter_route(*args)
        out_r = scatter_route_ref(*args, combiner="add")
        np.testing.assert_array_equal(np.asarray(out_k[0]),
                                      np.asarray(out_r[0]))
        np.testing.assert_allclose(np.asarray(out_k[1]),
                                   np.asarray(out_r[1]),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(out_k[2]),
                                      np.asarray(out_r[2]))

    @pytest.mark.parametrize("combiner", ["add", "min", "max", "replace"])
    def test_ops_matches_combine_route(self, combiner):
        """ops-level dispatch == the engine's fused sort path, slot for
        slot (payloads to float addition order for add)."""
        from repro.core.delta import (ANN_ADJUST, DeltaBuffer,
                                      combine_route)
        from repro.core.partition import PartitionSnapshot
        rng = np.random.default_rng(7)
        n, shards, cap, keyspace = 300, 6, 40, 500
        count = 250
        keys = np.full(n, -1, np.int32)
        keys[:count] = rng.integers(0, keyspace, count)
        pay = rng.normal(size=(n, 2)).astype(np.float32)
        db = DeltaBuffer(keys=jnp.asarray(keys), payload=jnp.asarray(pay),
                         ann=jnp.full(n, ANN_ADJUST, jnp.int8),
                         count=jnp.asarray(count),
                         overflowed=jnp.asarray(False))
        snap = PartitionSnapshot(n_keys=keyspace, num_shards=shards)
        owners = snap.owner_of(db.keys)
        ref = combine_route(db, owners, shards, cap, combiner)
        for use_kernel in (False, True):
            got = scatter_route_deltas(db, owners, shards, cap, combiner,
                                       snapshot=snap,
                                       use_kernel=use_kernel)
            np.testing.assert_array_equal(np.asarray(ref.keys),
                                          np.asarray(got.keys))
            np.testing.assert_array_equal(np.asarray(ref.ann),
                                          np.asarray(got.ann))
            if combiner == "add":
                np.testing.assert_allclose(np.asarray(ref.payload),
                                           np.asarray(got.payload),
                                           rtol=1e-5, atol=1e-6)
            else:
                np.testing.assert_array_equal(np.asarray(ref.payload),
                                              np.asarray(got.payload))
            assert int(ref.count) == int(got.count)
            assert bool(ref.overflowed) == bool(got.overflowed)


class TestEdgePropagate:
    @pytest.mark.parametrize("n,deg", [(600, 6.0), (1500, 12.0)])
    @pytest.mark.parametrize("combiner", ["add", "min"])
    def test_sweep(self, n, deg, combiner):
        indptr, indices = make_powerlaw_graph(n, avg_degree=deg, seed=n)
        csc = build_tiled_csc(indptr, indices, n, tile_n=512, chunk=256)
        rng = np.random.default_rng(1)
        payload = jnp.asarray(rng.normal(size=n).astype(np.float32))
        out_k = propagate(payload, csc, n, combiner, use_kernel=True)
        out_r = propagate(payload, csc, n, combiner, use_kernel=False)
        mask = np.isfinite(np.asarray(out_r))
        np.testing.assert_allclose(np.asarray(out_k)[mask],
                                   np.asarray(out_r)[mask],
                                   rtol=1e-4, atol=1e-4)

    def test_matches_pagerank_dense_push(self):
        """The kernel's contract == the engine's dense push semantics."""
        n = 512
        indptr, indices = make_powerlaw_graph(n, avg_degree=8.0, seed=3)
        deg = np.maximum(np.diff(indptr), 1)
        pr = np.random.default_rng(0).random(n).astype(np.float32)
        csc = build_tiled_csc(indptr, indices, n)
        out = np.asarray(propagate(jnp.asarray(pr / deg), csc, n, "add"))
        expect = np.zeros(n, np.float32)
        src = np.repeat(np.arange(n), np.diff(indptr))
        np.add.at(expect, indices, (pr / deg)[src])
        np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


class TestKMeansAssign:
    @pytest.mark.parametrize("n,k,d", [(1000, 8, 2), (777, 32, 5),
                                       (4096, 128, 2), (256, 3, 16)])
    def test_sweep(self, n, k, d):
        rng = np.random.default_rng(n * k)
        pts = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        cents = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
        a_k, d_k = assign(pts, cents, tile_p=256)
        a_r, d_r = kmeans_assign_ref(pts, cents)
        assert bool(jnp.all(a_k == a_r))
        np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r),
                                   rtol=1e-4, atol=1e-4)


class TestFlashAttention:
    @pytest.mark.parametrize("b,h,hkv,t,s,d", [
        (2, 4, 2, 256, 256, 64), (1, 8, 8, 128, 128, 32),
        (2, 4, 1, 256, 384, 64), (1, 2, 2, 384, 128, 128)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_sweep(self, b, h, hkv, t, s, d, causal):
        if causal and t != s:
            pytest.skip("causal kernels assume aligned diag (t == s)")
        rng = np.random.default_rng(t + s)
        q = jnp.asarray(rng.normal(size=(b, h, t, d)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(np.float32))
        out_k = flash_attention(q, k, v, causal=causal)
        out_r = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=2e-4, atol=2e-4)

    def test_blocked_xla_variant_matches(self):
        from repro.models.attention import blocked_attention
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(2, 4, 256, 32)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(2, 2, 256, 32)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(2, 2, 256, 32)).astype(np.float32))
        out_b = blocked_attention(q, k, v, causal=True, block_k=64)
        out_r = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_r),
                                   rtol=2e-4, atol=2e-4)
