"""Declarative rule frontend: parse round-trips, optimizer rewrites,
lowering bit-identity against the handwritten algorithms, and a rules-only
program (reachability) running end-to-end with zero engine changes."""
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro import frontend as F
from repro.algorithms import connected_components as cc
from repro.algorithms import pagerank, sssp
from repro.core import plan as P
from repro.core.engine import ShardedExecutor
from repro.core.optimizer import CostModel, optimize
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import make_powerlaw_graph, shard_csr
from repro.frontend import expr as E
from repro.frontend.lower import CompiledProgram, _extract_spec
from repro.obs.calibrate import RouteCostTable
from repro.runtime import FaultEvent, FaultSchedule

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
N, S = 1024, 4


@pytest.fixture(scope="module")
def graph():
    indptr, indices = make_powerlaw_graph(N, avg_degree=8.0, seed=0)
    snap = PartitionSnapshot(n_keys=N, num_shards=S)
    return indptr, indices, snap, shard_csr(indptr, indices, S)


# ---------------------------------------------------------------------------
# Parse / build / render round-trips.
# ---------------------------------------------------------------------------

def _random_expr(rng, rels, var="u", depth=0):
    roll = rng.integers(0, 3 if depth < 3 else 2)
    if roll == 0:
        return E.Const(float(np.round(rng.uniform(-4, 4), 3)))
    if roll == 1:
        return E.Ref(str(rng.choice(rels)), var)
    op = str(rng.choice(["+", "-", "*", "/"]))
    return E.BinOp(op, _random_expr(rng, rels, var, depth + 1),
                   _random_expr(rng, rels, var, depth + 1))


class TestParseRoundTrip:
    @pytest.mark.parametrize("text,builder", [
        (F.PAGERANK_TEXT, F.pagerank_program),
        (F.SSSP_TEXT, F.sssp_program),
        (F.CC_TEXT, F.cc_program),
        (F.REACHABILITY_TEXT, F.reachability_program),
    ])
    def test_canonical_programs(self, text, builder):
        parsed = F.parse_program(text)
        assert parsed == builder()
        assert F.parse_program(parsed.to_text()) == parsed

    @settings(max_examples=30)
    @given(seed=st.integers(0, 10**6),
           agg=st.sampled_from(["add", "min", "max"]),
           threshold=st.floats(min_value=1e-6, max_value=10.0))
    def test_random_programs(self, seed, agg, threshold):
        """Property: build → to_text → parse is the identity."""
        rng = np.random.default_rng(seed)
        b = F.ProgramBuilder(f"p{seed}").threshold(threshold)
        b.input("edge", "u", "v")
        if rng.integers(0, 2):
            b.init("head", _random_expr(rng, ["id"], var="v"), var="v")
        for _ in range(rng.integers(0, 3)):
            b.fact("head", int(rng.integers(0, 100)),
                   float(np.round(rng.uniform(-9, 9), 3)))
        b.rule("head", agg, _random_expr(rng, ["head", "deg"]),
               var="v", src="u")
        prog = b.build()
        assert F.parse_program(prog.to_text()) == prog

    @settings(max_examples=20)
    @given(seed=st.integers(0, 10**6))
    def test_expr_text_round_trip(self, seed):
        """Property: the expression TREE (not just its value) round-trips
        through to_text — parenthesization must respect associativity."""
        rng = np.random.default_rng(seed)
        e = _random_expr(rng, ["x", "deg"])
        b = (F.ProgramBuilder("t").input("edge", "u", "v")
             .rule("x", "add", e, var="v", src="u").build())
        assert F.parse_program(b.to_text()).rules[0].term == b.rules[0].term

    def test_comments_and_whitespace(self):
        text = ("# header comment\nprogram   demo.\n"
                "input edge(u, v).  # trailing\n"
                "x(v) min= x(u) :- edge(u, v).\n")
        prog = F.parse_program(text)
        assert prog.name == "demo" and prog.rules[0].agg == "min"

    def test_parse_errors(self):
        with pytest.raises(F.ParseError):
            F.parse_program("program p. @!?")
        with pytest.raises(F.ParseError):
            F.parse_program("x(v) foo= x(u) :- edge(u, v).")
        with pytest.raises(F.ParseError):
            F.parse_program("input edge(u, v). x(w) min= x(u) "
                            ":- edge(u, v).")  # head var != edge dst
        with pytest.raises(F.FrontendError):
            F.parse_program("threshold 0.0.\ninput edge(u, v).")

    def test_builder_validation(self):
        with pytest.raises(F.FrontendError):
            F.ProgramBuilder("p").rule("x", "add", E.ref("x")).build()
        with pytest.raises(F.FrontendError):
            (F.ProgramBuilder("p").input("edge", "u", "v")
             .rule("x", "avg", E.ref("x")).build())
        with pytest.raises(F.FrontendError):  # cross-variable reference
            (F.ProgramBuilder("p").input("edge", "u", "v")
             .rule("x", "add", E.ref("x", "v")).build())


# ---------------------------------------------------------------------------
# Planner + optimizer: real IR-to-IR rewrites.
# ---------------------------------------------------------------------------

class TestPlanAndOptimize:
    def test_plan_shape(self):
        plan = F.plan_program(F.pagerank_program())
        assert plan.op == "fixpoint" and plan.combiner == "add"
        ops = [n.op for n in P.walk(plan)]
        for op in ("scan", "select", "udf", "join", "project", "rehash",
                   "groupby"):
            assert op in ops
        names = [n.name for n in P.walk(plan) if n.op == "udf"]
        assert "view:rank" in names and "term" in names

    def test_optimizer_pushes_preagg_below_rehash(self):
        raw = F.plan_program(F.pagerank_program())
        opt = optimize(raw)
        seq = [n.op for n in P.walk(opt)]
        assert seq.index("rehash") < seq.index("preagg")  # preagg under it
        # Sender-side combining shrinks the network lane by ~the preagg
        # reduction; the plan stays scan(disk)-dominated overall.
        assert P.total_resource(opt)[2] < 0.2 * P.total_resource(raw)[2]
        assert P.plan_runtime(opt) <= P.plan_runtime(raw)

    def test_optimizer_idempotent(self):
        plan = F.plan_program(F.pagerank_program())
        once = optimize(plan)
        twice = optimize(once)
        assert once == twice

    def test_pinned_udfs_survive_in_order(self):
        opt = optimize(F.plan_program(F.pagerank_program()))
        names = [n.name for n in P.walk(opt) if n.op == "udf"]
        assert names.index("term") < names.index("view:rank")  # term above

    def test_fixpoint_idempotent_takes_retraction_path(self):
        """Satellite: min/max fixpoints cost-estimate along the §6
        delta-retraction path — geometric Δ decay, fewer iterations and a
        cheaper plan than the same shape under a monotone add."""
        base = P.scan("r", 1e5)
        rec = P.rehash(P.scan("delta", 1e5))
        fp_add = P.fixpoint(base, rec, max_iters=64, combiner="add")
        fp_min = P.fixpoint(base, rec, max_iters=64, combiner="min")
        fp_max = P.fixpoint(base, rec, max_iters=64, combiner="max")
        assert fp_min.estimated_iterations < fp_add.estimated_iterations
        assert fp_max.estimated_iterations == fp_min.estimated_iterations
        assert P.plan_runtime(fp_min) < P.plan_runtime(fp_add)
        assert fp_add.estimated_iterations == 64  # monotone: full budget

    def test_cost_model_from_route_table(self):
        """Satellite: the optimizer consults measured route costs when a
        calibration table is provided, static constants otherwise."""
        table = RouteCostTable(backend="cpu", combiner="add",
                               entries={1024: (1.024e-4, 2e-4),
                                        4096: (8e-4, 4.096e-4)})
        assert table.per_tuple_cost(1024) == pytest.approx(1e-7)
        assert table.per_tuple_cost(4096) == pytest.approx(1e-7)
        cm = CostModel.from_route_table(table)
        assert cm.rehash_net_per_tuple == pytest.approx(
            table.median_per_tuple())
        assert cm.source == "measured:cpu"
        assert CostModel().source == "static"
        plan = F.plan_program(F.pagerank_program(), cost_model=cm)
        rh = next(n for n in P.walk(plan) if n.op == "rehash")
        assert rh.resource[2] == pytest.approx(
            rh.out_cardinality * cm.rehash_net_per_tuple)

    def test_optimized_plan_runs_identically(self, graph):
        """Rewrites change cost, never semantics: lowering the raw planner
        output and the optimized plan gives bit-identical runs."""
        _, _, snap, g = graph
        prog = F.pagerank_program()
        opt_cp = F.compile_program(prog)
        logical = F.plan_program(prog)
        raw_cp = CompiledProgram(program=prog, logical=logical,
                                 optimized=logical,
                                 spec=_extract_spec(prog, logical))
        a, _ = opt_cp.run(g, snap, max_iters=40)
        b, _ = raw_cp.run(g, snap, max_iters=40)
        assert bool(jnp.all(a == b))


# ---------------------------------------------------------------------------
# Lowering validation.
# ---------------------------------------------------------------------------

class TestLoweringValidation:
    def test_nonlinear_add_term_rejected(self):
        prog = (F.ProgramBuilder("bad").input("edge", "u", "v")
                .rule("x", "add", E.ref("x") * E.ref("x")).build())
        with pytest.raises(F.FrontendError, match="homogeneous-linear"):
            F.compile_program(prog)

    def test_affine_add_term_rejected(self):
        # T(a) = 0.15 + 0.85 a is affine: T(a) − T(b) ≠ T(a − b).
        prog = (F.ProgramBuilder("bad").input("edge", "u", "v")
                .rule("x", "add", 0.15 + 0.85 * E.ref("x")).build())
        with pytest.raises(F.FrontendError, match="homogeneous-linear"):
            F.compile_program(prog)

    def test_view_over_idempotent_head_rejected(self):
        prog = (F.ProgramBuilder("bad").input("edge", "u", "v")
                .view("y", 2.0 * E.ref("x"))
                .rule("x", "min", E.ref("y")).build())
        with pytest.raises(NotImplementedError):
            F.compile_program(prog)

    def test_multi_rule_rejected(self):
        prog = (F.ProgramBuilder("bad").input("edge", "u", "v")
                .rule("x", "min", E.ref("x"))
                .rule("y", "min", E.ref("y")).build())
        with pytest.raises(NotImplementedError, match="one recursive rule"):
            F.compile_program(prog)

    def test_unknown_relation_in_term_rejected(self):
        prog = (F.ProgramBuilder("bad").input("edge", "u", "v")
                .rule("x", "min", E.ref("mystery")).build())
        with pytest.raises(F.FrontendError, match="mystery"):
            F.compile_program(prog)


# ---------------------------------------------------------------------------
# Compiled vs handwritten: bit-identity (simulated backend).
# ---------------------------------------------------------------------------

def _ulp_close(a, b, ulps=1):
    a, b = np.asarray(a), np.asarray(b)
    tol = ulps * np.spacing(np.maximum(np.abs(a), np.abs(b)))
    both_nonfinite = ~np.isfinite(a) & ~np.isfinite(b) & (np.sign(a)
                                                          == np.sign(b))
    return bool(np.all(both_nonfinite | (np.abs(a - b) <= tol)))


class TestBitIdentity:
    @settings(max_examples=4)
    @given(seed=st.integers(0, 1000), deg=st.sampled_from([4.0, 12.0]))
    def test_pagerank(self, seed, deg):
        indptr, indices = make_powerlaw_graph(512, avg_degree=deg, seed=seed)
        snap = PartitionSnapshot(n_keys=512, num_shards=S)
        g = shard_csr(indptr, indices, S)
        cp = F.compile_program(F.pagerank_program())
        got, rg = cp.run(g, snap, max_iters=60)
        want, rw = pagerank.run(g, snap, max_iters=60)
        # ≤1 ulp budget for the float-add combiner; currently exact.
        assert _ulp_close(got, want, ulps=1)
        assert bool(jnp.all(got == want))
        assert np.array_equal(np.asarray(rg.stats.delta_counts),
                              np.asarray(rw.stats.delta_counts))

    @settings(max_examples=4)
    @given(seed=st.integers(0, 1000), source=st.integers(0, 511))
    def test_sssp(self, seed, source):
        indptr, indices = make_powerlaw_graph(512, avg_degree=8.0, seed=seed)
        snap = PartitionSnapshot(n_keys=512, num_shards=S)
        g = shard_csr(indptr, indices, S)
        cp = F.compile_program(F.sssp_program(source=source))
        got, _ = cp.run(g, snap, max_iters=80)
        want, _ = sssp.run(g, snap, source=source, max_iters=80)
        assert bool(jnp.all(got == want))

    @settings(max_examples=4)
    @given(seed=st.integers(0, 1000))
    def test_connected_components(self, seed):
        indptr, indices = make_powerlaw_graph(512, avg_degree=6.0, seed=seed)
        snap = PartitionSnapshot(n_keys=512, num_shards=S)
        g = shard_csr(indptr, indices, S)
        cp = F.compile_program(F.cc_program())
        got, _ = cp.run(g, snap, max_iters=80)
        want, _ = cc.run(g, snap, max_iters=80)
        assert bool(jnp.all(got == want))

    def test_dense_mode_and_ladder(self, graph):
        """Compiled algorithms inherit the executor machinery unchanged:
        no-delta mode and the capacity ladder stay bit-identical."""
        _, _, snap, g = graph
        cp = F.compile_program(F.pagerank_program())
        a, _ = cp.run(g, snap, mode="nodelta", max_iters=40)
        b, _ = pagerank.run(g, snap, mode="nodelta", max_iters=40)
        assert bool(jnp.all(a == b))
        c, rc = cp.run(g, snap, max_iters=40, ladder_tiers=4,
                       src_capacity=snap.block_size)
        d, rd = pagerank.run(g, snap, max_iters=40, ladder_tiers=4,
                             src_capacity=snap.block_size)
        assert bool(jnp.all(c == d))
        assert np.array_equal(np.asarray(rc.stats.delta_counts),
                              np.asarray(rd.stats.delta_counts))


# ---------------------------------------------------------------------------
# Rules-only reachability: whole pipeline, zero engine changes.
# ---------------------------------------------------------------------------

class TestReachability:
    @settings(max_examples=5)
    @given(seed=st.integers(0, 1000), source=st.integers(0, 511))
    def test_matches_bfs_oracle(self, seed, source):
        n = 512
        indptr, indices = make_powerlaw_graph(n, avg_degree=6.0, seed=seed)
        snap = PartitionSnapshot(n_keys=n, num_shards=S)
        g = shard_csr(indptr, indices, S)
        cp = F.compile_program(F.reachability_program(source=source))
        vals, res = cp.run(g, snap, max_iters=80)
        dist = np.asarray(sssp.reference_sssp(np.asarray(indptr),
                                              np.asarray(indices), n,
                                              source=source))
        assert np.array_equal(np.asarray(vals)[:n] == 1.0, dist < np.inf)
        assert int(res.stats.iterations) < 80  # converged, not exhausted

    def test_from_text(self, graph):
        _, _, snap, g = graph
        cp = F.compile_program(F.parse_program(F.REACHABILITY_TEXT))
        vals, _ = cp.run(g, snap, max_iters=80)
        assert float(np.asarray(vals)[0]) == 1.0


# ---------------------------------------------------------------------------
# Resilient driver + shard_map backend.
# ---------------------------------------------------------------------------

class TestEngineIntegration:
    def test_resilient_run_with_fault_schedule(self, graph):
        """A compiled program survives injected failures and lands on the
        same state as the undisturbed run."""
        _, _, snap, g = graph
        cp = F.compile_program(F.sssp_program())
        ex = ShardedExecutor(snapshot=snap, seg_capacity=8192,
                             edge_capacity=8192,
                             src_capacity=snap.block_size)
        algo = cp.make_algorithm(snap, src_capacity=snap.block_size,
                                 edge_capacity=8192)
        state0 = cp.initial_state(snap)
        live0 = ex.live_count(algo, state0, g)
        ref = ex.run(algo, state0, live0, g, 80)
        schedule = FaultSchedule(events=(
            FaultEvent(kind="fail", at=2, shard=1),
            FaultEvent(kind="fail", at=4, shard=3),
        ))
        with tempfile.TemporaryDirectory() as td:
            rr = ex.run_resilient(algo, state0, live0, g, 80,
                                  ckpt_root=td, fault_plan=schedule)
        assert rr.metrics["converged"]
        assert rr.metrics["recoveries"] == 2
        assert bool(jnp.all(jnp.stack(
            [jnp.all(x == y) for x, y in zip(ref.state,
                                             rr.result.state)])))
        assert bool(jnp.all(cp.values(rr.result.state)
                            == cp.values(ref.state)))

    @pytest.mark.slow
    def test_bit_identical_shard_map(self):
        """Compiled PR/SSSP/CC match the handwritten algorithms on the
        real-SPMD shard_map backend too."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.data.graphs import make_powerlaw_graph, shard_csr
from repro.core.partition import PartitionSnapshot
from repro.core.engine import ShardedExecutor
from repro.algorithms import pagerank, sssp, connected_components as cc
from repro import frontend as F
n, S = 512, 8
indptr, indices = make_powerlaw_graph(n, avg_degree=8.0, seed=0)
snap = PartitionSnapshot(n_keys=n, num_shards=S)
g = shard_csr(indptr, indices, S)
mesh = jax.make_mesh((S,), ('shards',))
def make_ex():
    return ShardedExecutor(snapshot=snap, seg_capacity=8192,
                           edge_capacity=8192, src_capacity=snap.block_size,
                           backend='shard_map', axis_name='shards',
                           mesh=mesh)
cases = [(F.pagerank_program(), pagerank, {}, 60),
         (F.sssp_program(), sssp, dict(source=0), 80),
         (F.cc_program(), cc, {}, 80)]
caps = dict(src_capacity=snap.block_size, edge_capacity=8192)
for prog, mod, kw, iters in cases:
    cp = F.compile_program(prog)
    a, _ = cp.run(g, snap, max_iters=iters, executor=make_ex(), **caps)
    b, _ = mod.run(g, snap, max_iters=iters, executor=make_ex(), **kw,
                   **caps)
    assert bool(jnp.all(a == b)), prog.name
print('FRONTEND_SHARD_MAP_OK')
"""
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = SRC
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stderr[-3000:]
        assert "FRONTEND_SHARD_MAP_OK" in out.stdout
