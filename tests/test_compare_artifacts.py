"""Exit-code contract of the benchmark regression gate
(benchmarks/compare_artifacts.py), exercised as a subprocess the way CI
invokes it.  The critical case: a suite present in the committed baseline
but absent from the fresh run must warn and exit 3 (ungated ≠ clean)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def write_artifact(d, suite, value=1.0, quick=True, failed=False):
    payload = {"suite": suite, "quick": quick, "failed": failed,
               "wall_s": value, "config": {},
               "metrics": {},
               "records": [{"name": f"{suite}_steady", "value": value,
                            "unit": "s"}]}
    with open(os.path.join(d, f"BENCH_{suite}.json"), "w") as fh:
        json.dump(payload, fh)


def run_gate(baseline, fresh, *extra):
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.compare_artifacts",
         "--baseline", str(baseline), "--fresh", str(fresh), *extra],
        capture_output=True, text=True, cwd=ROOT, timeout=120)
    return out.returncode, out.stdout, out.stderr


@pytest.fixture
def dirs(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    base.mkdir(), fresh.mkdir()
    return base, fresh


class TestCompareArtifacts:
    def test_clean_compare_exits_zero(self, dirs):
        base, fresh = dirs
        write_artifact(base, "alpha")
        write_artifact(fresh, "alpha")
        code, out, _ = run_gate(base, fresh)
        assert code == 0 and "no wall-clock regressions" in out

    def test_regression_exits_one(self, dirs):
        base, fresh = dirs
        write_artifact(base, "alpha", value=1.0)
        write_artifact(fresh, "alpha", value=2.0)
        code, out, err = run_gate(base, fresh)
        assert code == 1 and "REGRESSION" in out

    def test_failed_fresh_suite_exits_one(self, dirs):
        base, fresh = dirs
        write_artifact(base, "alpha")
        write_artifact(fresh, "alpha", failed=True)
        code, _, _ = run_gate(base, fresh)
        assert code == 1

    def test_empty_fresh_dir_exits_two(self, dirs):
        base, fresh = dirs
        write_artifact(base, "alpha")
        code, _, err = run_gate(base, fresh)
        assert code == 2 and "no BENCH_" in err

    def test_mode_mismatch_exits_three(self, dirs):
        base, fresh = dirs
        write_artifact(base, "alpha", quick=False)
        write_artifact(fresh, "alpha", quick=True)
        code, _, err = run_gate(base, fresh)
        assert code == 3 and "mode mismatch" in err

    def test_baseline_suite_missing_from_fresh_exits_three(self, dirs):
        """A suite silently dropped from the bench matrix must not read
        as a pass: loud stderr WARNING + exit 3, like mode-mismatch."""
        base, fresh = dirs
        write_artifact(base, "alpha")
        write_artifact(base, "beta")
        write_artifact(fresh, "alpha")
        code, _, err = run_gate(base, fresh)
        assert code == 3
        assert "missing from the fresh run" in err and "beta" in err
        assert "alpha" not in err.split("missing", 1)[-1]

    def test_missing_suite_outside_only_filter_ignored(self, dirs):
        base, fresh = dirs
        write_artifact(base, "alpha")
        write_artifact(base, "beta")
        write_artifact(fresh, "alpha")
        code, _, _ = run_gate(base, fresh, "--only", "alpha")
        assert code == 0

    def test_missing_suite_inside_only_filter_caught(self, dirs):
        base, fresh = dirs
        write_artifact(base, "alpha")
        write_artifact(base, "beta")
        write_artifact(fresh, "alpha")
        code, _, err = run_gate(base, fresh, "--only", "alpha,beta")
        assert code == 3 and "beta" in err

    def test_fresh_only_suite_is_not_missing(self, dirs):
        base, fresh = dirs
        write_artifact(base, "alpha")
        write_artifact(fresh, "alpha")
        write_artifact(fresh, "gamma")  # new suite, no baseline yet: fine
        code, out, _ = run_gate(base, fresh)
        assert code == 0 and "no committed baseline" in out
