"""Chaos-hardened recovery: multi-event fault schedules, retry/timeout/
backoff, checkpoint integrity, and graceful degradation.

The contract split across these tests:

  * Every RECOVERABLE chaos schedule — however many compounding faults,
    correlated replica losses, failures-during-recovery, rescales, and
    stragglers it strings together — yields a final state bit-identical
    to the failure-free run (resilience changes WHEN/WHERE work happens,
    never WHAT is computed).
  * Every UNRECOVERABLE schedule (recovery budget exhausted) degrades:
    the view layer serves the last converged snapshot with explicit
    staleness metadata.  It never raises to the caller and never serves
    a corrupt or partially-updated answer.
  * Checkpoint I/O is torn-write-safe: atomic writes leave the previous
    restore point intact, checksums catch corruption, corrupt copies
    quarantine and fall back to replicas or older steps.
"""
import json
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.algorithms import sssp
from repro.core.engine import ShardedExecutor
from repro.core.partition import PartitionSnapshot, unshard_dense_state
from repro.data.graphs import make_powerlaw_graph, shard_csr
from repro.runtime.chaos import (ChaosConfig, acceptance_schedule,
                                 generate_schedule)
from repro.runtime.checkpoint import (CheckpointCorruption,
                                      CheckpointManager, atomic_write_json)
from repro.runtime.recovery import (FaultEvent, FaultPlan, FaultSchedule,
                                    ReplicaChain, as_schedule)
from repro.runtime.retry import (OperationTimeout, RecoveryExhausted,
                                 Retrier, RetryBudget, RetryPolicy)
from repro.runtime.straggler import SpeculationPolicy, StragglerMitigator

N, S = 512, 4


@pytest.fixture(scope="module")
def graph():
    indptr, indices = make_powerlaw_graph(N, avg_degree=8.0, seed=0)
    snap = PartitionSnapshot(n_keys=N, num_shards=S)
    return indptr, indices, snap, shard_csr(indptr, indices, S)


def make_executor(snap, **kw):
    kw.setdefault("ladder_tiers", 4)
    return ShardedExecutor(snapshot=snap, seg_capacity=8192,
                          edge_capacity=8192,
                          src_capacity=snap.block_size, **kw)


def flat_state(snap, state) -> np.ndarray:
    return np.asarray(unshard_dense_state(snap, jnp.stack(state, -1)))


# ---------------------------------------------------------------------------
# Retry policy: deterministic backoff, budgets, timeouts.
# ---------------------------------------------------------------------------

class TestRetry:
    def test_backoff_deterministic_seeded_and_bounded(self):
        p = RetryPolicy(base_delay=0.01, max_delay=1.0, jitter=0.5, seed=7)
        for attempt in range(6):
            d1 = p.backoff("restore:1", attempt)
            d2 = p.backoff("restore:1", attempt)
            assert d1 == d2            # deterministic per (seed, op, k)
            raw = min(0.01 * 2 ** attempt, 1.0)
            assert raw * 0.5 <= d1 <= raw * 1.5
        # distinct ops / seeds draw distinct jitter streams
        assert p.backoff("restore:1", 0) != p.backoff("restore:2", 0)
        q = RetryPolicy(base_delay=0.01, max_delay=1.0, jitter=0.5, seed=8)
        assert p.backoff("restore:1", 3) != q.backoff("restore:1", 3)

    def test_retrier_retries_transient_then_succeeds(self):
        calls = {"n": 0}
        slept = []

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise OSError("transient")
            return "ok"

        r = Retrier(policy=RetryPolicy(max_attempts=4),
                    sleep=slept.append)
        assert r.call(flaky, op="read") == "ok"
        assert calls["n"] == 3 and len(slept) == 2
        assert [e["kind"] for e in r.events] == ["retry", "retry"]

    def test_exhaustion_kinds_distinguish_local_from_budget(self):
        r = Retrier(policy=RetryPolicy(max_attempts=2),
                    sleep=lambda s: None)
        with pytest.raises(RecoveryExhausted) as ei:
            r.call(lambda: (_ for _ in ()).throw(OSError("x")), op="rd")
        assert ei.value.kind == "attempts"        # local — recoverable
        b = RetryBudget(max_attempts=1, max_recoveries=1)
        b.draw_attempt("op")
        with pytest.raises(RecoveryExhausted) as ei:
            b.draw_attempt("op")
        assert ei.value.kind == "budget:attempts"  # shared — degrade
        b.draw_recovery("restore")
        with pytest.raises(RecoveryExhausted) as ei:
            b.draw_recovery("restore")
        assert ei.value.kind == "budget:recoveries"

    def test_timeout_reports_but_returns_value(self):
        clock = iter([0.0, 10.0])          # one attempt taking 10s
        r = Retrier(policy=RetryPolicy(timeout=0.5),
                    clock=lambda: next(clock), sleep=lambda s: None)
        assert r.call(lambda: 42, op="slow", shard=3) == 42
        (ev,) = r.drain_timeouts()
        assert ev["shard"] == 3 and ev["elapsed_s"] == 10.0

    def test_nonretryable_errors_pass_through(self):
        r = Retrier(sleep=lambda s: None)
        with pytest.raises(ZeroDivisionError):
            r.call(lambda: 1 / 0, op="math")
        assert r.events == []

    def test_policy_validation_names_field(self):
        with pytest.raises(ValueError, match="max_attempts"):
            RetryPolicy(max_attempts=0)
        with pytest.raises(ValueError, match="jitter"):
            RetryPolicy(jitter=1.5)


# ---------------------------------------------------------------------------
# Schedule validation: errors name the offending field and value.
# ---------------------------------------------------------------------------

class TestScheduleValidation:
    def test_faultplan_strategy_error_names_field_and_value(self):
        with pytest.raises(ValueError,
                           match=r"FaultPlan\.strategy.*'bogus'"):
            FaultPlan(strategy="bogus")

    def test_faultplan_collision_error_is_actionable(self):
        with pytest.raises(ValueError, match=r"collide on stratum 3"
                                             r".*FaultSchedule"):
            FaultPlan(fail_at=3, rescale_at=3, new_num_shards=8)

    def test_faultplan_paired_fields(self):
        with pytest.raises(ValueError,
                           match=r"rescale_at.*new_num_shards"):
            FaultPlan(rescale_at=2)
        with pytest.raises(ValueError, match=r"FaultPlan\.fail_at.*-1"):
            FaultPlan(fail_at=-1)

    def test_faultevent_validation(self):
        with pytest.raises(ValueError, match=r"FaultEvent\.kind.*'boom'"):
            FaultEvent(kind="boom", at=0)
        with pytest.raises(ValueError, match=r"slowdown > 1\.0"):
            FaultEvent(kind="straggle", at=0, slowdown=0.5)
        with pytest.raises(ValueError, match="new_num_shards"):
            FaultEvent(kind="rescale", at=0)
        with pytest.raises(ValueError, match=r"FaultEvent\.during"):
            FaultEvent(kind="fail", at=0, during="lunch")

    def test_schedule_ordering_and_anchors(self):
        with pytest.raises(ValueError, match="non-decreasing"):
            FaultSchedule(events=(FaultEvent(kind="fail", at=5),
                                  FaultEvent(kind="fail", at=2)))
        with pytest.raises(ValueError, match="during='recovery'"):
            FaultSchedule(events=(
                FaultEvent(kind="fail", at=2, during="recovery"),))
        with pytest.raises(ValueError, match="during='rescale'"):
            FaultSchedule(events=(
                FaultEvent(kind="fail", at=2, during="rescale"),))

    def test_faultplan_converts_losslessly(self):
        plan = FaultPlan(fail_at=5, failed_shard=2, rescale_at=2,
                         new_num_shards=8, strategy="incremental")
        sched = plan.to_schedule()
        assert [e.kind for e in sched.events] == ["rescale", "fail"]
        assert sched.events[1].shard == 2 and sched.events[1].at == 5
        assert as_schedule(None).events == ()
        assert as_schedule(sched) is sched
        with pytest.raises(ValueError, match="FaultPlan or FaultSchedule"):
            as_schedule("nope")


# ---------------------------------------------------------------------------
# Checkpoint integrity: checksums, quarantine, torn writes, epoch GC.
# ---------------------------------------------------------------------------

class TestCheckpointIntegrity:
    def _tree(self, v: float):
        return {"mut": np.full((8, 2), v, np.float32)}

    def test_bit_flip_detected_quarantined_and_replica_wins(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), num_nodes=4, replication=3)
        cm.save_full(0, 1, self._tree(1.25))
        own = tmp_path / "node0" / "full_00000001_of0.npz"
        raw = bytearray(own.read_bytes())
        raw[len(raw) // 2] ^= 0xFF                      # bit corruption
        own.write_bytes(bytes(raw))
        tree, step = cm.load_full(0, self._tree(0.0), from_replica=True)
        assert step == 1
        np.testing.assert_array_equal(tree["mut"],
                                      self._tree(1.25)["mut"])
        assert len(cm.quarantined) == 1
        assert os.path.basename(os.path.dirname(cm.quarantined[0])) \
            == "quarantine"
        assert not own.exists()                          # moved aside

    def test_torn_write_falls_back_to_previous_step(self, tmp_path):
        """Regression: a write killed mid-stream (simulated by truncating
        EVERY replica copy of the newest full checkpoint — as if the
        crash tore the logical write everywhere) must recover from the
        previous step, never serve torn bytes, never raise."""
        cm = CheckpointManager(str(tmp_path), num_nodes=4, replication=3)
        cm.save_full(0, 1, self._tree(1.0))
        cm.save_full(0, 2, self._tree(2.0))
        for node in (0, 1, 2):
            p = tmp_path / f"node{node}" / "full_00000002_of0.npz"
            p.write_bytes(p.read_bytes()[:len(p.read_bytes()) // 2])
        tree, step = cm.load_full(0, self._tree(0.0), from_replica=True)
        assert step == 1                       # previous epoch's answer
        np.testing.assert_array_equal(tree["mut"], self._tree(1.0)["mut"])
        assert len(cm.quarantined) == 3

    def test_all_copies_torn_raises_corruption_not_garbage(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), num_nodes=2, replication=2)
        cm.save_full(0, 1, self._tree(1.0))
        for node in (0, 1):
            p = tmp_path / f"node{node}" / "full_00000001_of0.npz"
            p.write_bytes(b"torn")
        with pytest.raises(CheckpointCorruption):
            cm.load_full(0, self._tree(0.0), from_replica=True)

    def test_corrupt_delta_reads_from_replica(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), num_nodes=3, replication=2)
        cm.save_full(0, 0, self._tree(0.0))
        cm.save_delta(0, 1, np.arange(3, dtype=np.int32),
                      np.ones((3, 2), np.float32))
        p = tmp_path / "node0" / "delta_00000001_of0.npz"
        p.write_bytes(p.read_bytes()[:40])               # torn
        steps = list(cm.replay_deltas(0, since_step=0, from_replica=True))
        assert len(steps) == 1 and steps[0][0] == 1
        np.testing.assert_array_equal(steps[0][2],
                                      np.ones((3, 2), np.float32))

    def test_atomic_write_survives_failed_replace(self, tmp_path,
                                                  monkeypatch):
        """A crash at the replace boundary leaves the OLD file intact
        and readable — the atomicity contract."""
        path = str(tmp_path / "m" / "views.json")
        atomic_write_json(path, {"v": 1})

        def boom(src, dst):
            raise OSError("crash mid-replace")

        monkeypatch.setattr(os, "replace", boom)
        with pytest.raises(OSError):
            atomic_write_json(path, {"v": 2})
        monkeypatch.undo()
        with open(path) as f:
            assert json.load(f) == {"v": 1}
        # no stray tmp files left behind
        assert os.listdir(tmp_path / "m") == ["views.json"]

    def test_epoch_gc_keeps_only_recent_epochs(self, tmp_path):
        snap = PartitionSnapshot(n_keys=64, num_shards=4)
        chain = ReplicaChain(str(tmp_path / "c"), snap, 2, keep_epochs=2)
        packed = np.zeros((4, snap.block_size, 2), np.float32)
        for _ in range(4):                    # epochs 0..3
            chain.open_epoch()
            chain.baseline(packed)
        left = sorted(d for d in os.listdir(tmp_path / "c")
                      if d.startswith("epoch"))
        assert left == ["epoch2", "epoch3"]


# ---------------------------------------------------------------------------
# Straggler signals from I/O timeouts.
# ---------------------------------------------------------------------------

class TestTimeoutStragglerFeed:
    def test_note_timeout_promotes_shard_to_straggler(self):
        m = StragglerMitigator(4, SpeculationPolicy(threshold=2.0,
                                                    min_history=1))
        for _ in range(2):
            m.observe_stratum([1.0, 1.0, 1.0, 1.0])
        m.note_timeout(2)
        report = m.observe_stratum([1.0, 1.0, 1.0, 1.0])
        assert [d["shard"] for d in report["speculations"]] == [2]
        # flag is consumed: the next clean stratum speculates nothing
        report = m.observe_stratum([1.0, 1.0, 1.0, 1.0])
        assert report["speculations"] == []


# ---------------------------------------------------------------------------
# Chaos property: recoverable schedules are bit-identical.
# ---------------------------------------------------------------------------

_REF_CACHE: dict = {}


def _sssp_setup(graph):
    indptr, indices, snap, g = graph
    if "ex" not in _REF_CACHE:
        ex = make_executor(snap, route_strategy="auto")
        algo = sssp.make_algorithm(snap, src_capacity=snap.block_size,
                                   edge_capacity=8192)
        state0 = sssp.initial_state(snap, 0)
        ref = ex.run(algo, state0, 1, g, 80)
        _REF_CACHE.update(ex=ex, algo=algo, state0=state0, ref=ref,
                          ref_flat=flat_state(snap, ref.state))
    return _REF_CACHE


class TestChaosSchedules:
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2 ** 16))
    def test_recoverable_random_schedule_bit_identical(self, graph, seed):
        """Property: ANY seeded schedule of compounding failures +
        stragglers (no rescale here — covered below with remake) lands
        bit-identical to the failure-free run."""
        indptr, indices, snap, g = graph
        ctx = _sssp_setup(graph)
        schedule = generate_schedule(ChaosConfig(
            seed=seed, num_shards=S, n_events=3, max_stratum=5,
            p_rescale=0.0, p_correlated=0.3, p_during_recovery=0.4))
        with tempfile.TemporaryDirectory() as td:
            rr = ctx["ex"].run_resilient(
                ctx["algo"], ctx["state0"], 1, g, 80, ckpt_root=td,
                fault_plan=schedule)
        assert rr.metrics["converged"]
        np.testing.assert_array_equal(
            ctx["ref_flat"], flat_state(snap, rr.result.state),
            err_msg=f"seed={seed} events={schedule.events}")

    def test_acceptance_schedule_bit_identical(self, graph, tmp_path):
        """The ISSUE acceptance scenario: >= 3 faults including one
        correlated replica loss and one failure-during-recovery."""
        indptr, indices, snap, g = graph
        ctx = _sssp_setup(graph)
        schedule = acceptance_schedule(num_shards=S)
        assert schedule.fail_count >= 3
        assert any(e.correlated for e in schedule.events)
        assert any(e.during == "recovery" for e in schedule.events)
        rr = ctx["ex"].run_resilient(
            ctx["algo"], ctx["state0"], 1, g, 80,
            ckpt_root=str(tmp_path), fault_plan=schedule)
        assert rr.metrics["converged"]
        assert rr.metrics["recoveries"] >= 3
        np.testing.assert_array_equal(
            ctx["ref_flat"], flat_state(snap, rr.result.state))
        kinds = [e["event"] for e in rr.metrics["events"]]
        assert kinds.count("failure") >= 3
        assert "recovery" in kinds

    def test_rescale_with_midmigration_failure(self, graph, tmp_path):
        """Failure injected DURING an elastic rescale fires under the
        new snapshot against the barely-migrated chain."""
        indptr, indices, snap, g = graph
        ctx = _sssp_setup(graph)

        def remake(new_snap):
            return (make_executor(new_snap, route_strategy="auto"),
                    sssp.make_algorithm(new_snap,
                                        src_capacity=new_snap.block_size,
                                        edge_capacity=8192),
                    shard_csr(indptr, indices, new_snap.num_shards))

        schedule = FaultSchedule(events=(
            FaultEvent(kind="rescale", at=2, new_num_shards=8),
            FaultEvent(kind="fail", at=2, shard=6, during="rescale"),
            FaultEvent(kind="fail", at=3, shard=1),
        ))
        rr = ctx["ex"].run_resilient(
            ctx["algo"], ctx["state0"], 1, g, 80,
            ckpt_root=str(tmp_path), fault_plan=schedule, remake=remake)
        assert rr.metrics["converged"]
        assert rr.metrics["final_num_shards"] == 8
        got = np.asarray(unshard_dense_state(
            snap.resnapshot(8), jnp.stack(rr.result.state, -1)))
        np.testing.assert_array_equal(ctx["ref_flat"], got)

    def test_correlated_loss_beyond_replication_restarts(self, graph,
                                                         tmp_path):
        """replication=2: a correlated failure wipes the shard AND its
        only replica — incremental restore is impossible, the driver
        must fall back to restart (older-epoch semantics) and still land
        bit-identical."""
        indptr, indices, _, _ = graph
        snap = PartitionSnapshot(n_keys=N, num_shards=S, replication=2)
        g = shard_csr(indptr, indices, S)
        ex = make_executor(snap, route_strategy="auto")
        algo = sssp.make_algorithm(snap, src_capacity=snap.block_size,
                                   edge_capacity=8192)
        state0 = sssp.initial_state(snap, 0)
        ref = ex.run(algo, state0, 1, g, 80)
        schedule = FaultSchedule(events=(
            FaultEvent(kind="fail", at=2, shard=1, correlated=True),))
        rr = ex.run_resilient(algo, state0, 1, g, 80,
                              ckpt_root=str(tmp_path),
                              fault_plan=schedule)
        assert rr.metrics["converged"]
        assert rr.metrics["restarts"] >= 1
        kinds = [e["event"] for e in rr.metrics["events"]]
        assert "recovery_fallback" in kinds
        np.testing.assert_array_equal(flat_state(snap, ref.state),
                                      flat_state(snap, rr.result.state))

    def test_straggle_events_feed_speculation_not_results(self, graph,
                                                          tmp_path):
        indptr, indices, snap, g = graph
        ctx = _sssp_setup(graph)
        schedule = FaultSchedule(events=tuple(
            FaultEvent(kind="straggle", at=k, shard=2, slowdown=50.0)
            for k in range(2, 6)))
        rr = ctx["ex"].run_resilient(
            ctx["algo"], ctx["state0"], 1, g, 80,
            ckpt_root=str(tmp_path), fault_plan=schedule,
            policy=SpeculationPolicy(threshold=3.0, min_history=1))
        assert rr.metrics["converged"]
        specs = rr.metrics["speculations"]
        assert specs and all(d["shard"] == 2 for d in specs)
        assert all(v["ok"] for v in rr.metrics["speculation_verified"])
        np.testing.assert_array_equal(
            ctx["ref_flat"], flat_state(snap, rr.result.state))

    def test_retry_events_surface_in_metrics(self, graph, tmp_path):
        """Transient I/O errors during restore retry with backoff and
        land in the run's event stream + metrics counters."""
        indptr, indices, snap, g = graph
        ctx = _sssp_setup(graph)
        schedule = FaultSchedule(events=(
            FaultEvent(kind="fail", at=2, shard=1),))
        rr = ctx["ex"].run_resilient(
            ctx["algo"], ctx["state0"], 1, g, 80,
            ckpt_root=str(tmp_path), fault_plan=schedule,
            retry=RetryPolicy(max_attempts=3, base_delay=0.0,
                              max_delay=0.0))
        assert rr.metrics["converged"]
        assert rr.metrics["io_retries"] == 0      # clean disk: no retries
        assert rr.metrics["recoveries"] == 1
        assert "budget" not in rr.metrics         # none attached


# ---------------------------------------------------------------------------
# Unrecoverable schedules degrade — never raise, never corrupt.
# ---------------------------------------------------------------------------

class TestGracefulDegradation:
    def _mgr(self):
        from repro.incremental.mutations import EdgeInsert
        from repro.incremental.view import ViewManager
        indptr, indices = make_powerlaw_graph(256, 4.0, seed=1)
        mgr = ViewManager()
        view = mgr.create_graph_view("d", "sssp", indptr, indices, 256,
                                     num_shards=4, source=0)
        return mgr, view, EdgeInsert

    def test_budget_exhaustion_serves_stale_tagged_answer(self):
        mgr, view, EdgeInsert = self._mgr()
        fresh = mgr.query("d", detail=True)
        assert not fresh.degraded and fresh.stale_batches == 0

        view.fault_plan = FaultSchedule(events=(
            FaultEvent(kind="fail", at=0, shard=1),))
        view.retry_budget = RetryBudget(max_recoveries=0)
        mgr.mutate("d", EdgeInsert(0, 200))
        report = mgr.refresh("d")["d"]           # must NOT raise
        assert report.mode == "degraded"

        ans = mgr.query("d", detail=True)        # must NOT raise
        assert ans.degraded
        assert ans.stale_batches == 1
        assert ans.reason == "budget:recoveries"
        assert ans.version == 0 and ans.latest_version == 1
        # the degraded answer IS the last converged snapshot — bit-equal
        np.testing.assert_array_equal(ans.value, fresh.value)
        # legacy callers still get the bare array, served not raised
        np.testing.assert_array_equal(mgr.query("d"), fresh.value)

    def test_catchup_restores_freshness_and_correctness(self):
        mgr, view, EdgeInsert = self._mgr()
        view.fault_plan = FaultSchedule(events=(
            FaultEvent(kind="fail", at=0, shard=1),))
        view.retry_budget = RetryBudget(max_recoveries=0)
        mgr.mutate("d", EdgeInsert(0, 200))
        assert mgr.refresh("d")["d"].mode == "degraded"

        view.retry_budget = None                 # operator restored it
        report = mgr.refresh("d")["d"]
        assert report.mode == "cold"             # lost plan => cold only
        ans = mgr.query("d", detail=True)
        assert not ans.degraded and ans.stale_batches == 0
        assert ans.version == 1

        # bit-identical to a never-degraded view over the same data
        mgr2, view2, _ = self._mgr()
        view2.apply(EdgeInsert(0, 200))
        view2.refresh()
        np.testing.assert_array_equal(mgr2.query("d"), ans.value)

    def test_degradation_emits_observability_events(self):
        from repro.obs.metrics import MetricsRegistry
        from repro.obs.trace import Tracer
        from repro.incremental.mutations import EdgeInsert
        from repro.incremental.view import ViewManager
        indptr, indices = make_powerlaw_graph(256, 4.0, seed=1)
        tracer, metrics = Tracer(), MetricsRegistry()
        mgr = ViewManager(tracer=tracer, metrics=metrics)
        view = mgr.create_graph_view("d", "sssp", indptr, indices, 256,
                                     num_shards=4, source=0)
        view.fault_plan = FaultSchedule(events=(
            FaultEvent(kind="fail", at=0, shard=1),))
        view.retry_budget = RetryBudget(max_recoveries=0)
        mgr.mutate("d", EdgeInsert(0, 200))
        mgr.refresh("d")
        assert metrics.counter("view.degradations").value == 1
        assert metrics.gauge("view.staleness.d").value == 1
        names = [e.get("name") for e in tracer.events]
        assert "view_degraded" in names
        mgr.refresh("d", force="cold")
        assert metrics.gauge("view.staleness.d").value == 0
        names = [e.get("name") for e in tracer.events]
        assert "view_recovered" in names


# ---------------------------------------------------------------------------
# Real-SPMD backend (subprocess: needs 8 virtual devices).
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_chaos_acceptance_shard_map():
    """The acceptance schedule on the shard_map backend: multi-event
    chaos recovery must reproduce the fused shard_map run exactly."""
    from subproc import run_sub
    out = run_sub("""
import tempfile
import jax, jax.numpy as jnp
from repro.data.graphs import make_powerlaw_graph, shard_csr
from repro.core.partition import PartitionSnapshot
from repro.core.engine import ShardedExecutor
from repro.launch.mesh import flat_mesh
from repro.algorithms import sssp
from repro.runtime.chaos import acceptance_schedule
n, S = 512, 8
indptr, indices = make_powerlaw_graph(n, avg_degree=8.0, seed=0)
snap = PartitionSnapshot(n_keys=n, num_shards=S)
g = shard_csr(indptr, indices, S)
ex = ShardedExecutor(snapshot=snap, seg_capacity=8192, edge_capacity=8192,
                     src_capacity=snap.block_size, backend='shard_map',
                     axis_name='shards', mesh=flat_mesh(S, 'shards'),
                     ladder_tiers=4)
algo = sssp.make_algorithm(snap, src_capacity=snap.block_size,
                           edge_capacity=8192)
state0 = sssp.initial_state(snap, 0)
ref = ex.run(algo, state0, 1, g, 80)
schedule = acceptance_schedule(num_shards=S)
with tempfile.TemporaryDirectory() as td:
    rr = ex.run_resilient(algo, state0, 1, g, 80, ckpt_root=td,
                          fault_plan=schedule)
assert rr.metrics['converged']
assert rr.metrics['recoveries'] >= 3
assert bool(jnp.all(jnp.stack([jnp.all(a == b) for a, b in
                               zip(ref.state, rr.result.state)])))
print('CHAOS_SPMD_OK')
""")
    assert "CHAOS_SPMD_OK" in out


# ---------------------------------------------------------------------------
# CLI smoke (simulated mode): exit-code contract + bit-comparison output.
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_chaos_cli_simulated_smoke():
    """``python -m repro.runtime.chaos`` exit-code contract: 0 with
    ``identical: true`` in the JSON summary for a recoverable seeded
    schedule on a tiny graph."""
    import json
    import os
    import subprocess
    import sys

    from subproc import SRC, default_timeout
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-m", "repro.runtime.chaos", "--seed", "3",
         "--events", "2", "--quick", "--nodes", "1024"],
        env=env, capture_output=True, text=True,
        timeout=default_timeout())
    assert out.returncode == 0, out.stderr[-3000:] + out.stdout[-2000:]
    summary = json.loads(out.stdout)
    assert summary["mode"] == "simulated"
    assert summary["identical"] is True
    assert summary["seed"] == 3
    # The bit-comparison drives the exit code: the summary must carry
    # the recovery accounting the comparison gates on.
    for key in ("recoveries", "restarts", "strata_executed", "events"):
        assert key in summary
