"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; only launch/dryrun.py forces 512 virtual devices.

Also provides a minimal fallback for ``hypothesis`` so the suite collects
and runs when the real package is absent (see requirements-dev.txt): the
shim draws a small, deterministic sample from each strategy instead of
doing real property search.  Install ``hypothesis`` for full coverage.
"""
import gc
import sys
import types

import numpy as np
import pytest

import jax

try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised when hypothesis missing
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    # Parameter names mirror the real hypothesis API so both positional
    # and keyword call styles behave identically under the shim.
    def _integers(min_value=0, max_value=1 << 30):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    def _booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    def _lists(elem, min_size=0, max_size=10, **_kw):
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elem.draw(rng) for _ in range(size)]
        return _Strategy(draw)

    def _given(**strategies):
        def decorate(fn):
            import functools
            import inspect

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples", 10)
                rng = np.random.default_rng(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            # Drop drawn params from the visible signature so pytest does
            # not look for fixtures named after them.
            sig = inspect.signature(fn)
            remaining = [p for name, p in sig.parameters.items()
                         if name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=remaining)
            wrapper._shim_max_examples = 10
            return wrapper
        return decorate

    def _settings(max_examples=10, **_kw):
        def decorate(fn):
            fn._shim_max_examples = max_examples
            return fn
        return decorate

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _st.lists = _lists

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_shim__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


# Every module's fixpoints stay alive in jax's global jit caches even
# after the module's fixtures are torn down; by the tail of the suite the
# accumulated executables segfault XLA inside backend_compile on small
# CI boxes.  Dropping the caches after the HEAVY modules keeps the live
# set bounded by one module's worth of whole-engine compilations, while
# fast unit modules (pure-python logic, subprocess-only, or a handful of
# tiny jits) skip the drop so they neither pay the clear nor force the
# next module to recompile shared helpers.
_CACHE_HEAVY_MODULES = frozenset({
    "test_algorithms", "test_chaos", "test_incremental", "test_kernels",
    "test_ladder", "test_models", "test_obs", "test_optimized_paths",
    "test_rehash_strategies", "test_resilient", "test_sharding_roofline",
})


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_after_module(request):
    yield
    if request.module.__name__ in _CACHE_HEAVY_MODULES:
        jax.clear_caches()
        gc.collect()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
