"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; only launch/dryrun.py forces 512 virtual devices."""
import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
