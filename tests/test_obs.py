"""Observability layer: metrics registry, tracer, exporters, measured
route calibration, and the measured-latency speculation feed.

The load-bearing contract: attaching a tracer/registry never changes
WHAT is computed — every traced run below is asserted bit-identical to
its untraced twin — while the recorded events/metrics must agree with
the run's own stats (probe count == strata executed, etc.).
"""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.algorithms import pagerank, sssp
from repro.core.engine import ShardedExecutor
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import make_powerlaw_graph, shard_csr
from repro.obs import (MeasuredLatencies, MetricsRegistry, RouteCostTable,
                       Tracer, calibrate_executor_table, metrics_to_json,
                       to_chrome_trace)
from repro.runtime import FaultPlan, SpeculationPolicy

N, S = 512, 4


@pytest.fixture(scope="module")
def graph():
    indptr, indices = make_powerlaw_graph(N, avg_degree=8.0, seed=0)
    snap = PartitionSnapshot(n_keys=N, num_shards=S)
    return snap, shard_csr(indptr, indices, S)


def make_executor(snap, **kw):
    kw.setdefault("ladder_tiers", 4)
    kw.setdefault("route_strategy", "auto")
    return ShardedExecutor(snapshot=snap, seg_capacity=8192,
                           edge_capacity=8192,
                           src_capacity=snap.block_size, **kw)


def pr_setup(snap):
    algo = pagerank.make_algorithm(snap, src_capacity=snap.block_size,
                                   edge_capacity=8192)
    return algo, pagerank.initial_state(snap), snap.padded_keys


def states_equal(a, b) -> bool:
    return bool(jnp.all(jnp.stack(
        [jnp.all(x == y) for x, y in zip(a, b)])))


# ---------------------------------------------------------------------------
# Metrics registry.
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter_gauge_histogram(self):
        reg = MetricsRegistry()
        reg.counter("c").inc()
        reg.counter("c").inc(2.5)
        reg.gauge("g").set(7)
        reg.gauge("g").inc(3)
        reg.gauge("g").dec(1)
        for v in (0.001, 0.01, 0.01, 5.0):
            reg.histogram("h").observe(v)
        snap = reg.snapshot()
        assert snap["c"]["value"] == 3.5
        assert snap["g"]["value"] == 9
        h = snap["h"]
        assert h["count"] == 4
        assert h["min"] == 0.001 and h["max"] == 5.0
        np.testing.assert_allclose(h["sum"], 5.021)
        assert sum(h["buckets"].values()) == 4

    def test_kind_mismatch_raises(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_snapshot_is_json_and_reset(self):
        reg = MetricsRegistry()
        reg.counter("a").inc()
        reg.histogram("b").observe(1.0)
        json.dumps(reg.snapshot())          # must serialize as-is
        reg.reset()
        assert reg.snapshot() == {}


# ---------------------------------------------------------------------------
# Tracer + exporter.
# ---------------------------------------------------------------------------

class TestTracer:
    def test_span_and_instant_structure(self):
        tr = Tracer("t")
        with tr.span("work", tid="host", k=1) as args:
            args["result"] = 42
        tr.instant("ping", shard=2)
        spans = [e for e in tr.events if e["ph"] == "X"]
        assert spans[0]["name"] == "work"
        assert spans[0]["args"] == {"k": 1, "result": 42}
        assert spans[0]["dur"] >= 0
        ct = to_chrome_trace(tr)
        json.dumps(ct)
        phs = {e["ph"] for e in ct["traceEvents"]}
        assert phs == {"M", "X", "i"}
        # thread_name metadata rows label every recorded tid
        rows = [e["args"]["name"] for e in ct["traceEvents"]
                if e["name"] == "thread_name"]
        assert "host" in rows

    def test_traced_run_bit_identical_and_probe_counts(self, graph):
        snap, g = graph
        algo, state0, live0 = pr_setup(snap)
        ref = make_executor(snap).run(algo, state0, live0, g, 60)

        tr = Tracer("pr", metrics=MetricsRegistry())
        res = make_executor(snap, tracer=tr).run(algo, state0, live0, g, 60)
        assert states_equal(ref.state, res.state)
        np.testing.assert_array_equal(np.asarray(ref.stats.delta_counts),
                                      np.asarray(res.stats.delta_counts))
        iters = int(ref.stats.iterations)
        probes = [e for e in tr.events if e["name"].startswith("stratum")]
        assert len(probes) == iters
        # probe payloads mirror the run's own stats, stratum by stratum
        by_stratum = {e["args"]["stratum"]: e["args"] for e in probes}
        counts = np.asarray(ref.stats.delta_counts)
        for k in range(iters):
            assert by_stratum[k]["emitted"] == int(counts[k])
        snap_m = tr.metrics.snapshot()
        assert snap_m["engine.strata"]["value"] == iters
        assert snap_m["engine.deltas_emitted"]["value"] == int(
            counts.sum())
        assert snap_m["engine.stratum_seconds"]["count"] == iters
        assert any(e["name"] == "fixpoint_done" for e in tr.events)

    def test_measured_latencies_indexing(self):
        ml = MeasuredLatencies()
        with pytest.raises(ValueError):
            ml(0)
        ml.observe([1.0, 2.0])
        ml.observe([3.0, 4.0])
        assert ml(0) == [1.0, 2.0]
        assert ml(1) == [3.0, 4.0]
        assert ml(99) == [3.0, 4.0]         # clamped to latest
        assert len(ml) == 2


# ---------------------------------------------------------------------------
# Measured route calibration (route_strategy="measured").
# ---------------------------------------------------------------------------

class TestMeasuredRoute:
    def test_measured_mode_requires_table(self, graph):
        snap, g = graph
        algo, state0, live0 = pr_setup(snap)
        ex = make_executor(snap, route_strategy="measured")
        with pytest.raises(ValueError, match="route_table"):
            ex.run(algo, state0, live0, g, 60)

    def test_calibrated_run_matches_auto_results(self, graph):
        snap, g = graph
        algo, state0, live0 = pr_setup(snap)
        ex_auto = make_executor(snap)
        table = calibrate_executor_table(ex_auto, algo, reps=1, warmup=0)
        assert table.backend == jax.default_backend()
        ex = make_executor(snap, route_strategy="measured",
                           route_table=table)
        ref = ex_auto.run(algo, state0, live0, g, 60)
        res = ex.run(algo, state0, live0, g, 60)
        # dispatch may differ (measured vs modeled) but the rehash is
        # strategy-invariant: identical deltas, identical bytes
        assert states_equal(ref.state, res.state)
        np.testing.assert_array_equal(np.asarray(ref.stats.delta_counts),
                                      np.asarray(res.stats.delta_counts))
        np.testing.assert_array_equal(np.asarray(ref.stats.rehash_bytes),
                                      np.asarray(res.stats.rehash_bytes))
        iters = int(res.stats.iterations)
        assert np.all(np.asarray(res.stats.routes)[:iters] >= 0)

    def test_table_interpolation_and_backend_stamp(self):
        table = RouteCostTable(backend="tpu", combiner="add",
                               entries={64: (1.0, 3.0), 256: (3.0, 1.0)})
        assert table.pick(64, strict=False) == "sort"
        assert table.pick(256, strict=False) == "scatter"
        assert table.pick(1024, strict=False) == "scatter"   # clamped
        s, p = table.costs(128)              # log-midpoint of 64..256
        np.testing.assert_allclose([s, p], [2.0, 2.0])
        with pytest.raises(ValueError, match="tpu"):
            table.pick(64)                   # CPU test runner != tpu

    def test_from_bench_records(self):
        records = [
            {"name": "r1", "value": 0.02, "unit": "s", "C": 1024, "S": 4,
             "combiner": "add", "strategy": "sort"},
            {"name": "r2", "value": 0.01, "unit": "s", "C": 1024, "S": 4,
             "combiner": "add", "strategy": "scatter"},
            {"name": "r3", "value": 0.5, "unit": "s", "C": 4096, "S": 8,
             "combiner": "add", "strategy": "sort"},          # wrong S
            {"name": "r4", "value": 7, "unit": "count", "C": 1024, "S": 4,
             "combiner": "add", "strategy": "sort"},          # wrong unit
        ]
        table = RouteCostTable.from_bench_records(records, shards=4,
                                                  backend="cpu")
        assert table.entries == {1024: (0.02, 0.01)}
        assert table.pick(1024, strict=False) == "scatter"
        with pytest.raises(ValueError):
            RouteCostTable.from_bench_records(records, shards=16)


# ---------------------------------------------------------------------------
# Resilient driver: measured-latency speculation + event mirroring.
# ---------------------------------------------------------------------------

class TestResilientObservability:
    def test_policy_without_model_uses_measured(self, graph, tmp_path):
        snap, g = graph
        algo = sssp.make_algorithm(snap, src_capacity=snap.block_size,
                                   edge_capacity=8192)
        state0 = sssp.initial_state(snap, 0)
        ex = make_executor(snap)
        ref = ex.run(algo, state0, 1, g, 80)
        rr = ex.run_resilient(
            algo, state0, 1, g, 80, ckpt_root=str(tmp_path),
            policy=SpeculationPolicy(threshold=2.0, min_history=1))
        assert rr.metrics["converged"]
        assert states_equal(ref.state, rr.result.state)
        assert rr.metrics["latency_source"] == "measured"
        walls = rr.metrics["stratum_wall_s"]
        assert len(walls) == rr.metrics["strata_executed"]
        assert all(w > 0 for w in walls)

    def test_recovery_events_reach_tracer_and_registry(self, graph,
                                                       tmp_path):
        snap, g = graph
        algo, state0, live0 = pr_setup(snap)
        tr = Tracer("resil")
        reg = MetricsRegistry()
        ex = make_executor(snap, tracer=tr)
        ref = make_executor(snap).run(algo, state0, live0, g, 80)
        rr = ex.run_resilient(
            algo, state0, live0, g, 80, ckpt_root=str(tmp_path),
            fault_plan=FaultPlan(fail_at=3, failed_shard=1), metrics=reg)
        assert rr.metrics["converged"]
        assert states_equal(ref.state, rr.result.state)
        names = [e["name"] for e in tr.events]
        assert "failure" in names
        assert names.count("stratum_sliced") == rr.metrics[
            "strata_executed"]
        assert names.count("replicate") == rr.metrics["strata_executed"]
        snap_m = reg.snapshot()
        assert snap_m["recovery.failures"]["value"] == 1
        assert snap_m["recovery.stratum_seconds"]["count"] == rr.metrics[
            "strata_executed"]
        json.dumps(to_chrome_trace(tr))
        json.dumps(metrics_to_json(reg, extra={"x": 1}))


# ---------------------------------------------------------------------------
# View instrumentation.
# ---------------------------------------------------------------------------

class TestViewObservability:
    def test_refresh_metrics_and_journal_depth(self):
        from repro.incremental import EdgeInsert, ViewManager
        indptr, indices = make_powerlaw_graph(256, avg_degree=6.0, seed=3)
        tr, reg = Tracer("views"), MetricsRegistry()
        mgr = ViewManager(tracer=tr, metrics=reg)
        mgr.create_graph_view("pv", "pagerank", indptr, indices, 256,
                              num_shards=4, threshold=1e-4)
        mgr.mutate("pv", EdgeInsert(3, 9))
        rep = mgr.refresh("pv")["pv"]
        mgr.refresh("pv")                    # noop
        snap_m = reg.snapshot()
        assert snap_m["view.colds"]["value"] == 1
        assert snap_m["view.noops"]["value"] == 1
        assert snap_m["view.mutations_applied"]["value"] == 1
        assert snap_m["view.journal_depth.pv"]["value"] == 1
        assert snap_m[f"view.{rep.mode}s"]["value"] >= 1
        if rep.mode == "repair":
            assert snap_m["view.repair_seconds"]["count"] == 1
        rows = [e for e in tr.events if e.get("tid") == "views"]
        assert [e["name"] for e in rows[:2]] == ["pv.cold", f"pv.{rep.mode}"]
        # untraced twin computes the same answer
        mgr2 = ViewManager()
        mgr2.create_graph_view("pv", "pagerank", indptr, indices, 256,
                               num_shards=4, threshold=1e-4)
        mgr2.mutate("pv", EdgeInsert(3, 9))
        mgr2.refresh("pv")
        np.testing.assert_array_equal(mgr.query("pv"), mgr2.query("pv"))

    def test_checkpoint_resets_journal_depth(self, tmp_path):
        from repro.incremental import EdgeInsert, ViewManager
        indptr, indices = make_powerlaw_graph(256, avg_degree=6.0, seed=3)
        reg = MetricsRegistry()
        mgr = ViewManager(journal_root=str(tmp_path), metrics=reg)
        mgr.create_graph_view("pv", "pagerank", indptr, indices, 256,
                              num_shards=4, threshold=1e-4)
        for s, d in ((5, 9), (80, 160)):
            mgr.mutate("pv", EdgeInsert(s, d))
            mgr.refresh("pv")
        assert reg.snapshot()["view.journal_depth.pv"]["value"] == 2
        mgr.checkpoint("pv")
        assert reg.snapshot()["view.journal_depth.pv"]["value"] == 0
